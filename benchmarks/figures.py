"""Paper-figure harnesses (Figs 12-21, Table 2) over the gpusim reproduction.

Each ``fig*`` function returns a dict of derived results and prints a
compact table; ``benchmarks.run`` drives them all and asserts the
validation targets from EXPERIMENTS.md §Reproduction.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Dict

import numpy as np

from repro.core import predictor as P
from repro.core.gpusim import (FEATURE_NAMES, SCHEMES, WORKLOADS,
                               profile_features, run_all)
from repro.core.gpusim.corpus import train_sim_predictor
from repro.core.gpusim.sim import FUSED, QSPLIT

_MODEL_CACHE = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "sim_predictor.json")


@functools.lru_cache(maxsize=1)
def trained_predictor():
    if os.path.exists(_MODEL_CACHE):
        return P.load_model(_MODEL_CACHE), {"cached": True}
    model, info = train_sim_predictor(variants_per_workload=16, epochs=32)
    os.makedirs(os.path.dirname(_MODEL_CACHE), exist_ok=True)
    P.save_model(model, _MODEL_CACHE)
    return model, info


@functools.lru_cache(maxsize=1)
def all_results():
    model, _ = trained_predictor()
    decider = lambda feats: bool(P.predict_fuse(model, feats))
    return {s: run_all(s, fuse_decider=decider) for s in SCHEMES}


def _speedups(scheme: str) -> Dict[str, float]:
    res = all_results()
    return {n: res[scheme][n].ipc / res["baseline"][n].ipc for n in WORKLOADS}


def _geo(d: Dict[str, float]) -> float:
    return float(np.exp(np.mean(np.log(list(d.values())))))


def fig12_performance() -> Dict:
    """IPC speedup over the scale-out baseline, 5 schemes (paper Fig 12)."""
    out = {"schemes": {}}
    print(f"{'bench':8s}" + "".join(f"{s:>14s}" for s in SCHEMES[1:]))
    for name in WORKLOADS:
        row = [_speedups(s)[name] for s in SCHEMES[1:]]
        print(f"{name:8s}" + "".join(f"{v:14.3f}" for v in row))
    for s in SCHEMES[1:]:
        sp = _speedups(s)
        out["schemes"][s] = {"geomean": _geo(sp), **sp}
        print(f"geomean {s:14s} {_geo(sp):.3f}")
    wr = _speedups("warp_regroup")
    out["validation"] = {
        "SM_speedup": wr["SM"], "paper_SM": 4.25,
        "MUM_speedup": wr["MUM"], "paper_MUM": 2.11,
        "geomean": _geo(wr), "paper_geomean": 1.47,
        "regroup_over_direct":
            _geo(wr) / _geo(_speedups("direct_split")),
    }
    return out


def fig13_stalls() -> Dict:
    """Control-divergence stall fraction (paper Fig 13)."""
    res = all_results()
    out = {}
    print(f"{'bench':8s}" + "".join(f"{s:>14s}" for s in SCHEMES))
    for name in WORKLOADS:
        row = [res[s][name].control_stall for s in SCHEMES]
        out[name] = dict(zip(SCHEMES, row))
        print(f"{name:8s}" + "".join(f"{v:14.3f}" for v in row))
    # paper: baseline (narrow pipes) has the least control stalls
    means = {s: float(np.mean([out[n][s] for n in WORKLOADS]))
             for s in SCHEMES}
    out["mean"] = means
    return out


def fig14_16_memory() -> Dict:
    """L1I / L1D miss rates + actual memory access rate (Figs 14-16)."""
    res = all_results()
    out = {}
    print(f"{'bench':8s}{'l1i_b':>8s}{'l1i_wr':>8s}{'l1d_b':>8s}"
          f"{'l1d_wr':>8s}{'mem_b':>8s}{'mem_wr':>8s}")
    for name in WORKLOADS:
        b = res["baseline"][name]
        w = res["warp_regroup"][name]
        out[name] = {
            "l1i_base": b.l1i_miss, "l1i_amoeba": w.l1i_miss,
            "l1d_base": b.l1d_miss, "l1d_amoeba": w.l1d_miss,
            "mem_rate_base": b.actual_mem_rate,
            "mem_rate_amoeba": w.actual_mem_rate,
        }
        print(f"{name:8s}{b.l1i_miss:8.3f}{w.l1i_miss:8.3f}{b.l1d_miss:8.3f}"
              f"{w.l1d_miss:8.3f}{b.actual_mem_rate:8.3f}"
              f"{w.actual_mem_rate:8.3f}")
    return out


def fig17_18_noc() -> Dict:
    """NoC stall rate + per-router injection rate (Figs 17-18)."""
    res = all_results()
    out = {}
    print(f"{'bench':8s}{'stall_b':>9s}{'stall_wr':>9s}{'inj_b':>8s}"
          f"{'inj_wr':>8s}")
    for name in WORKLOADS:
        b = res["baseline"][name]
        w = res["warp_regroup"][name]
        out[name] = {"noc_stall_base": b.noc_stall,
                     "noc_stall_amoeba": w.noc_stall,
                     "inject_base": b.injection_rate,
                     "inject_amoeba": w.injection_rate}
        print(f"{name:8s}{b.noc_stall:9.3f}{w.noc_stall:9.3f}"
              f"{b.injection_rate:8.3f}{w.injection_rate:8.3f}")
    return out


def fig19_dynamics() -> Dict:
    """Fuse/split phases of RAY (paper Fig 19)."""
    res = all_results()
    tr = res["warp_regroup"]["RAY"].trace
    fused_frac = (tr == FUSED).mean(axis=1)
    out = {
        "epochs": int(tr.shape[0]),
        "fused_frac_series": fused_frac[:64].round(3).tolist(),
        "mean_fused": float((tr == FUSED).mean()),
        "mean_split": float((tr == QSPLIT).mean()),
        "switches": int(res["warp_regroup"]["RAY"].switches),
        "heterogeneous_epochs_frac": float(
            ((tr == FUSED).any(axis=1) & (tr == QSPLIT).any(axis=1)).mean()),
    }
    print(json.dumps({k: v for k, v in out.items()
                      if k != "fused_frac_series"}, indent=1))
    return out


def fig20_predictor() -> Dict:
    """Predictor coefficients + per-benchmark impact magnitudes (Table 2 /
    Fig 20)."""
    model, info = trained_predictor()
    out = {"coefficients": dict(zip(FEATURE_NAMES,
                                    np.asarray(model.w).round(4).tolist())),
           "train_info": {k: v for k, v in info.items()}}
    print("coefficients:")
    for n, w in out["coefficients"].items():
        print(f"  {n:18s} {w:+.3f}")
    impacts = {}
    for name in ("BFS", "RAY", "CP", "SM"):
        x = profile_features(WORKLOADS[name])
        imp = np.asarray(P.feature_impacts(model, x))
        impacts[name] = {
            "impacts": dict(zip(FEATURE_NAMES, imp.round(3).tolist())),
            "P_fuse": float(P.predict_proba(model, x)),
        }
        print(f"{name}: P(fuse)={impacts[name]['P_fuse']:.3f}")
    out["impacts"] = impacts
    return out


def fig21_dws() -> Dict:
    """AMOEBA vs Dynamic Warp Subdivision (paper Fig 21)."""
    wr = _speedups("warp_regroup")
    dws = _speedups("dws")
    rel = {n: wr[n] / dws[n] for n in WORKLOADS}
    out = {"amoeba_over_dws": rel, "geomean": _geo(rel),
           "SM_over_dws": rel["SM"], "paper_SM_over_dws": 3.97,
           "paper_geomean": 1.27}
    print(f"AMOEBA/DWS geomean {out['geomean']:.3f} "
          f"(paper ~1.27); SM {rel['SM']:.2f} (paper 3.97)")
    return out
