"""fleet_scale sweep: the vectorized SoA core at 100+ groups x 100k requests.

The scaling benchmark the ROADMAP gated on: replay a 100k-request trace
through a 100-group fleet under the struct-of-arrays engine
(``FleetConfig.engine="vec"``, see ``repro.fleet.vec``) in CI minutes,
and measure its ticks-per-second advantage over the object engine on the
*same* dynamic configuration.  The object baseline is priced on a
steady-state segment (a warmup run absorbs the jit compiles first) so
the reported speedup is engine-vs-engine, not compile-vs-no-compile.

Also carries the ``suggest_split`` micro-benchmark: the control plane's
candidate scan used to re-sort and re-partition the live batch for every
candidate topology (O(parts x capacity) full evaluations); the shared-
ordering evaluator in ``repro.control.space`` sorts once and prices each
candidate from cached per-part counts.  The micro-benchmark times the
faithful legacy formulation against the shipped one on identical inputs
and asserts identical argmins.

    PYTHONPATH=src python benchmarks/fleet_scale_bench.py \
        --groups 100 --requests 100000 --budget-s 600 --min-speedup 20
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT = os.path.join(ROOT, "BENCH_fleet.json")
TIMING_OUT = os.path.join(ROOT, "BENCH_fleet_scale_timing.json")

# summary keys kept per variant — full summaries carry one snapshot per
# group (100+ entries), which would bloat the committed artifact
_KEEP = ("wall_ticks", "idle_ticks", "wall_s", "ticks_per_sec",
         "completed", "submitted", "efficiency", "utilization",
         "throughput_tokens_per_tick", "latency", "mean_queue_depth",
         "churn_per_kilotick")


def scale_trace(n_requests: int, groups: int, horizon: int,
                seed: int = 0) -> List:
    """A flat 100k-request trace built directly (no per-tick sampling).

    Work-balanced arrivals over ``horizon`` ticks, a bimodal-ish length
    mix, round-robin shards (so sticky routing would spread it), and one
    shared prompt object — requests never mutate their prompt, and the
    single length keeps the object baseline to one prefill shape per
    batch size.
    """
    import numpy as np

    from repro.serve.engine import Request
    rng = np.random.default_rng(seed)
    lengths = rng.choice([4, 8, 16, 32, 48], size=n_requests,
                         p=[0.35, 0.3, 0.2, 0.1, 0.05])
    arrivals = np.sort(rng.integers(0, horizon, size=n_requests))
    prompt = [1] * 8
    return [Request(rid=i, prompt=prompt, max_new_tokens=int(lengths[i]),
                    arrival=int(arrivals[i]), shard=i % groups)
            for i in range(n_requests)]


def fleet_scale_sweep(cfg, params, rt, *, groups: int = 100,
                      capacity: int = 8, n_requests: int = 100_000,
                      obj_warmup_ticks: int = 10,
                      obj_measure_ticks: int = 20,
                      seed: int = 0,
                      budget_s: Optional[float] = None,
                      min_speedup: Optional[float] = None,
                      decode=None) -> Dict:
    """Vec-engine variants over the full trace + object steady-state tps."""
    from repro.configs.base import AmoebaConfig, FleetConfig
    from repro.fleet import FleetEngine

    amoeba = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                          min_phase_steps=2)
    # horizon sized so the fleet stays loaded but drains: total decode
    # work over ~70% of the fleet's peak token throughput
    mean_len = 0.35 * 4 + 0.3 * 8 + 0.2 * 16 + 0.1 * 32 + 0.05 * 48
    horizon = max(int(n_requests * mean_len / (groups * capacity * 0.7)), 1)
    variants = {
        "static_fused": dict(mode="fused", router="least_loaded"),
        "static_split": dict(mode="split", router="least_loaded"),
        "dynamic_threshold": dict(mode="dynamic", router="least_loaded"),
    }
    out: Dict = {"config": {
        "groups": groups, "capacity": capacity, "n_requests": n_requests,
        "horizon": horizon, "seed": seed, "window": 64,
        "obj_warmup_ticks": obj_warmup_ticks,
        "obj_measure_ticks": obj_measure_ticks}}

    for label, kw in variants.items():
        eng = FleetEngine(cfg, None, rt=rt, fleet=FleetConfig(
            num_groups=groups, capacity=capacity, window=64,
            amoeba=amoeba, engine="vec", **kw))
        eng.submit(scale_trace(n_requests, groups, horizon, seed))
        s = eng.run()
        if s["completed"] != n_requests:
            raise RuntimeError(f"{label}: completed {s['completed']} of "
                               f"{n_requests} requests")
        out[label] = {k: s[k] for k in _KEEP}
        lat = s["latency"]
        print(f"{label:18s} ticks={s['wall_ticks']:6d} "
              f"wall={s['wall_s']:7.2f}s tps={s['ticks_per_sec']:8.1f} "
              f"eff={s['efficiency']:.3f} p50={lat['p50']:5.1f} "
              f"p99={lat['p99']:6.1f} done={s['completed']}")

    # object-engine baseline: identical dynamic config, steady-state
    # segment only (the warmup run absorbs the jit compiles)
    eng = FleetEngine(cfg, params, rt=rt, decode_fn=decode,
                      fleet=FleetConfig(
                          num_groups=groups, capacity=capacity, window=64,
                          amoeba=amoeba, engine="object",
                          **variants["dynamic_threshold"]))
    eng.submit(scale_trace(n_requests, groups, horizon, seed))
    s1 = eng.run(max_ticks=obj_warmup_ticks)
    t0 = time.perf_counter()
    s2 = eng.run(max_ticks=obj_warmup_ticks + obj_measure_ticks)
    dt = time.perf_counter() - t0
    obj_ticks = s2["wall_ticks"] - s1["wall_ticks"]
    obj_tps = obj_ticks / max(dt, 1e-9)
    out["object_baseline"] = {
        "measured_ticks": obj_ticks, "wall_s": round(dt, 3),
        "ticks_per_sec": round(obj_tps, 2),
        "note": "steady-state segment after a warmup run absorbed "
                "the jit compiles; same dynamic config as the vec run"}
    print(f"{'object_baseline':18s} ticks={obj_ticks:6d} "
          f"wall={dt:7.2f}s tps={obj_tps:8.2f} (steady-state)")

    vec_tps = out["dynamic_threshold"]["ticks_per_sec"]
    vec_wall = sum(out[k]["wall_s"] for k in variants)
    speedup = vec_tps / max(obj_tps, 1e-9)
    out["validation"] = {
        "vec_ticks_per_sec": vec_tps,
        "object_ticks_per_sec": round(obj_tps, 2),
        "vec_speedup_ticks_per_sec": round(speedup, 1),
        "vec_total_wall_s": round(vec_wall, 2),
        "all_traces_drained": True,
        "budget_s": budget_s,
        "within_budget": bool(budget_s is None or vec_wall <= budget_s),
    }
    print(f"vec vs object (dynamic, {groups} groups): "
          f"{speedup:,.1f}x ticks/sec; vec swept "
          f"{len(variants)}x{n_requests:,} requests in {vec_wall:.1f}s")
    if budget_s is not None and vec_wall > budget_s:
        raise RuntimeError(f"fleet_scale vec sweep took {vec_wall:.1f}s "
                           f"> budget {budget_s:.0f}s")
    if min_speedup is not None and speedup < min_speedup:
        raise RuntimeError(f"vec speedup {speedup:.1f}x < required "
                           f"{min_speedup:.0f}x")
    return out


def obs_overhead_sweep(cfg, rt, *, groups: int = 20, capacity: int = 8,
                       n_requests: int = 20_000, seed: int = 0,
                       repeats: int = 3) -> Dict:
    """Ticks-per-second cost of the obs event stream on the vec engine.

    Three modes over the identical fleet_scale dynamic config, best of
    ``repeats`` runs each to suppress scheduler noise:

    * ``baseline`` — ``obs="off"``, the reference;
    * ``off`` — ``obs="off"`` again: same code path, so the measured
      "overhead" is the noise floor the ≤ 2% acceptance bound must
      absorb (off-mode adds only ``log.enabled`` attribute checks);
    * ``full`` — ring buffer + per-tick metrics sampling, bounded
      against ``off`` at ≤ 15%.
    """
    from repro.configs.base import AmoebaConfig, FleetConfig
    from repro.fleet import FleetEngine

    amoeba = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                          min_phase_steps=2)
    mean_len = 0.35 * 4 + 0.3 * 8 + 0.2 * 16 + 0.1 * 32 + 0.05 * 48
    horizon = max(int(n_requests * mean_len / (groups * capacity * 0.7)), 1)

    def best_tps(obs_mode: str) -> float:
        tps = []
        for _ in range(repeats):
            eng = FleetEngine(cfg, None, rt=rt, fleet=FleetConfig(
                num_groups=groups, capacity=capacity, window=64,
                amoeba=amoeba, engine="vec", mode="dynamic",
                router="least_loaded", obs=obs_mode))
            eng.submit(scale_trace(n_requests, groups, horizon, seed))
            s = eng.run()
            if s["completed"] != n_requests:
                raise RuntimeError(
                    f"obs={obs_mode}: completed {s['completed']} of "
                    f"{n_requests}")
            tps.append(s["ticks_per_sec"])
        return max(tps)

    baseline = best_tps("off")
    off = best_tps("off")
    full = best_tps("full")
    out = {
        "config": {"groups": groups, "capacity": capacity,
                   "n_requests": n_requests, "horizon": horizon,
                   "seed": seed, "repeats": repeats},
        "ticks_per_sec": {"baseline": baseline, "off": off, "full": full},
        "off_overhead_frac": round(1.0 - off / max(baseline, 1e-9), 4),
        "full_overhead_frac": round(1.0 - full / max(off, 1e-9), 4),
    }
    out["validation"] = {
        "off_within_2pct": out["off_overhead_frac"] <= 0.02,
        "full_within_15pct": out["full_overhead_frac"] <= 0.15,
    }
    print(f"obs overhead: baseline={baseline:.1f} off={off:.1f} "
          f"full={full:.1f} tps -> off {out['off_overhead_frac']:+.2%}, "
          f"full {out['full_overhead_frac']:+.2%}")
    return out


# -- suggest_split micro-benchmark ---------------------------------------------

def _legacy_counts(B, topo):
    """partition()'s per-part counts, pre-cache (recomputed every call)."""
    k = len(topo)
    if k <= 1 or B < 2:
        return (B,) + (0,) * max(k - 1, 0)
    C = sum(topo)
    quota = [B * s / C for s in topo]
    counts = [int(q) for q in quota]
    extras = B - sum(counts)
    by_frac = sorted(range(k), key=lambda i: (quota[i] - counts[i], i),
                     reverse=True)
    for i in by_frac[:extras]:
        counts[i] += 1
    if B <= C:
        for i in range(k):
            while counts[i] > topo[i]:
                j = min((m for m in range(k) if counts[m] < topo[m]),
                        key=lambda m: (abs(m - i), m))
                counts[j] += 1
                counts[i] -= 1
    if B >= k:
        for i in range(k):
            while counts[i] == 0:
                j = max(range(k), key=lambda m: (counts[m], -m))
                counts[j] -= 1
                counts[i] += 1
    return tuple(counts)


def _legacy_cost(sp, r, t, policy):
    """The O(parts x capacity) per-candidate evaluation: full re-sort +
    re-partition + fancy-indexed per-part max — the formulation the
    shared-ordering evaluator replaced."""
    import numpy as np

    from repro.core.regroup import POLICIES

    topo = sp.as_topology(t)
    idx = list(range(r.size))
    if len(topo) <= 1 or len(idx) < 2:
        parts = [idx] + [[] for _ in range(len(topo) - 1)]
    else:
        fast, slow = POLICIES[policy](idx, r)
        order = fast + slow
        parts, pos = [], 0
        for c in _legacy_counts(len(idx), topo):
            parts.append(order[pos:pos + c])
            pos += c
    return float(sum(s * r[np.asarray(p, np.int64)].max()
                     for s, p in zip(topo, parts) if len(p)))


def _legacy_suggest_improve(sp, cur, r, policy):
    c = sp.as_topology(cur)
    cands = [t for t in sp.split_moves(c) + sp.resize_moves(c)
             if len(t) <= r.size]
    if not cands:
        return None
    best = min(cands, key=lambda t: (_legacy_cost(sp, r, t, policy),
                                     len(t), t))
    if _legacy_cost(sp, r, best, policy) \
            < _legacy_cost(sp, r, c, policy) - 1e-12:
        return best
    return None


def suggest_split_microbench(capacity: int = 16, max_ways: int = 8,
                             trials: int = 200, seed: int = 0) -> Dict:
    """Legacy vs shipped candidate scan on identical inputs.

    Benchmarks ``suggest_improve`` from 1-5-part start topologies — the
    states the controller actually scans from, where the candidate set
    (every single-part cut plus every neighboring re-cut) is largest.
    """
    import numpy as np

    from repro.control import ConfigSpace

    sp = ConfigSpace(capacity=capacity, max_ways=max_ways, hetero=True)
    rng = np.random.default_rng(seed)
    starts = [t for t in sp.compositions() if len(t) <= 5]
    cases = [(starts[rng.integers(0, len(starts))],
              rng.integers(1, 60, capacity).astype(np.float64))
             for _ in range(trials)]
    for cur, r in cases[:20]:           # argmins must be identical
        assert sp.suggest_improve(cur, r) == _legacy_suggest_improve(
            sp, cur, r, "warp_regroup"), (cur, r)
    t0 = time.perf_counter()
    for cur, r in cases:
        _legacy_suggest_improve(sp, cur, r, "warp_regroup")
    legacy_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for cur, r in cases:
        sp.suggest_improve(cur, r)
    fast_s = time.perf_counter() - t0
    out = {"capacity": capacity, "max_ways": max_ways, "trials": trials,
           "bench": "suggest_improve from 1-5 part topologies",
           "legacy_us_per_call": round(legacy_s / trials * 1e6, 1),
           "fast_us_per_call": round(fast_s / trials * 1e6, 1),
           "speedup": round(legacy_s / max(fast_s, 1e-12), 1)}
    print(f"suggest_improve microbench (capacity={capacity}, "
          f"max_ways={max_ways}): legacy {out['legacy_us_per_call']}us "
          f"-> fast {out['fast_us_per_call']}us "
          f"({out['speedup']}x)")
    return out


def write_timing_sidecar(result: Dict, path: str = TIMING_OUT) -> None:
    """Compact wall-clock sidecar uploaded by CI next to the full artifact."""
    timing = {"validation": result["validation"],
              "per_variant_wall_s": {
                  k: result[k]["wall_s"] for k in
                  ("static_fused", "static_split", "dynamic_threshold")},
              "object_baseline": result["object_baseline"]}
    with open(path, "w") as f:
        json.dump(timing, f, indent=1)


def main() -> Dict:
    import sys
    sys.path.insert(0, os.path.join(ROOT, "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=100)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--requests", type=int, default=100_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if the vec sweep exceeds this wall budget")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail below this vec/object ticks-per-sec ratio")
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--timing-out", default=TIMING_OUT)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = get_config("qwen3-14b", reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    rt = T.Runtime(production=False, remat=False)

    print(f"== fleet_scale sweep ({args.groups} groups x "
          f"{args.requests:,} requests) ==")
    result = fleet_scale_sweep(
        cfg, params, rt, groups=args.groups, capacity=args.capacity,
        n_requests=args.requests, seed=args.seed,
        budget_s=args.budget_s, min_speedup=args.min_speedup)
    result["suggest_split_microbench"] = suggest_split_microbench()

    # merge into the shared artifact rather than clobbering other sweeps
    merged = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            merged = json.load(f)
    merged["fleet_scale"] = result
    with open(args.out, "w") as f:
        json.dump(merged, f, indent=1)
    write_timing_sidecar(result, args.timing_out)
    print(f"wrote {os.path.abspath(args.out)} and "
          f"{os.path.abspath(args.timing_out)}")
    return result


if __name__ == "__main__":
    main()
