"""Mesh-level AMOEBA (beyond-paper): plan selection + serving regrouping.

Two demonstrations of the paper's mechanism operating on the TPU fleet:

1. **Plan selection** — for cells with fused/scale_out plan dry-runs, the
   controller compares compiled rooflines and picks the plan; reports the
   step-time delta vs always-base (the mesh translation of Fig 12's
   static_fuse-vs-baseline comparison).

2. **Serving regroup** — the real engine on a reduced model: fused
   baseline vs direct_split vs warp_regroup on a long-tail decode trace
   (the mesh translation of Figs 12/19 dynamics).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

import numpy as np

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def plan_selection() -> Dict:
    """Compare base/fused/scale_out artifacts where available."""
    from repro.configs.base import AmoebaConfig
    from repro.core.controller import AmoebaController
    from repro.core.metrics import StepProfile

    # single-pod plan family only: base 16x16 vs 32x8 / 8x32 refactorings
    # of the same 256 chips (multi-pod artifacts are a different fleet)
    single_pod = ("16x16", "32x8_scale_out", "8x32_fused")
    cells: Dict[str, Dict[str, dict]] = {}
    for path in glob.glob(os.path.join(ART_DIR, "*.json")):
        with open(path) as f:
            a = json.load(f)
        if a.get("skipped") or a["mesh"] not in single_pod:
            continue
        key = f"{a['arch']}/{a['shape']}"
        cells.setdefault(key, {})[a.get("plan", "base")] = a

    ctl = AmoebaController(AmoebaConfig())
    out = {}
    for key, plans in sorted(cells.items()):
        if len(plans) < 2:
            continue
        profiles = {}
        for plan, a in plans.items():
            profiles[plan] = StepProfile(
                name=key, flops=a["flops_per_device"],
                hbm_bytes=a["hbm_bytes_per_device"],
                coll_bytes=a["collective_bytes_per_device"],
                chips=a["chips"], model_flops=a["model_flops"])
        d = ctl.choose_plan(profiles, param_bytes_per_chip=1e8,
                            steps_remaining=1e5)
        base_s = profiles["base"].roofline()["step_s"]
        best_s = profiles[d.plan].roofline()["step_s"]
        out[key] = {"chosen": d.plan, "reason": d.reason,
                    "base_step_s": base_s, "chosen_step_s": best_s,
                    "speedup": base_s / best_s if best_s else 1.0}
        print(f"{key:40s} -> {d.plan:10s} step {base_s:.3g}s -> {best_s:.3g}s"
              f" ({out[key]['speedup']:.2f}x)")
    if not out:
        print("no multi-plan artifacts yet (run dryrun --plan fused / "
              "--plan scale_out on chosen cells)")
    return out


def serving_regroup(requests: int = 24, capacity: int = 8,
                    seed: int = 0) -> Dict:
    import jax

    from repro.configs import get_config
    from repro.configs.base import AmoebaConfig
    from repro.models import transformer as T
    from repro.serve import Request, ServeEngine

    cfg = get_config("qwen3-14b", reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)

    def mk():
        # long-tail decode lengths: most requests short, a few dominate the
        # batch critical path — the divergence regime the paper targets
        rng = np.random.default_rng(seed)
        return [Request(i, list(map(int, rng.integers(
            0, cfg.vocab_size, int(rng.choice([8, 16]))))),
            int(rng.choice([3, 40], p=[0.72, 0.28])))
            for i in range(requests)]

    out = {}
    for name, dyn, pol in [("fused_baseline", False, "warp_regroup"),
                           ("direct_split", True, "direct_split"),
                           ("warp_regroup", True, "warp_regroup")]:
        eng = ServeEngine(cfg, params, amoeba=AmoebaConfig(
            regroup_policy=pol, split_threshold=0.3, fuse_threshold=0.05,
            min_phase_steps=2), capacity=capacity)
        eng.submit(mk())
        st = eng.run(dynamic=dyn)
        out[name] = {"ticks": st.ticks, "slot_steps": st.slot_steps,
                     "efficiency": round(st.efficiency, 4),
                     "splits": st.splits, "fuses": st.fuses,
                     "completed": st.completed}
    base = out["fused_baseline"]["efficiency"]
    for k in out:
        out[k]["vs_fused"] = round(out[k]["efficiency"] / max(base, 1e-9), 3)
    print(json.dumps(out, indent=1))
    return out
