"""Fleet-scale AMOEBA benchmark: static configurations, dynamic, policies.

Two chip-level sweeps over one bursty long-tail trace, the serving
translation of Fig 12:

**Mode sweep** — the three chip configurations the paper compares:

* ``static_fused``   — every pair permanently fused (big-SM-only chip),
* ``static_split``   — every pair permanently split (small-SM-only chip),
* ``amoeba_dynamic`` — every pair free to split/fuse on its own
  divergence signal, with length-aware routing onto the resulting
  heterogeneous mix.

**Policy sweep** — all-dynamic fleets differing only in the
``repro.control`` decision stack:

* ``threshold`` — fixed-ratio hysteresis (the paper's Fig 10/11 rule),
* ``predictor`` — §4.1.3's logistic model over live telemetry,
* ``online``    — predictor with periodic refits from the replay buffer,
* ``oracle``    — true slot-cost argmax: the upper bound.

All runs replay byte-identical traces (same seed) and share one compiled
decode, so differences are purely scheduling.  Results (slot-step
efficiency, p50/p95/p99 request latency, throughput, churn, utilization)
go to ``BENCH_fleet.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.run fleet
    PYTHONPATH=src python benchmarks/fleet_bench.py --quick   # CI smoke
"""
from __future__ import annotations

import json
import os
from typing import Dict

ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT = os.path.join(ROOT, "BENCH_fleet.json")


def fleet_bench(groups: int = 4, capacity: int = 8, horizon: int = 120,
                seed: int = 0, out_path: str = OUT) -> Dict:
    import jax

    from repro.configs import get_config
    from repro.configs.base import AmoebaConfig
    from repro.control import train_serve_predictor
    from repro.fleet import (bursty_longtail_trace, replay_modes,
                             replay_policies)
    from repro.models import transformer as T

    cfg = get_config("qwen3-14b", reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    rt = T.Runtime(production=False, remat=False)
    amoeba = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                          min_phase_steps=2)
    trace_factory = lambda: bursty_longtail_trace(
        horizon=horizon, vocab_size=cfg.vocab_size, seed=seed)

    # the policy sweep runs the full k-way topology ladder (1x8/2x4/4x2
    # for capacity 8) — the learned policies' edge over the fixed-ratio
    # rule comes precisely from knowing when the deeper splits pay
    ladder = amoeba.replace(max_ways=4 if capacity >= 4 else 2)
    out: Dict = {"config": {"groups": groups, "capacity": capacity,
                            "horizon": horizon, "seed": seed,
                            "trace": "bursty_longtail",
                            "policy_sweep_max_ways": ladder.max_ways}}

    print("== mode sweep (Fig 12 chip configurations) ==")
    out.update(replay_modes(cfg, params, rt, trace_factory,
                            groups=groups, capacity=capacity, amoeba=amoeba))

    print("\n== policy sweep (repro.control decision stacks) ==")
    model, minfo = train_serve_predictor(capacity=capacity,
                                         max_ways=ladder.max_ways,
                                         label_margin=ladder.label_margin)
    pol = replay_policies(cfg, params, rt, trace_factory,
                          groups=groups, capacity=capacity, amoeba=ladder,
                          model=model)
    out["policies"] = pol
    # sibling key, not inside "policies": keeps that mapping homogeneous
    # (one run summary per policy name) for downstream consumers
    out["predictor_model"] = {
        "train_accuracy": round(minfo["train_accuracy"], 4),
        "n": minfo["n"],
        "final_nll": round(minfo["final_nll"], 5),
    }

    dyn, fus = out["amoeba_dynamic"], out["static_fused"]
    thr = pol["threshold"]
    learned = {n: pol[n] for n in ("predictor", "online") if n in pol}
    best_learned = min(
        learned, key=lambda n: (learned[n]["latency"]["p99"],
                                -learned[n]["efficiency"]))
    bl = learned[best_learned]
    out["validation"] = {
        "p99_speedup_vs_fused": round(
            fus["latency"]["p99"] / max(dyn["latency"]["p99"], 1e-9), 3),
        "efficiency_gain_vs_fused": round(
            dyn["efficiency"] / max(fus["efficiency"], 1e-9), 3),
        "dynamic_beats_fused": bool(
            dyn["latency"]["p99"] < fus["latency"]["p99"]
            and dyn["efficiency"] > fus["efficiency"]),
        # policy sweep: a learned policy must beat the threshold rule on
        # p99 latency or efficiency; the oracle is the upper bound
        "best_learned_policy": best_learned,
        "learned_p99_speedup_vs_threshold": round(
            thr["latency"]["p99"] / max(bl["latency"]["p99"], 1e-9), 3),
        "learned_efficiency_gain_vs_threshold": round(
            bl["efficiency"] / max(thr["efficiency"], 1e-9), 3),
        "learned_beats_threshold": bool(
            bl["latency"]["p99"] < thr["latency"]["p99"]
            or bl["efficiency"] > thr["efficiency"]),
        "oracle_p99": pol["oracle"]["latency"]["p99"],
        "oracle_efficiency": pol["oracle"]["efficiency"],
    }
    v = out["validation"]
    print(f"\nAMOEBA-dynamic vs static-fused: "
          f"p99 {v['p99_speedup_vs_fused']:.2f}x, "
          f"efficiency {v['efficiency_gain_vs_fused']:.2f}x, "
          f"wins both: {v['dynamic_beats_fused']}")
    print(f"{best_learned} vs threshold: "
          f"p99 {v['learned_p99_speedup_vs_threshold']:.2f}x, "
          f"efficiency {v['learned_efficiency_gain_vs_threshold']:.2f}x, "
          f"wins either: {v['learned_beats_threshold']} "
          f"(oracle bound: p99={v['oracle_p99']:.1f}, "
          f"eff={v['oracle_efficiency']:.3f})")
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {os.path.abspath(out_path)}")
    return out


if __name__ == "__main__":
    import argparse
    import sys
    sys.path.insert(0, os.path.join(ROOT, "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small fleet, short trace")
    args = ap.parse_args()
    if args.quick:
        args.groups, args.capacity, args.horizon = 2, 4, 40
    fleet_bench(groups=args.groups, capacity=args.capacity,
                horizon=args.horizon, seed=args.seed, out_path=args.out)
