"""Fleet-scale AMOEBA benchmark: static configurations vs dynamic.

The chip-level translation of Fig 12: a ≥4-group serving fleet replays
one bursty long-tail trace under the three chip configurations the paper
compares —

* ``static_fused``   — every pair permanently fused (big-SM-only chip),
* ``static_split``   — every pair permanently split (small-SM-only chip),
* ``amoeba_dynamic`` — every pair free to split/fuse on its own
  divergence signal, with length-aware routing onto the resulting
  heterogeneous mix.

All three replay byte-identical traces (same seed) and share one compiled
decode, so differences are purely scheduling.  Results (slot-step
efficiency, p50/p95/p99 request latency, throughput, churn, utilization)
go to ``BENCH_fleet.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.run fleet
"""
from __future__ import annotations

import json
import os
from typing import Dict

ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT = os.path.join(ROOT, "BENCH_fleet.json")


def fleet_bench(groups: int = 4, capacity: int = 8, horizon: int = 120,
                seed: int = 0) -> Dict:
    import jax

    from repro.configs import get_config
    from repro.configs.base import AmoebaConfig
    from repro.fleet import bursty_longtail_trace, replay_modes
    from repro.models import transformer as T

    cfg = get_config("qwen3-14b", reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    rt = T.Runtime(production=False, remat=False)

    out: Dict = {"config": {"groups": groups, "capacity": capacity,
                            "horizon": horizon, "seed": seed,
                            "trace": "bursty_longtail"}}
    out.update(replay_modes(
        cfg, params, rt,
        lambda: bursty_longtail_trace(horizon=horizon,
                                      vocab_size=cfg.vocab_size, seed=seed),
        groups=groups, capacity=capacity,
        amoeba=AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                            min_phase_steps=2)))

    dyn, fus = out["amoeba_dynamic"], out["static_fused"]
    out["validation"] = {
        "p99_speedup_vs_fused": round(
            fus["latency"]["p99"] / max(dyn["latency"]["p99"], 1e-9), 3),
        "efficiency_gain_vs_fused": round(
            dyn["efficiency"] / max(fus["efficiency"], 1e-9), 3),
        "dynamic_beats_fused": bool(
            dyn["latency"]["p99"] < fus["latency"]["p99"]
            and dyn["efficiency"] > fus["efficiency"]),
    }
    v = out["validation"]
    print(f"\nAMOEBA-dynamic vs static-fused: "
          f"p99 {v['p99_speedup_vs_fused']:.2f}x, "
          f"efficiency {v['efficiency_gain_vs_fused']:.2f}x, "
          f"wins both: {v['dynamic_beats_fused']}")
    with open(OUT, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {os.path.abspath(OUT)}")
    return out


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(ROOT, "src"))
    fleet_bench()
