"""Fleet-scale AMOEBA benchmark: static configurations, dynamic, policies.

Three chip-level sweeps, the serving translation of Fig 12:

**Mode sweep** — the three chip configurations the paper compares:

* ``static_fused``   — every pair permanently fused (big-SM-only chip),
* ``static_split``   — every pair permanently split (small-SM-only chip),
* ``amoeba_dynamic`` — every pair free to split/fuse on its own
  divergence signal, with length-aware routing onto the resulting
  heterogeneous mix.

**Policy sweep** — all-dynamic fleets differing only in the
``repro.control`` decision stack:

* ``threshold`` — fixed-ratio hysteresis (the paper's Fig 10/11 rule),
* ``predictor`` — §4.1.3's logistic model over live telemetry,
* ``online``    — predictor with periodic refits from the replay buffer,
* ``oracle``    — true slot-cost argmax: the upper bound.

**Composition sweep** — the heterogeneous-topology headline (§5,
Fig 12): identical all-dynamic oracle fleets on a *skewed* long-tail
trace, differing only in the topology space — the balanced equal-ways
ladders (2-way, 4-way) vs the full composition lattice with per-part
moves (``(5, 3)``-style cuts).  Validation records whether
heterogeneous topologies beat the best equal ladder on p99 latency or
slot efficiency, plus the compositions actually visited.

**Work-stealing sweep** — the chip-level migration subsystem
(``repro.fleet.migrate``): identical shard-skewed traces
(``imbalanced_trace`` — one hot router shard hammers one group under
sticky routing) replayed with cross-group stealing disabled and
enabled at equal capacity.  Validation records the p99 speedup and the
steal/live-migration/stall counters.

**Slack-lease sweep** — the sub-reconfiguration capacity-sharing tier
(``repro.fleet.lease``): a rotating transient-burst trace (hot phases
too brief for a topology change to amortize) replayed with
reconfiguration only, with work stealing, and with slack leases on top
of stealing.  Validation pins the lease p99 against steal-only and the
zero-stall contract (no reconfig stall is ever attributable to a
lease grant).

All runs replay byte-identical traces (same seed) and share one compiled
decode, so differences are purely scheduling.  Results (slot-step
efficiency, p50/p95/p99 request latency, throughput, churn, utilization,
the Fig 20 per-feature ablation of the serve predictor) go to
``BENCH_fleet.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.run fleet
    PYTHONPATH=src python benchmarks/fleet_bench.py --quick   # CI smoke
"""
from __future__ import annotations

import json
import os
from typing import Dict

ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT = os.path.join(ROOT, "BENCH_fleet.json")


def composition_sweep(cfg, params, rt, decode, *, groups: int,
                      capacity: int, horizon: int, seed: int) -> Dict:
    """Equal-ways ladders vs the heterogeneous composition lattice.

    Every run is an all-dynamic oracle fleet (the policy variable is
    pinned to the upper bound so the only difference is the *topology
    space*) replaying one skewed long-tail trace.
    """
    from repro.configs.base import AmoebaConfig, FleetConfig
    from repro.fleet import FleetEngine, skewed_longtail_trace

    base = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                        min_phase_steps=2, policy="oracle")
    variants = {"equal_2way": base.replace(hetero=False, max_ways=2)}
    if capacity >= 4:
        variants["equal_4way"] = base.replace(hetero=False, max_ways=4)
    variants["hetero"] = base.replace(hetero=True,
                                      max_ways=min(capacity, 8))
    out: Dict = {}
    for label, amoeba in variants.items():
        trace = skewed_longtail_trace(horizon=horizon,
                                      vocab_size=cfg.vocab_size, seed=seed)
        eng = FleetEngine(cfg, params, rt=rt, decode_fn=decode,
                          fleet=FleetConfig(
                              num_groups=groups, capacity=capacity,
                              router="length_aware", mode="dynamic",
                              amoeba=amoeba))
        eng.submit(trace)
        s = eng.run()
        if s["completed"] != len(trace):
            raise RuntimeError(f"{label}: completed {s['completed']} of "
                               f"{len(trace)} requests")
        out[label] = s
        lat = s["latency"]
        print(f"{label:12s} ticks={s['wall_ticks']:4d} "
              f"eff={s['efficiency']:.3f} p50={lat['p50']:5.1f} "
              f"p99={lat['p99']:5.1f} "
              f"hetero_topos={s['control'].get('hetero_topologies_visited', 0)}")
    equal = {k: v for k, v in out.items() if k.startswith("equal")}
    best_equal = min(equal, key=lambda k: (equal[k]["latency"]["p99"],
                                           -equal[k]["efficiency"]))
    be, he = out[best_equal], out["hetero"]
    out["validation"] = {
        "best_equal_ladder": best_equal,
        "hetero_p99_speedup_vs_equal": round(
            be["latency"]["p99"] / max(he["latency"]["p99"], 1e-9), 3),
        "hetero_efficiency_gain_vs_equal": round(
            he["efficiency"] / max(be["efficiency"], 1e-9), 3),
        "hetero_beats_equal": bool(
            he["latency"]["p99"] < be["latency"]["p99"]
            or he["efficiency"] > be["efficiency"]),
        "hetero_topologies_visited": he["control"].get(
            "topologies_visited", []),
    }
    return out


def work_stealing_sweep(cfg, params, rt, decode, *, groups: int,
                        capacity: int, horizon: int, seed: int,
                        trace_out: str = None) -> Dict:
    """Cross-group work stealing on a shard-skewed trace, on vs off.

    Both runs use sticky (shard-affinity) routing on the imbalanced
    trace — one hot shard hammers one group while the rest starve —
    at equal capacity; the only difference is whether the
    ``repro.fleet.migrate`` planner may steal queued requests (and
    live-migrate KV-costed tails) across groups.
    """
    from repro.configs.base import AmoebaConfig, FleetConfig, MigrationConfig
    from repro.fleet import FleetEngine, imbalanced_trace

    amoeba = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                          min_phase_steps=2)
    variants = {"no_stealing": MigrationConfig(enabled=False),
                "stealing": MigrationConfig(enabled=True)}
    out: Dict = {}
    for label, mig in variants.items():
        trace = imbalanced_trace(horizon=horizon, vocab_size=cfg.vocab_size,
                                 seed=seed, shards=groups)
        # the stealing run carries the full event stream when a trace
        # path was requested (repro.obs) — steals/reconfigs/decisions
        # land in the exported JSONL the CI round-trip check consumes
        obs_mode = "full" if trace_out and label == "stealing" else "off"
        eng = FleetEngine(cfg, params, rt=rt, decode_fn=decode,
                          fleet=FleetConfig(
                              num_groups=groups, capacity=capacity,
                              router="sticky", mode="dynamic",
                              rebalance_every=4, migrate=mig,
                              amoeba=amoeba, obs=obs_mode))
        eng.submit(trace)
        s = eng.run()
        if obs_mode == "full":
            from repro.obs import write_jsonl
            n_ev = write_jsonl(trace_out, eng.obs.events(),
                               meta=eng.obs.meta)
            print(f"wrote {n_ev} events to {os.path.abspath(trace_out)}")
        if s["completed"] != len(trace):
            raise RuntimeError(f"{label}: completed {s['completed']} of "
                               f"{len(trace)} requests")
        out[label] = s
        lat = s["latency"]
        mig_s = s.get("migration", {})
        print(f"{label:12s} ticks={s['wall_ticks']:4d} "
              f"p50={lat['p50']:5.1f} p99={lat['p99']:5.1f} "
              f"steals={mig_s.get('steals', 0)} "
              f"live={mig_s.get('live_migrations', 0)} "
              f"stall={mig_s.get('stall_ticks', 0)}")
    off, on = out["no_stealing"], out["stealing"]
    mig_s = on.get("migration", {})
    out["validation"] = {
        "steal_p99_speedup": round(
            off["latency"]["p99"] / max(on["latency"]["p99"], 1e-9), 3),
        "stealing_beats_no_stealing": bool(
            on["latency"]["p99"] < off["latency"]["p99"]),
        "steals": mig_s.get("steals", 0),
        "live_migrations": mig_s.get("live_migrations", 0),
        "stall_ticks": mig_s.get("stall_ticks", 0),
        "rejected_amortization": mig_s.get("rejected_amortization", 0),
    }
    return out


def slack_lease_sweep(cfg, params, rt, decode, *, groups: int,
                      capacity: int, horizon: int, seed: int) -> Dict:
    """Slack leases vs stealing vs re-cutting on a transient burst.

    The transient-burst trace rotates a short hot phase across shards —
    bursts too brief for a topology change to amortize, which is exactly
    the gap the lease planner fills.  Three identical-capacity sticky
    fleets replay the same trace:

    * ``reconfig_only`` — dynamic split/fuse is the only adaptation,
    * ``steal_only``    — plus cross-group work stealing,
    * ``lease``         — plus slack leases on top of stealing.

    Validation pins the tentpole contract: leases grant, the lease p99
    is no worse than steal-only, and not one reconfig stall tick is ever
    attributable to a lease grant.
    """
    from repro.configs.base import (AmoebaConfig, FleetConfig, LeaseConfig,
                                    MigrationConfig)
    from repro.fleet import FleetEngine, transient_burst_trace

    # a realistic dwell clock: the topology layer holds each phase long
    # enough that a burst_len-tick burst is gone before a re-cut can
    # amortize — the regime the lease tier exists for
    amoeba = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                          min_phase_steps=8)
    burst_len = max(6, horizon // (2 * groups))
    variants = {
        "reconfig_only": (MigrationConfig(enabled=False),
                          LeaseConfig(enabled=False)),
        "steal_only": (MigrationConfig(enabled=True),
                       LeaseConfig(enabled=False)),
        "lease": (MigrationConfig(enabled=True), LeaseConfig(enabled=True)),
    }
    out: Dict = {}
    for label, (mig, lease) in variants.items():
        trace = transient_burst_trace(horizon=horizon,
                                      vocab_size=cfg.vocab_size,
                                      seed=seed, shards=groups,
                                      burst_len=burst_len)
        eng = FleetEngine(cfg, params, rt=rt, decode_fn=decode,
                          fleet=FleetConfig(
                              num_groups=groups, capacity=capacity,
                              router="sticky", mode="dynamic",
                              rebalance_every=4, migrate=mig,
                              lease=lease, amoeba=amoeba))
        eng.submit(trace)
        s = eng.run()
        if s["completed"] != len(trace):
            raise RuntimeError(f"{label}: completed {s['completed']} of "
                               f"{len(trace)} requests")
        out[label] = s
        lat = s["latency"]
        ls = s.get("lease", {})
        print(f"{label:14s} ticks={s['wall_ticks']:4d} "
              f"p50={lat['p50']:5.1f} p99={lat['p99']:5.1f} "
              f"grants={ls.get('grants', 0)} "
              f"revokes={ls.get('revokes', 0)} "
              f"expires={ls.get('expires', 0)} "
              f"slot_ticks_lent={ls.get('slot_ticks_lent', 0)}")
    rec, steal, lea = out["reconfig_only"], out["steal_only"], out["lease"]
    ls = lea["lease"]
    out["validation"] = {
        "lease_p99_speedup_vs_steal_only": round(
            steal["latency"]["p99"] / max(lea["latency"]["p99"], 1e-9), 3),
        "lease_p99_speedup_vs_reconfig_only": round(
            rec["latency"]["p99"] / max(lea["latency"]["p99"], 1e-9), 3),
        "lease_no_worse_than_steal_only": bool(
            lea["latency"]["p99"] <= steal["latency"]["p99"]),
        "lease_p50_speedup_vs_steal_only": round(
            steal["latency"]["p50"] / max(lea["latency"]["p50"], 1e-9), 3),
        "grants": ls["grants"],
        "revokes": ls["revokes"],
        "expires": ls["expires"],
        "slot_ticks_lent": ls["slot_ticks_lent"],
        "rejected_amortization": ls["rejected_amortization"],
        # the zero-stall contract: a lease is pure bookkeeping — no
        # topology move, no dwell clock, no reconfig stall, ever
        "lease_stall_ticks_charged": ls["stall_ticks_charged"],
        "zero_stall_contract_holds": bool(ls["stall_ticks_charged"] == 0),
        "leases_granted_and_returned": bool(
            ls["grants"] > 0
            and ls["grants"] == ls["revokes"] + ls["expires"]
            and ls["active"] == 0),
    }
    return out


def cluster_hierarchy_sweep(cfg, params, rt, decode, *, capacity: int,
                            horizon: int, seed: int, chips: int = 2,
                            groups_per_chip: int = 2) -> Dict:
    """Hierarchical vs distance-blind control on a 2D chip mesh.

    Both runs drive the same multi-chip imbalanced trace (one hot chip
    bursts fat-tailed work while the others trickle) through identical
    capacity on the same tiered physics — slow, high-latency inter-chip
    links under a near-free NoC.  The only difference is the planner's
    *cost model*: ``hierarchical`` plans chip-first and authorizes
    crossings only when the tiered cost amortizes, while ``flat_blind``
    (``ClusterConfig.distance_blind``) plans over one flat pool as if
    every pair were NoC-close — and execution charges it the physical
    prices anyway, which is how blind stealing thrashes slow links.  A
    third run re-prices the inter-chip tiers at zero bandwidth to pin
    the veto contract: every cross-chip move is refused while intra-chip
    migration keeps flowing.
    """
    from repro.configs.base import (AmoebaConfig, ClusterConfig, FleetConfig,
                                    MigrationConfig)
    from repro.cluster import ClusterEngine
    from repro.fleet import multichip_imbalanced_trace

    groups = chips * groups_per_chip
    amoeba = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                          min_phase_steps=2)
    mig = MigrationConfig(enabled=True, live=True)
    # slow high-latency links under a near-free NoC — the regime where
    # ignoring geometry costs the most — with enough cross-steal budget
    # that the amortization bar, not the cap, separates the two planners
    tiers = ClusterConfig(groups_per_chip=groups_per_chip,
                          noc_bandwidth=4e9, noc_latency=0.0,
                          link_bandwidth=256.0, link_latency=12.0,
                          net_bandwidth=64.0, net_latency=24.0,
                          max_cross_steals=4)
    variants = {"flat_blind": tiers.replace(distance_blind=True),
                "hierarchical": tiers,
                "zero_interchip": tiers.replace(link_bandwidth=0.0,
                                                net_bandwidth=0.0)}
    out: Dict = {"config": {"chips": chips,
                            "groups_per_chip": groups_per_chip,
                            "capacity": capacity,
                            "link_bandwidth": tiers.link_bandwidth,
                            "link_latency": tiers.link_latency}}
    for label, ccfg in variants.items():
        trace = multichip_imbalanced_trace(
            horizon=horizon, vocab_size=cfg.vocab_size, seed=seed,
            chips=chips, groups_per_chip=groups_per_chip)
        eng = ClusterEngine(cfg, params, rt=rt, decode_fn=decode,
                            fleet=FleetConfig(
                                num_groups=groups, capacity=capacity,
                                router="sticky", mode="dynamic",
                                rebalance_every=4, migrate=mig,
                                amoeba=amoeba, cluster=ccfg))
        eng.submit(trace)
        s = eng.run()
        if s["completed"] != len(trace):
            raise RuntimeError(f"{label}: completed {s['completed']} of "
                               f"{len(trace)} requests")
        out[label] = s
        lat, m = s["latency"], s["migration"]
        print(f"{label:14s} ticks={s['wall_ticks']:4d} "
              f"p50={lat['p50']:5.1f} p99={lat['p99']:5.1f} "
              f"steals={m['steals']} (noc={m['intra_chip_steals']} "
              f"x={m['cross_chip_steals']}) "
              f"live={m['live_migrations']} (noc={m['intra_chip_live']} "
              f"x={m['cross_chip_live']}) "
              f"vetoed={m['vetoed_cross_chip']} "
              f"link_stall={s['cluster']['tier_stall_ticks']['link']}")
    flat, hier = out["flat_blind"], out["hierarchical"]
    zero = out["zero_interchip"]
    zm = zero["migration"]
    out["validation"] = {
        "hierarchical_p99_speedup_vs_flat": round(
            flat["latency"]["p99"] / max(hier["latency"]["p99"], 1e-9), 3),
        "hierarchical_beats_flat": bool(
            hier["latency"]["p99"] <= flat["latency"]["p99"]),
        "flat_interchip_stall_ticks":
            flat["cluster"]["tier_stall_ticks"]["link"]
            + flat["cluster"]["tier_stall_ticks"]["net"],
        "hier_interchip_stall_ticks":
            hier["cluster"]["tier_stall_ticks"]["link"]
            + hier["cluster"]["tier_stall_ticks"]["net"],
        "hier_cross_chip_steals": hier["migration"]["cross_chip_steals"],
        "hier_vetoed_cross_chip": hier["migration"]["vetoed_cross_chip"],
        # the veto contract: dead inter-chip tiers stop every crossing
        # while the NoC keeps migrating
        "zero_bw_cross_moves": zm["cross_chip_steals"]
            + zm["cross_chip_live"],
        "zero_bw_intra_moves": zm["intra_chip_steals"]
            + zm["intra_chip_live"],
        "zero_bw_vetoes_crossings_intra_flows": bool(
            zm["cross_chip_steals"] + zm["cross_chip_live"] == 0
            and zm["intra_chip_steals"] + zm["intra_chip_live"] > 0),
    }
    return out


def fleet_bench(groups: int = 4, capacity: int = 8, horizon: int = 120,
                seed: int = 0, out_path: str = OUT,
                scale_groups: int = 100,
                scale_requests: int = 100_000,
                trace_out: str = None) -> Dict:
    import jax

    from repro.configs import get_config
    from repro.configs.base import AmoebaConfig
    from repro.control import (build_serve_corpus, serve_feature_ablation,
                               train_serve_predictor)
    from repro.fleet import (bursty_longtail_trace, replay_modes,
                             replay_policies)
    from repro.models import transformer as T
    from repro.serve.engine import make_decode_fn

    cfg = get_config("qwen3-14b", reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    rt = T.Runtime(production=False, remat=False)
    amoeba = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                          min_phase_steps=2)
    trace_factory = lambda: bursty_longtail_trace(
        horizon=horizon, vocab_size=cfg.vocab_size, seed=seed)

    # the policy sweep runs the full k-way topology ladder (1x8/2x4/4x2
    # for capacity 8) — the learned policies' edge over the fixed-ratio
    # rule comes precisely from knowing when the deeper splits pay
    ladder = amoeba.replace(max_ways=4 if capacity >= 4 else 2)
    out: Dict = {"config": {"groups": groups, "capacity": capacity,
                            "horizon": horizon, "seed": seed,
                            "trace": "bursty_longtail",
                            "policy_sweep_max_ways": ladder.max_ways}}

    print("== mode sweep (Fig 12 chip configurations) ==")
    out.update(replay_modes(cfg, params, rt, trace_factory,
                            groups=groups, capacity=capacity, amoeba=amoeba))

    print("\n== policy sweep (repro.control decision stacks) ==")
    model, minfo = train_serve_predictor(capacity=capacity,
                                         max_ways=ladder.max_ways,
                                         label_margin=ladder.label_margin)
    pol = replay_policies(cfg, params, rt, trace_factory,
                          groups=groups, capacity=capacity, amoeba=ladder,
                          model=model)
    out["policies"] = pol
    # the Fig 20 ablation: which serve feature carries the decision?
    Xc, yc = build_serve_corpus(n_samples=512, capacity=capacity,
                                max_ways=ladder.max_ways,
                                label_margin=ladder.label_margin)
    ablation = serve_feature_ablation(model, Xc, yc, steps=250)
    # sibling key, not inside "policies": keeps that mapping homogeneous
    # (one run summary per policy name) for downstream consumers
    out["predictor_model"] = {
        "train_accuracy": round(minfo["train_accuracy"], 4),
        "n": minfo["n"],
        "final_nll": round(minfo["final_nll"], 5),
        "feature_ablation": ablation,
    }
    top_feat = max(ablation, key=lambda k: ablation[k]["mean_abs_impact"])
    print("fig20 ablation: " + "  ".join(
        f"{k}={v['mean_abs_impact']:.2f}" for k, v in ablation.items())
        + f"  (dominant: {top_feat})")

    # drop compiled executables between sweeps: the accumulated jitted
    # shapes from dozens of engine replays can exhaust the CPU JIT's
    # mmap budget in one long-lived process (LLVM "Cannot allocate
    # memory"); each sweep recompiles what it needs
    jax.clear_caches()
    print("\n== composition sweep (heterogeneous vs equal ladders) ==")
    decode = make_decode_fn(cfg, rt)
    out["composition_sweep"] = composition_sweep(
        cfg, params, rt, decode, groups=groups,
        capacity=capacity, horizon=horizon, seed=seed)

    jax.clear_caches()
    print("\n== work-stealing sweep (imbalanced trace, sticky routing) ==")
    out["work_stealing"] = work_stealing_sweep(
        cfg, params, rt, decode, groups=groups,
        capacity=capacity, horizon=horizon, seed=seed,
        trace_out=trace_out)

    jax.clear_caches()
    print("\n== slack lease sweep (transient bursts, sticky routing) ==")
    out["slack_lease"] = slack_lease_sweep(
        cfg, params, rt, decode, groups=groups,
        capacity=capacity, horizon=horizon, seed=seed)

    jax.clear_caches()
    print("\n== cluster hierarchy sweep (2D mesh, tiered links) ==")
    out["cluster_hierarchy"] = cluster_hierarchy_sweep(
        cfg, params, rt, decode, capacity=capacity,
        horizon=horizon, seed=seed)

    jax.clear_caches()
    print(f"\n== fleet_scale sweep ({scale_groups} groups x "
          f"{scale_requests:,} requests, vec engine) ==")
    try:                                    # package vs direct execution
        from benchmarks.fleet_scale_bench import (fleet_scale_sweep,
                                                  obs_overhead_sweep,
                                                  suggest_split_microbench,
                                                  write_timing_sidecar)
    except ImportError:
        from fleet_scale_bench import (fleet_scale_sweep,
                                       obs_overhead_sweep,
                                       suggest_split_microbench,
                                       write_timing_sidecar)
    out["fleet_scale"] = fleet_scale_sweep(
        cfg, params, rt, groups=scale_groups, capacity=capacity,
        n_requests=scale_requests, seed=seed, decode=decode)
    out["fleet_scale"]["suggest_split_microbench"] = \
        suggest_split_microbench()
    write_timing_sidecar(out["fleet_scale"])

    print("\n== obs overhead microbench (event stream off/summary/full) ==")
    out["obs_overhead"] = obs_overhead_sweep(
        cfg, rt, groups=min(scale_groups, 20), capacity=capacity,
        n_requests=min(scale_requests, 20_000), seed=seed)

    dyn, fus = out["amoeba_dynamic"], out["static_fused"]
    thr = pol["threshold"]
    learned = {n: pol[n] for n in ("predictor", "online") if n in pol}
    best_learned = min(
        learned, key=lambda n: (learned[n]["latency"]["p99"],
                                -learned[n]["efficiency"]))
    bl = learned[best_learned]
    out["validation"] = {
        "p99_speedup_vs_fused": round(
            fus["latency"]["p99"] / max(dyn["latency"]["p99"], 1e-9), 3),
        "efficiency_gain_vs_fused": round(
            dyn["efficiency"] / max(fus["efficiency"], 1e-9), 3),
        "dynamic_beats_fused": bool(
            dyn["latency"]["p99"] < fus["latency"]["p99"]
            and dyn["efficiency"] > fus["efficiency"]),
        # policy sweep: a learned policy must beat the threshold rule on
        # p99 latency or efficiency; the oracle is the upper bound
        "best_learned_policy": best_learned,
        "learned_p99_speedup_vs_threshold": round(
            thr["latency"]["p99"] / max(bl["latency"]["p99"], 1e-9), 3),
        "learned_efficiency_gain_vs_threshold": round(
            bl["efficiency"] / max(thr["efficiency"], 1e-9), 3),
        "learned_beats_threshold": bool(
            bl["latency"]["p99"] < thr["latency"]["p99"]
            or bl["efficiency"] > thr["efficiency"]),
        "oracle_p99": pol["oracle"]["latency"]["p99"],
        "oracle_efficiency": pol["oracle"]["efficiency"],
    }
    v = out["validation"]
    print(f"\nAMOEBA-dynamic vs static-fused: "
          f"p99 {v['p99_speedup_vs_fused']:.2f}x, "
          f"efficiency {v['efficiency_gain_vs_fused']:.2f}x, "
          f"wins both: {v['dynamic_beats_fused']}")
    print(f"{best_learned} vs threshold: "
          f"p99 {v['learned_p99_speedup_vs_threshold']:.2f}x, "
          f"efficiency {v['learned_efficiency_gain_vs_threshold']:.2f}x, "
          f"wins either: {v['learned_beats_threshold']} "
          f"(oracle bound: p99={v['oracle_p99']:.1f}, "
          f"eff={v['oracle_efficiency']:.3f})")
    cv = out["composition_sweep"]["validation"]
    print(f"hetero vs {cv['best_equal_ladder']}: "
          f"p99 {cv['hetero_p99_speedup_vs_equal']:.2f}x, "
          f"efficiency {cv['hetero_efficiency_gain_vs_equal']:.2f}x, "
          f"wins either: {cv['hetero_beats_equal']}")
    wv = out["work_stealing"]["validation"]
    print(f"stealing vs no-stealing: p99 {wv['steal_p99_speedup']:.2f}x, "
          f"steals={wv['steals']} live={wv['live_migrations']}, "
          f"wins: {wv['stealing_beats_no_stealing']}")
    lv = out["slack_lease"]["validation"]
    print(f"lease vs steal-only: "
          f"p99 {lv['lease_p99_speedup_vs_steal_only']:.2f}x "
          f"(vs reconfig-only "
          f"{lv['lease_p99_speedup_vs_reconfig_only']:.2f}x), "
          f"grants={lv['grants']} lent={lv['slot_ticks_lent']} "
          f"slot-ticks, zero-stall: {lv['zero_stall_contract_holds']}")
    hv = out["cluster_hierarchy"]["validation"]
    print(f"hierarchical vs flat-blind: "
          f"p99 {hv['hierarchical_p99_speedup_vs_flat']:.2f}x, "
          f"interchip stall {hv['flat_interchip_stall_ticks']} -> "
          f"{hv['hier_interchip_stall_ticks']} ticks, "
          f"wins: {hv['hierarchical_beats_flat']}; zero-bw veto holds: "
          f"{hv['zero_bw_vetoes_crossings_intra_flows']}")
    sv = out["fleet_scale"]["validation"]
    print(f"vec engine at scale: {sv['vec_speedup_ticks_per_sec']:,}x "
          f"ticks/sec vs object ({sv['vec_ticks_per_sec']:,} vs "
          f"{sv['object_ticks_per_sec']}), "
          f"vec sweep wall {sv['vec_total_wall_s']}s")
    ov = out["obs_overhead"]
    print(f"obs overhead: off {ov['off_overhead_frac']:+.2%} "
          f"(<=2%: {ov['validation']['off_within_2pct']}), "
          f"full {ov['full_overhead_frac']:+.2%} "
          f"(<=15%: {ov['validation']['full_within_15pct']})")
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {os.path.abspath(out_path)}")
    return out


if __name__ == "__main__":
    import argparse
    import sys
    sys.path.insert(0, os.path.join(ROOT, "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--trace-out",
                    default=os.path.join(ROOT, "BENCH_fleet_trace.jsonl"),
                    help="JSONL event trace from the work_stealing sweep "
                         "(empty string disables)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small fleet, short trace")
    args = ap.parse_args()
    scale_groups, scale_requests = 100, 100_000
    if args.quick:
        args.groups, args.capacity, args.horizon = 2, 4, 40
        scale_groups, scale_requests = 12, 5_000
    fleet_bench(groups=args.groups, capacity=args.capacity,
                horizon=args.horizon, seed=args.seed, out_path=args.out,
                scale_groups=scale_groups, scale_requests=scale_requests,
                trace_out=args.trace_out or None)
