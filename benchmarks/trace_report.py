"""CLI over a JSONL event trace: timeline, attribution, mispredictions.

Usage::

    # full text report: timeline, decisions-preceding-reconfigs table,
    # top-K misprediction table
    python benchmarks/trace_report.py TRACE.jsonl

    # assert the JSONL round-trips exactly (CI uses this)
    python benchmarks/trace_report.py TRACE.jsonl --check

    # convert to Chrome trace-event JSON (open in ui.perfetto.dev)
    python benchmarks/trace_report.py TRACE.jsonl --chrome trace.json

Produce a trace by running any fleet engine with
``FleetConfig(obs="full")`` and exporting::

    from repro.obs import write_jsonl
    write_jsonl("TRACE.jsonl", eng.obs.events(), meta=eng.obs.meta)
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.events import jsonable                      # noqa: E402
from repro.obs.export import chrome_trace, read_jsonl      # noqa: E402
from repro.obs.report import render_report                 # noqa: E402


def check_roundtrip(path: str, meta, events) -> None:
    """Assert the file is the fixed point of parse -> re-serialize."""
    with open(path) as f:
        original = [line.strip() for line in f if line.strip()]
    rebuilt = [json.dumps({"kind": "_meta", **meta}, sort_keys=True)]
    rebuilt += [json.dumps(jsonable(e), sort_keys=True) for e in events]
    assert len(original) == len(rebuilt), \
        f"line count changed: {len(original)} -> {len(rebuilt)}"
    for i, (a, b) in enumerate(zip(original, rebuilt)):
        assert json.loads(a) == json.loads(b), \
            f"line {i} did not round-trip:\n  {a}\n  {b}"
    print(f"round-trip ok: {len(events)} events")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL trace (repro.obs.write_jsonl)")
    ap.add_argument("--check", action="store_true",
                    help="assert the JSONL round-trips exactly and exit")
    ap.add_argument("--chrome", metavar="OUT",
                    help="write Chrome trace-event JSON to OUT")
    ap.add_argument("--timeline", type=int, default=40,
                    help="max timeline lines (default 40)")
    ap.add_argument("--top-k", type=int, default=10,
                    help="misprediction table size (default 10)")
    args = ap.parse_args(argv)

    meta, events = read_jsonl(args.trace)
    if args.check:
        check_roundtrip(args.trace, meta, events)
        return 0
    if args.chrome:
        trace = chrome_trace(events, meta)
        with open(args.chrome, "w") as f:
            json.dump(trace, f)
        print(f"wrote {len(trace['traceEvents'])} trace events "
              f"to {args.chrome} (open in ui.perfetto.dev)")
        return 0
    print(render_report(events, meta, timeline_limit=args.timeline,
                        top_k=args.top_k))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
