"""Benchmark driver: one harness per paper table/figure + the mesh-level
roofline/AMOEBA analyses.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig12 roofline

Writes machine-readable results to experiments/bench_results.json.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import figures, fleet_bench, mesh_amoeba, roofline  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "bench_results.json")

BENCHES = {
    "fig12": figures.fig12_performance,
    "fig13": figures.fig13_stalls,
    "fig14_16": figures.fig14_16_memory,
    "fig17_18": figures.fig17_18_noc,
    "fig19": figures.fig19_dynamics,
    "fig20": figures.fig20_predictor,
    "fig21": figures.fig21_dws,
    "roofline": lambda: {"cells": roofline.main()},
    "mesh_plan_selection": mesh_amoeba.plan_selection,
    "serving_regroup": mesh_amoeba.serving_regroup,
    "fleet": fleet_bench.fleet_bench,
}


def main() -> None:
    wanted = sys.argv[1:] or list(BENCHES)
    results = {}
    for name in wanted:
        fn = BENCHES[name]
        print(f"\n======== {name} ========")
        t0 = time.time()
        results[name] = {"result": fn(), "seconds": round(time.time() - t0, 2)}
        print(f"[{name}: {results[name]['seconds']}s]")

    # headline validation summary (reproduction vs paper)
    if "fig12" in results and "fig21" in results:
        v = results["fig12"]["result"]["validation"]
        d = results["fig21"]["result"]
        print("\n======== validation vs paper ========")
        print(f"SM speedup        {v['SM_speedup']:.2f}  (paper 4.25)")
        print(f"MUM speedup       {v['MUM_speedup']:.2f}  (paper 2.11)")
        print(f"geomean           {v['geomean']:.3f} (paper ~1.47)")
        print(f"regroup/direct    {v['regroup_over_direct']:.3f} (paper ~1.16)")
        print(f"AMOEBA/DWS        {d['geomean']:.3f} (paper ~1.27)")
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\nwrote {OUT}")


if __name__ == "__main__":
    main()
