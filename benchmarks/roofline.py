"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

For every compiled (arch x shape x mesh) cell: the three terms in seconds,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs (useful-compute fraction)
and the roofline fraction (useful time / bound time).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def load_artifacts(art_dir: str = ART_DIR) -> List[Dict]:
    arts = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            arts.append(json.load(f))
    return arts


def table(arts: List[Dict], mesh: str = "16x16") -> List[Dict]:
    rows = []
    for a in arts:
        if a.get("skipped") or a["mesh"] != mesh or a.get("plan", "base") != "base":
            continue
        r = a["roofline"]
        rows.append({
            "arch": a["arch"], "shape": a["shape"], "mesh": a["mesh"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "roofline_frac": r["roofline_frac"],
            "useful_flop_frac": r["useful_flop_frac"],
            "temp_gb": a.get("temp_size_in_bytes", 0) / 1e9,
            "args_gb": a.get("argument_size_in_bytes", 0) / 1e9,
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def print_table(rows: List[Dict]) -> None:
    hdr = (f"{'arch':<18}{'shape':<12}{'compute_s':>11}{'memory_s':>10}"
           f"{'coll_s':>10}{'bound':>11}{'roofl%':>8}{'useful%':>9}"
           f"{'temp_GB':>9}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:<18}{r['shape']:<12}{r['compute_s']:>11.3e}"
              f"{r['memory_s']:>10.3e}{r['collective_s']:>10.3e}"
              f"{r['bottleneck']:>11}{100*r['roofline_frac']:>7.1f}%"
              f"{100*r['useful_flop_frac']:>8.1f}%{r['temp_gb']:>9.1f}")


def main(out_path: str = None) -> List[Dict]:
    arts = load_artifacts()
    if not arts:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return []
    for mesh in ("16x16", "pod2x16x16"):
        rows = table(arts, mesh)
        if rows:
            print(f"\n=== roofline, mesh {mesh} ({len(rows)} cells) ===")
            print_table(rows)
    if out_path:
        with open(out_path, "w") as f:
            json.dump({"cells": table(arts, "16x16")
                       + table(arts, "pod2x16x16")}, f, indent=1)
    return table(arts, "16x16")


if __name__ == "__main__":
    main()
