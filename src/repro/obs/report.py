"""Text renderers over a trace: timeline, attribution, mispredictions.

This is the library behind ``benchmarks/trace_report.py`` (the CLI) and
``examples/trace_timeline.py``; it works on live
:class:`~repro.obs.events.Event` objects or JSONL re-reads alike.

The attribution table answers the acceptance question "which decision
preceded each topology change": for every ``reconfig`` event it finds
the latest prior ``policy_decision`` on the same group and prints the
decision's features, predicted win, and realized outcome next to the cut
it caused.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from repro.obs.audit import (decision_rows, misprediction_rate,
                             top_mispredictions)


def _as_dict(e: Any) -> Dict[str, Any]:
    return e if isinstance(e, dict) else e.as_dict()


def _topo(t) -> str:
    if not t:
        return "?"
    return "(" + ",".join(str(int(w)) for w in t) + ")"


def _fmt_payload(kind: str, p: Dict[str, Any]) -> str:
    if kind == "reconfig":
        return (f"{_topo(p.get('from'))} -> {_topo(p.get('to'))}"
                f" gain={p.get('gain', 0):+.3f} [{p.get('reason', '')}]")
    if kind in ("steal", "migrate"):
        return (f"r{p.get('rid')} {p.get('src')} -> {p.get('dst')}"
                + (f" stall={p['stall']}" if p.get("stall") else "")
                + (f" tier={p['tier']}" if p.get("tier") else ""))
    if kind == "spill":
        # gid (the timeline address column) is the acting source group;
        # the payload still carries both endpoints
        return f"g{p.get('src')} -> g{p.get('dst')}"
    if kind == "lease":
        dst = p.get("dst") or (None, None)
        s = (f"{p.get('action')} l{p.get('lid')} {p.get('slots')} slot(s)"
             f" -> g{dst[0]}/p{dst[1]}")
        if p.get("action") == "grant":
            s += f" term={p.get('term')} gain={p.get('gain', 0):+.3f}"
        elif p.get("reason"):
            s += f" [{p.get('reason')}]"
        return s
    if kind == "admission":
        return f"n={p.get('n')} rids={p.get('rids')}"
    if kind == "policy_decision":
        s = (f"{_topo(p.get('from'))} -> {_topo(p.get('target'))}"
             f" proba={p.get('proba', 0):.2f} [{p.get('reason', '')}]")
        if not p.get("applied"):
            s += " (held)"
        return s
    if kind == "refit":
        return " ".join(f"{k}={p[k]}" for k in sorted(p))
    if kind == "region_grab":
        return f"chip={p.get('chip')} {p.get('action')} groups={p.get('groups')}"
    if kind == "stall":
        return f"remaining={p.get('remaining')}"
    return str(p)


def render_timeline(events: Sequence[Any],
                    limit: Optional[int] = None) -> str:
    """One line per event: ``[tick] kind g<gid>/p<part> detail``."""
    evs = [_as_dict(e) for e in events]
    lines = []
    shown = evs if limit is None else evs[:limit]
    for e in shown:
        addr = f"g{e['gid']}" if e["gid"] >= 0 else "fleet"
        if e["part"] is not None:
            addr += f"/p{e['part']}"
        lines.append(f"[{e['tick']:>6}] {e['kind']:<15} {addr:<8} "
                     f"{_fmt_payload(e['kind'], e['payload'])}")
    if limit is not None and len(evs) > limit:
        lines.append(f"... {len(evs) - limit} more events")
    return "\n".join(lines)


def attribution_rows(events: Sequence[Any]) -> List[Dict[str, Any]]:
    """Join each reconfig to the latest prior decision on its group."""
    evs = sorted((_as_dict(e) for e in events), key=lambda e: e["seq"])
    last_decision: Dict[int, Dict[str, Any]] = {}
    rows: List[Dict[str, Any]] = []
    for e in evs:
        if e["kind"] == "policy_decision":
            last_decision[e["gid"]] = e
        elif e["kind"] == "reconfig":
            d = last_decision.get(e["gid"])
            dp = d["payload"] if d else {}
            rows.append({
                "tick": e["tick"], "gid": e["gid"],
                "from": e["payload"].get("from"),
                "to": e["payload"].get("to"),
                "gain": e["payload"].get("gain"),
                "reason": e["payload"].get("reason"),
                "decision_tick": d["tick"] if d else None,
                "features": dp.get("features"),
                "proba": dp.get("proba"),
                "label": dp.get("label"),
            })
    return rows


def render_attribution(events: Sequence[Any]) -> str:
    rows = attribution_rows(events)
    if not rows:
        return "(no reconfigs in trace)"
    lines = ["tick    gid  change              decision@  proba  label  "
             "reason                features"]
    for r in rows:
        feats = ("[" + ", ".join(f"{f:.2f}" for f in r["features"]) + "]"
                 if r["features"] else "-")
        proba = f"{r['proba']:.2f}" if r["proba"] is not None else "  - "
        label = f"{r['label']:.0f}" if r["label"] is not None else "-"
        lines.append(
            f"{r['tick']:<7} {r['gid']:<4} "
            f"{_topo(r['from'])+'->'+_topo(r['to']):<19} "
            f"{str(r['decision_tick']):<10} {proba:<6} {label:<6} "
            f"{(r['reason'] or '')[:20]:<21} {feats}")
    return "\n".join(lines)


def render_mispredictions(events: Sequence[Any], k: int = 10) -> str:
    rows = decision_rows(events)
    rate = misprediction_rate(rows)
    if rate is None:
        return ("(no labeled decisions in trace — run with an online "
                "policy so the replay buffer is wired)")
    worst = top_mispredictions(rows, k=k)
    lines = [f"labeled decisions: "
             f"{sum(1 for r in rows if r['mispredicted'] is not None)}  "
             f"misprediction rate: {rate:.3f}"]
    if not worst:
        lines.append("(no mispredictions)")
        return "\n".join(lines)
    lines.append("tick    gid  proba  label  conf   move               "
                 "features")
    for r in worst:
        feats = ("[" + ", ".join(f"{f:.2f}" for f in r["features"]) + "]"
                 if r["features"] else "-")
        lines.append(
            f"{r['tick']:<7} {r['gid']:<4} {r['proba']:.2f}   "
            f"{r['label']:.0f}      {r['confidence']:.2f}   "
            f"{_topo(r['from'])+'->'+_topo(r['target']):<19}{feats}")
    return "\n".join(lines)


def render_report(events: Sequence[Any], meta: Optional[Dict] = None,
                  timeline_limit: int = 40, top_k: int = 10) -> str:
    """The full text report the CLI prints."""
    sections = []
    if meta:
        sections.append("== meta ==\n" + "\n".join(
            f"{k}: {meta[k]}" for k in sorted(meta) if k != "mesh"))
    sections.append("== timeline ==\n"
                    + render_timeline(events, limit=timeline_limit))
    sections.append("== decisions preceding each topology change ==\n"
                    + render_attribution(events))
    sections.append(f"== top-{top_k} mispredictions ==\n"
                    + render_mispredictions(events, k=top_k))
    return "\n\n".join(sections)
