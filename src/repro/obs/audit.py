"""Decision audit: join predictions to realized outcomes.

Each ``policy_decision`` event carries what the controller saw (the
feature vector), what the predictor believed (``proba``: P(more-split
wins), ``gain``), what move it chose, and — when a
:class:`~repro.control.ReplayBuffer` is wired — the realized label the
controller logged for that same tick (``label``: 1.0 when regrouping the
live batch would actually have beaten the margin) plus the absolute
replay index (``replay_idx``) of the stored sample.

That makes mispredictions queryable: a decision is *mispredicted* when
the predictor leaned one way (``proba`` vs 0.5) and the realized label
landed on the other.  ``confidence`` is how far the predictor leaned, so
``top_mispredictions`` surfaces the confidently-wrong decisions first —
the ones worth staring at when tuning ``refit_every`` or the drift
threshold.

Rows are built from event dicts (live :class:`~repro.obs.events.Event`
objects or JSONL re-reads both work), so the audit runs offline from a
trace file alone.  When the live buffer is still around,
:func:`verify_replay` cross-checks each row's label against the stored
sample via the buffer's ``total_added`` high-water mark.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence


def _as_dict(e: Any) -> Dict[str, Any]:
    return e if isinstance(e, dict) else e.as_dict()


def decision_rows(events: Sequence[Any]) -> List[Dict[str, Any]]:
    """Flatten ``policy_decision`` events into audit rows.

    Rows with a realized label gain ``mispredicted`` / ``confidence``
    columns; rows without (replay not wired, or too few live requests to
    label) keep them ``None`` so callers can filter.
    """
    rows: List[Dict[str, Any]] = []
    for raw in events:
        e = _as_dict(raw)
        if e["kind"] != "policy_decision":
            continue
        p = e["payload"]
        row: Dict[str, Any] = {
            "tick": e["tick"], "gid": e["gid"],
            "from": p.get("from"), "target": p.get("target"),
            "applied": p.get("applied"),
            "proba": p.get("proba"), "gain": p.get("gain"),
            "reason": p.get("reason"), "features": p.get("features"),
            "replay_idx": p.get("replay_idx"),
            "label": p.get("label"), "label_gain": p.get("label_gain"),
            "mispredicted": None, "confidence": None,
        }
        if row["label"] is not None and row["proba"] is not None:
            pred_split = row["proba"] > 0.5
            real_split = row["label"] > 0.5
            row["mispredicted"] = pred_split != real_split
            row["confidence"] = round(abs(row["proba"] - 0.5), 4)
        rows.append(row)
    return rows


def top_mispredictions(rows: Sequence[Dict[str, Any]],
                       k: int = 10) -> List[Dict[str, Any]]:
    """The K most confidently wrong decisions, worst first."""
    wrong = [r for r in rows if r["mispredicted"]]
    wrong.sort(key=lambda r: (-r["confidence"], r["tick"], r["gid"]))
    return wrong[:k]


def misprediction_rate(rows: Sequence[Dict[str, Any]]) -> Optional[float]:
    labeled = [r for r in rows if r["mispredicted"] is not None]
    if not labeled:
        return None
    return sum(1 for r in labeled if r["mispredicted"]) / len(labeled)


def verify_replay(rows: Sequence[Dict[str, Any]], replay) -> int:
    """Cross-check audit rows against the live ReplayBuffer.

    ``replay_idx`` is the absolute add index; samples evicted from the
    bounded buffer are skipped.  Returns the number of rows verified;
    raises if a retained sample's label disagrees with the event.
    """
    base = replay.total_added - len(replay)
    checked = 0
    for r in rows:
        idx = r.get("replay_idx")
        if idx is None:
            continue
        pos = idx - base
        if pos < 0 or pos >= len(replay):
            continue  # evicted
        stored = float(replay._y[pos])
        if stored != float(r["label"]):
            raise AssertionError(
                f"audit/replay mismatch at replay_idx={idx}: "
                f"event label {r['label']} vs stored {stored}")
        checked += 1
    return checked
