"""Structured event stream for the fleet/control/cluster stack.

AMOEBA's runtime is a monitor -> predict -> reconfigure loop; end-of-run
aggregates (:mod:`repro.fleet.telemetry`) can say *how often* the loop
fired but not *why* any individual firing happened.  The
:class:`EventLog` records every control-plane decision as a typed,
tick-stamped record so a run can be replayed decision by decision:

========== =================================================================
kind        emitted when
========== =================================================================
reconfig    a group changes topology (``ReconfigurableGroup.step``)
steal       a queued request moves between groups (``MigrationPlanner``)
migrate     an in-flight request moves with its KV rows
spill       the router reroutes a pinned admission off a hot group
region_grab a cluster region gathers or releases groups
admission   a prefill wave admits requests into a part
policy_decision  a ``GroupController`` resolves a topology proposal
refit       an online policy refits (or drift-resets) its predictor
stall       a part burns a tick paying a KV-transfer stall
lease       a slot lease is granted / revoked / expired (``LeasePlanner``)
========== =================================================================

Every event stamps ``gid`` with the *acting* group (the spill source,
the lease lender, the reconfiguring group); counterpart addresses ride
the payload (``dst``).

The log has three modes (``FleetConfig.obs``):

* ``off`` — ``emit`` returns immediately; hot paths guard on
  ``log.enabled`` before building payloads, so the only cost is one
  attribute check.  Summaries are bit-identical to a build without the
  log.
* ``summary`` — per-kind counters only; no ring, no payload retention.
* ``full`` — counters plus a bounded ring of :class:`Event` records and
  per-tick :class:`~repro.obs.metrics.MetricsRegistry` sampling.

Every emission site lives in *shared control-plane code* (never inside a
``VecGroup`` data-plane override), so the object and vec engines produce
identical event streams — asserted by ``tests/test_vec_equivalence.py``,
which makes the trace itself a correctness oracle for the control plane.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import numpy as np

EVENT_KINDS = (
    "reconfig", "steal", "migrate", "spill", "region_grab",
    "admission", "policy_decision", "refit", "stall", "lease",
)

OBS_MODES = ("off", "summary", "full")


def jsonable(v: Any) -> Any:
    """Normalize a payload value to the JSON-stable fixed point.

    Tuples become lists and numpy scalars become native Python numbers,
    so a trace written to JSONL and read back compares equal to the
    in-memory event — the round-trip check in
    ``benchmarks/trace_report.py`` relies on this.  Normalization runs
    lazily on first *view* (:meth:`Event.as_dict`), not at emit time:
    the hot path just stores the payload dict, and a 30k-event run pays
    the recursive walk only for the events something actually reads.
    """
    if isinstance(v, (tuple, list)):
        return [jsonable(x) for x in v]
    if isinstance(v, dict):
        return {k: jsonable(x) for k, x in v.items()}
    if isinstance(v, np.ndarray):
        return [jsonable(x) for x in v.tolist()]
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return v


@dataclass
class Event:
    """One typed control-plane record: what happened, where, and when.

    The payload is stored exactly as emitted (tuples, numpy scalars and
    all) and normalized to the JSON fixed point on first view — always
    read it through :meth:`as_dict`.
    """
    seq: int
    tick: int
    kind: str
    gid: int
    part: Optional[int] = None
    payload: Dict[str, Any] = field(default_factory=dict)
    _normalized: bool = field(default=False, repr=False, compare=False)

    def as_dict(self) -> Dict[str, Any]:
        if not self._normalized:
            self.payload = {k: jsonable(v) for k, v in self.payload.items()}
            self._normalized = True
        return {"seq": self.seq, "tick": self.tick, "kind": self.kind,
                "gid": self.gid, "part": self.part, "payload": self.payload}


class EventLog:
    """Ring-buffered structured event stream; near-zero cost when off.

    The engine owns the clock: :meth:`set_tick` is called once per wall
    tick, and emitters that have no tick in scope (policy refits, the
    controller's observe path) stamp records with ``self.now``.
    """

    def __init__(self, mode: str = "off", capacity: int = 65536):
        if mode not in OBS_MODES:
            raise ValueError(
                f"unknown obs mode {mode!r}; expected one of {OBS_MODES}")
        self.mode = mode
        self.enabled = mode != "off"
        self.full = mode == "full"
        self.capacity = int(capacity)
        self.counts: Dict[str, int] = {k: 0 for k in EVENT_KINDS}
        self.dropped = 0
        self.now = 0
        self._seq = 0
        self._ring: Deque[Event] = collections.deque(maxlen=self.capacity)
        # run-level context for exporters (mesh layout, wall ticks, ...)
        self.meta: Dict[str, Any] = {}

    def set_tick(self, tick: int) -> None:
        self.now = tick

    def emit(self, kind: str, gid: int = -1, part: Optional[int] = None,
             tick: Optional[int] = None, **payload: Any) -> None:
        if not self.enabled:
            return
        self.counts[kind] += 1
        self._seq += 1
        if not self.full:
            return
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(Event(
            seq=self._seq, tick=self.now if tick is None else int(tick),
            kind=kind, gid=int(gid),
            part=None if part is None else int(part),
            payload=payload))

    # -- views -----------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def total(self) -> int:
        return self._seq

    def events(self, kind: Optional[str] = None) -> List[Event]:
        if kind is None:
            return list(self._ring)
        return [e for e in self._ring if e.kind == kind]

    def count(self, kind: str) -> int:
        return self.counts[kind]

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "mode": self.mode,
            "total_events": self._seq,
            "by_kind": {k: self.counts[k] for k in EVENT_KINDS
                        if self.counts[k]},
        }
        if self.full:
            out["retained"] = len(self._ring)
            out["dropped"] = self.dropped
        return out

    def clear(self) -> None:
        self.counts = {k: 0 for k in EVENT_KINDS}
        self.dropped = 0
        self._seq = 0
        self._ring.clear()


#: Shared disabled log: every component that *may* be observed defaults to
#: this, so instrumented code never branches on ``obs is None``.
NULL_LOG = EventLog(mode="off")
