"""Counters, gauges, and histograms sampled per tick.

Where :class:`~repro.obs.events.EventLog` answers *what happened*, the
:class:`MetricsRegistry` answers *what the fleet looked like* while it
happened: queue depth, live load, and per-tier transfer bytes, sampled
once per wall tick by the engine when ``FleetConfig.obs == "full"``.

Histograms are streaming power-of-two bucket counts (no sample
retention), so a 100k-tick run costs a fixed few dicts.  Everything
feeding the registry is shared control-plane state, so the object and
vec engines produce identical snapshots.
"""
from __future__ import annotations

from typing import Any, Dict


def _bucket(value: float) -> int:
    """Power-of-two bucket index: 0 for <=0, else bit_length(ceil(v))."""
    iv = int(value)
    if iv <= 0:
        return 0
    return iv.bit_length()


class Histogram:
    """Streaming histogram: count/sum/min/max plus log2 buckets."""

    def __init__(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.buckets: Dict[int, int] = {}

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        b = _bucket(v)
        self.buckets[b] = self.buckets.get(b, 0) + 1

    def snapshot(self) -> Dict[str, Any]:
        if not self.count:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": round(self.sum / self.count, 3),
            "min": self.min, "max": self.max,
            # bucket b holds values in [2^(b-1), 2^b); keys sorted for
            # stable JSON output
            "log2_buckets": {str(b): self.buckets[b]
                             for b in sorted(self.buckets)},
        }


class MetricsRegistry:
    """Named counters, last-value gauges, and streaming histograms."""

    def __init__(self) -> None:
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}

    def count(self, name: str, delta: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + delta

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        h.observe(value)

    # -- per-tick fleet sampling ----------------------------------------------

    def sample_fleet(self, tick: int, groups, planner=None,
                     live: int = None) -> None:
        """One wall tick's worth of fleet-shape samples.

        ``groups`` supply queue depth and live load (via the shared
        ``live_count`` hook); a cluster planner contributes per-tier
        cumulative byte gauges when present.  Callers that can compute
        the fleet-wide live count cheaper than a per-group scan (the
        vec engine's flat arrays) pass it via ``live``.
        """
        qd = sum(len(g.queue) for g in groups)
        if live is None:
            live = sum(g.live_count() for g in groups)
        self.observe("fleet.queue_depth", qd)
        self.observe("fleet.live", live)
        self.gauge("fleet.queue_depth", qd)
        self.gauge("fleet.live", live)
        self.gauge("fleet.tick", tick)
        tier_bytes = getattr(planner, "tier_bytes", None)
        if tier_bytes:
            for tier in sorted(tier_bytes):
                self.gauge(f"tier.{tier}.bytes", tier_bytes[tier])

    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {k: self.counters[k] for k in sorted(self.counters)},
            "gauges": {k: self.gauges[k] for k in sorted(self.gauges)},
            "histograms": {k: self.histograms[k].snapshot()
                           for k in sorted(self.histograms)},
        }
