"""Observability for the AMOEBA serving stack.

``repro.obs`` gives the monitor -> predict -> reconfigure loop a
decision-level record: a structured :class:`EventLog` (what happened,
where, when), a :class:`MetricsRegistry` (what the fleet looked like,
per tick), a decision audit joining predictions to realized outcomes,
and exporters (JSONL + Chrome trace-event for Perfetto).  Select with
``FleetConfig.obs`` — ``"off"`` (default, near-zero overhead and
bit-identical summaries), ``"summary"`` (counters only), or ``"full"``
(ring buffer + metrics + audit).
"""
from repro.obs.audit import (decision_rows, misprediction_rate,
                             top_mispredictions, verify_replay)
from repro.obs.events import (EVENT_KINDS, NULL_LOG, OBS_MODES, Event,
                              EventLog, jsonable)
from repro.obs.export import (chrome_trace, read_jsonl, write_chrome_trace,
                              write_jsonl)
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.report import (attribution_rows, render_attribution,
                              render_mispredictions, render_report,
                              render_timeline)

__all__ = [
    "EVENT_KINDS", "OBS_MODES", "Event", "EventLog", "NULL_LOG", "jsonable",
    "Histogram", "MetricsRegistry",
    "decision_rows", "top_mispredictions", "misprediction_rate",
    "verify_replay",
    "write_jsonl", "read_jsonl", "chrome_trace", "write_chrome_trace",
    "attribution_rows", "render_timeline", "render_attribution",
    "render_mispredictions", "render_report",
]
