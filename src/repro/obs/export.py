"""Trace exporters: JSONL and Chrome trace-event JSON (Perfetto).

JSONL is the archival format: one ``{"kind": "_meta", ...}`` header line
(run context: mesh layout, wall ticks, obs mode) followed by one event
object per line, normalized to JSON's fixed point by
:func:`~repro.obs.events.jsonable` when viewed — so
``read_jsonl(write_jsonl(...))`` is exact, which
``benchmarks/trace_report.py --check`` asserts in CI.

The Chrome trace maps the fleet onto Perfetto's process/thread model:

* process = chip (when a mesh layout is in ``meta``), thread = group;
* each group's **topology** is a span (``ph: "X"``) named after the
  composition (``"5+3"``), rebuilt by walking its ``reconfig`` events;
* **reconfigs** are instants (``ph: "i"``) at the moment of the cut;
* **steals/migrates** are flow events (``ph: "s"`` at the source group,
  ``ph: "f"`` at the destination) so Perfetto draws the arrow;
* everything else (spill, admission, stall, region_grab,
  policy_decision, refit) renders as thread-scoped instants.

Ticks map to microseconds at 1 tick = 1 ms so short runs stay readable.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.events import Event

US_PER_TICK = 1000  # 1 wall tick renders as 1 ms in Perfetto


def _as_dict(e: Any) -> Dict[str, Any]:
    return e if isinstance(e, dict) else e.as_dict()


def write_jsonl(path: str, events: Sequence[Any],
                meta: Optional[Dict[str, Any]] = None) -> int:
    """Write a meta header plus one event per line; returns event count."""
    evs = [_as_dict(e) for e in events]
    with open(path, "w") as f:
        f.write(json.dumps({"kind": "_meta", **(meta or {})},
                           sort_keys=True) + "\n")
        for e in evs:
            f.write(json.dumps(e, sort_keys=True) + "\n")
    return len(evs)


def read_jsonl(path: str) -> Tuple[Dict[str, Any], List[Dict[str, Any]]]:
    """Read a trace back; returns (meta, events)."""
    meta: Dict[str, Any] = {}
    events: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            if obj.get("kind") == "_meta":
                meta = {k: v for k, v in obj.items() if k != "kind"}
            else:
                events.append(obj)
    return meta, events


def _topo_name(topo) -> str:
    if not topo:
        return "?"
    return "+".join(str(int(w)) for w in topo)


def chrome_trace(events: Sequence[Any],
                 meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Build a Chrome trace-event dict from an event stream."""
    meta = meta or {}
    evs = sorted((_as_dict(e) for e in events), key=lambda e: e["seq"])
    mesh = meta.get("mesh") or {}
    chip_of = {int(g): int(c)
               for g, c in (mesh.get("chip_of") or {}).items()}

    def pid(gid: int) -> int:
        return chip_of.get(gid, 0)

    out: List[Dict[str, Any]] = []
    gids = sorted({e["gid"] for e in evs if e["gid"] >= 0})
    pids = sorted(set(chip_of.values())) if chip_of else [0]
    for p in pids:
        name = f"chip {p}" if chip_of else "fleet"
        out.append({"ph": "M", "pid": p, "tid": 0,
                    "name": "process_name", "args": {"name": name}})
    for g in gids:
        out.append({"ph": "M", "pid": pid(g), "tid": g,
                    "name": "thread_name", "args": {"name": f"group {g}"}})

    end_tick = meta.get("wall_ticks")
    if end_tick is None:
        end_tick = (max((e["tick"] for e in evs), default=0)) + 1

    # -- topology spans + reconfig instants, per group -------------------------
    span_start: Dict[int, int] = {}
    span_topo: Dict[int, Any] = {}
    for e in evs:
        if e["kind"] != "reconfig":
            continue
        g, t = e["gid"], e["tick"]
        frm, to = e["payload"].get("from"), e["payload"].get("to")
        if g not in span_start:
            span_start[g], span_topo[g] = 0, frm
        out.append({"ph": "X", "pid": pid(g), "tid": g, "cat": "topology",
                    "name": _topo_name(span_topo[g]),
                    "ts": span_start[g] * US_PER_TICK,
                    "dur": max(t - span_start[g], 0) * US_PER_TICK})
        out.append({"ph": "i", "s": "t", "pid": pid(g), "tid": g,
                    "cat": "reconfig", "ts": t * US_PER_TICK,
                    "name": f"reconfig {_topo_name(frm)}->{_topo_name(to)}",
                    "args": e["payload"]})
        span_start[g], span_topo[g] = t, to
    for g, t0 in span_start.items():
        out.append({"ph": "X", "pid": pid(g), "tid": g, "cat": "topology",
                    "name": _topo_name(span_topo[g]),
                    "ts": t0 * US_PER_TICK,
                    "dur": max(end_tick - t0, 1) * US_PER_TICK})

    # -- flows (steal/migrate) + instants for the rest -------------------------
    for e in evs:
        kind, t = e["kind"], e["tick"]
        if kind == "reconfig":
            continue
        p = e["payload"]
        if kind in ("steal", "migrate"):
            src = p.get("src", e["gid"])
            dst = p.get("dst", e["gid"])
            sg = src[0] if isinstance(src, list) else src
            dg = dst[0] if isinstance(dst, list) else dst
            flow = {"cat": kind, "id": e["seq"],
                    "name": f"{kind} r{p.get('rid', '?')}"}
            out.append({"ph": "s", "pid": pid(sg), "tid": sg,
                        "ts": t * US_PER_TICK, **flow})
            out.append({"ph": "f", "bp": "e", "pid": pid(dg), "tid": dg,
                        "ts": t * US_PER_TICK + 1, **flow})
            out.append({"ph": "i", "s": "t", "pid": pid(dg), "tid": dg,
                        "cat": kind, "ts": t * US_PER_TICK + 1,
                        "name": flow["name"], "args": p})
        else:
            g = e["gid"] if e["gid"] >= 0 else gids[0] if gids else 0
            out.append({"ph": "i", "s": "t", "pid": pid(g), "tid": g,
                        "cat": kind, "ts": t * US_PER_TICK,
                        "name": kind, "args": p})

    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, events: Sequence[Any],
                       meta: Optional[Dict[str, Any]] = None) -> int:
    trace = chrome_trace(events, meta)
    with open(path, "w") as f:
        json.dump(trace, f)
    return len(trace["traceEvents"])
