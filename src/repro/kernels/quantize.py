"""Row-wise symmetric int8 quantization Pallas kernel.

Used by the gradient-compression path of the DP all-reduce: gradients are
quantized to int8 + one fp32 scale per row before crossing the ICI, cutting
collective bytes 4x (the paper's NoC term is the analogous bottleneck its
fusion relieves; compression attacks the same roofline term from the
software side).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# wire layout of the int8 path: one int8 code per entry plus one float32
# scale per row (the (T, 1) scale tensor of quantize_int8_pallas).  The
# KV-migration cost model (repro.fleet.migrate.KVTransferCost) prices
# quantized transfers from these, so the bytes-on-the-wire estimate and
# the kernel's actual layout cannot drift apart.
INT8_CODE_BYTES = 1
INT8_SCALE_BYTES = 4


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                     # (bt, D)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)     # (bt, 1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_int8_pallas(x: jnp.ndarray, *, bt: int = 256,
                         interpret: bool = False):
    """x: (T, D) -> (q int8 (T, D), scale f32 (T, 1))."""
    T, D = x.shape
    bt = min(bt, T)
    nt = -(-T // bt)
    pt = nt * bt - T
    if pt:
        x = jnp.pad(x, ((0, pt), (0, 0)))

    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((bt, D), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, D), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nt * bt, D), jnp.int8),
            jax.ShapeDtypeStruct((nt * bt, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:T], s[:T]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)
