"""Linear-recurrence Pallas TPU kernels: RG-LRU gate scan and the Mamba-1
selective scan (fused with the C-contraction).

The recurrence ``h_t = a_t * h_{t-1} + b_t`` is sequential in t, so the
kernel keeps ``h`` resident in VMEM scratch and streams (a, b) tiles from
HBM: grid (B, n_width, n_seq) with the sequence dimension innermost —
exactly one HBM read per input element and one write per output element,
which is the roofline floor for this memory-bound op.

For the selective SSM the (D, N) state history is *never* written to HBM:
``y_t = <h_t, c_t>`` is contracted in-register, the TPU mirror of what the
CUDA selective-scan kernel does in shared memory.  Layout note: state tiles
are (N, bd) so the model dimension rides the 128-wide lane axis; N (8..16)
sits on sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# RG-LRU scan: a, b (B, S, W) -> h (B, S, W)
# ---------------------------------------------------------------------------

def _rglru_kernel(a_ref, b_ref, h_ref, h_scr, *, bs: int):
    is_ = pl.program_id(2)

    @pl.when(is_ == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def body(t, h):
        at = a_ref[0, pl.ds(t, 1), :]          # (1, bw)
        bt = b_ref[0, pl.ds(t, 1), :]
        h = at * h + bt
        h_ref[0, pl.ds(t, 1), :] = h
        return h

    h_scr[...] = jax.lax.fori_loop(0, bs, body, h_scr[...])


def rglru_scan_pallas(a: jnp.ndarray, b: jnp.ndarray, *, bs: int = 256,
                      bw: int = 512, interpret: bool = False) -> jnp.ndarray:
    """a, b: (B, S, W) float32 -> h: (B, S, W) float32."""
    B, S, W = a.shape
    bs = min(bs, S)
    bw = min(bw, W)
    ns = -(-S // bs)
    nw = -(-W // bw)
    ps, pw = ns * bs - S, nw * bw - W
    if ps or pw:
        # a=1, b=0 are the identity of the recurrence
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pw)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, ps), (0, pw)))

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, bs=bs),
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda b_, iw, is_: (b_, is_, iw)),
            pl.BlockSpec((1, bs, bw), lambda b_, iw, is_: (b_, is_, iw)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda b_, iw, is_: (b_, is_, iw)),
        out_shape=jax.ShapeDtypeStruct((B, ns * bs, nw * bw), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:, :S, :W]


# ---------------------------------------------------------------------------
# Selective SSM scan + contraction:
#   a, b (B, S, N, D), c (B, S, N)  ->  y (B, S, D), h_last (B, N, D)
# ---------------------------------------------------------------------------

def _ssm_kernel(a_ref, b_ref, c_ref, y_ref, h_last_ref, h_scr, *,
                bs: int, ns: int):
    is_ = pl.program_id(2)

    @pl.when(is_ == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def body(t, h):
        at = a_ref[0, pl.ds(t, 1)][0]          # (N, bd)
        bt = b_ref[0, pl.ds(t, 1)][0]
        ct = c_ref[0, pl.ds(t, 1)][0]          # (N,)
        h = at * h + bt                        # (N, bd)
        y = jnp.sum(h * ct[:, None], axis=0)   # (bd,)
        y_ref[0, pl.ds(t, 1), :] = y[None]
        return h

    h_scr[...] = jax.lax.fori_loop(0, bs, body, h_scr[...])

    @pl.when(is_ == ns - 1)
    def _finish():
        h_last_ref[0] = h_scr[...]


def ssm_scan_pallas(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, *,
                    bs: int = 128, bd: int = 512, interpret: bool = False):
    """a, b: (B, S, N, D); c: (B, S, N) — all float32.

    Returns (y (B, S, D), h_last (B, N, D)).
    """
    B, S, N, D = a.shape
    bs = min(bs, S)
    bd = min(bd, D)
    ns = -(-S // bs)
    nd = -(-D // bd)
    ps, pd = ns * bs - S, nd * bd - D
    if ps or pd:
        a = jnp.pad(a, ((0, 0), (0, ps), (0, 0), (0, pd)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, ps), (0, 0), (0, pd)))
        c = jnp.pad(c, ((0, 0), (0, ps), (0, 0)))

    y, h_last = pl.pallas_call(
        functools.partial(_ssm_kernel, bs=bs, ns=ns),
        grid=(B, nd, ns),
        in_specs=[
            pl.BlockSpec((1, bs, N, bd), lambda b_, id_, is_: (b_, is_, 0, id_)),
            pl.BlockSpec((1, bs, N, bd), lambda b_, id_, is_: (b_, is_, 0, id_)),
            pl.BlockSpec((1, bs, N), lambda b_, id_, is_: (b_, is_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda b_, id_, is_: (b_, is_, id_)),
            pl.BlockSpec((1, N, bd), lambda b_, id_, is_: (b_, 0, id_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, ns * bs, nd * bd), jnp.float32),
            jax.ShapeDtypeStruct((B, N, nd * bd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, bd), jnp.float32)],
        interpret=interpret,
    )(a, b, c)
    return y[:, :S, :D], h_last[:, :, :D]
