"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

Deliberately naive: full-materialization attention, step-by-step scans.
Numerics are fp32 throughout.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, Skv, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, G, hd) / (hd ** 0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kf)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, vf)
    return o.reshape(B, S, H, hd).astype(q.dtype)


def rglru_scan(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a, b: (B, S, W) -> h (B, S, W); h_t = a_t h_{t-1} + b_t, h_{-1} = 0."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, jnp.zeros_like(a[:, 0]),
                         (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


def ssm_scan(a: jnp.ndarray, b: jnp.ndarray,
             c: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a, b: (B, S, D, N); c: (B, S, N) -> (y (B, S, D), h_last (B, D, N))."""
    def step(h, abc):
        at, bt, ct = abc
        h = at * h + bt                       # (B, D, N)
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h0 = jnp.zeros_like(a[:, 0])
    h_last, ys = jax.lax.scan(
        step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1), c.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h_last


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray,
            eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale
