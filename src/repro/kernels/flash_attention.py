"""Blockwise (flash) attention Pallas TPU kernel.

AMOEBA's fused SM shares one double-width coalescing unit between two former
SMs; the TPU analogue of that memory-system discipline is a tiled attention
kernel whose working set lives in VMEM: each (q-block, kv-block) tile is
loaded once from HBM, scored on the MXU, and folded into an online-softmax
accumulator — K/V bytes are read exactly once per q-block regardless of the
sequence length.

Layout: the kernel operates on head-major (B, H, S, hd) tensors so the
lane dimension is hd (128-aligned for every assigned arch).  GQA maps the
kv-head for query head ``h`` as ``h // (H // KV)`` inside the k/v BlockSpec
index maps — no materialized head broadcast.

Grid: (B, H, nq, nk) with the kv-block dimension innermost; the running
(m, l, acc) statistics persist in VMEM scratch across the sequential nk
steps (TPU grid semantics), and the output tile is written once on the
last kv step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: Optional[int],
                 bq: int, bk: int, nk: int, s_q: int, s_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal/window block skip: the whole tile is masked out — do no compute.
    q_lo = iq * bq
    k_lo = ik * bk
    live = k_lo < s_kv
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < s_kv
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = jnp.max(m_scr[...], axis=1)                # (bq,)
        l_prev = jnp.max(l_scr[...], axis=1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])                     # (bq, bk)
        l_cur = l_prev * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jax.lax.broadcast_in_dim(m_cur, m_scr.shape, (0,))
        l_scr[...] = jax.lax.broadcast_in_dim(l_cur, l_scr.shape, (0,))

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.max(l_scr[...], axis=1)
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_hm(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       causal: bool = True, window: Optional[int] = None,
                       bq: int = 256, bk: int = 256,
                       interpret: bool = False) -> jnp.ndarray:
    """Head-major flash attention.

    q: (B, H, Sq, hd);  k, v: (B, KV, Skv, hd) with H % KV == 0.
    Returns (B, H, Sq, hd) in q.dtype.
    """
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)

    bq = min(bq, Sq)
    bk = min(bk, Skv)
    nq = -(-Sq // bq)
    nk = -(-Skv // bk)
    pq, pk = nq * bq - Sq, nk * bk - Skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, s_q=Sq, s_kv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running max
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running sum
            pltpu.VMEM((bq, hd), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
