"""Jitted public wrappers around the Pallas kernels.

On a CPU runtime (this container) the kernels run in ``interpret=True``
mode — the kernel body executes in Python/XLA exactly as written, which is
how they are validated against the ``ref.py`` oracles.  On a TPU runtime
the same calls lower to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import linear_scan as _ls
from repro.kernels import quantize as _qz
from repro.kernels import rmsnorm as _rn


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 256, bk: int = 256) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, Skv, KV, hd) -> (B, S, H, hd)."""
    qhm = q.transpose(0, 2, 1, 3)
    khm = k.transpose(0, 2, 1, 3)
    vhm = v.transpose(0, 2, 1, 3)
    out = _fa.flash_attention_hm(qhm, khm, vhm, causal=causal, window=window,
                                 bq=bq, bk=bk, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("bs", "bw"))
def rglru_scan(a: jnp.ndarray, b: jnp.ndarray, *, bs: int = 256,
               bw: int = 512) -> jnp.ndarray:
    """a, b: (B, S, W) fp32 -> h (B, S, W) fp32."""
    return _ls.rglru_scan_pallas(a, b, bs=bs, bw=bw, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("bs", "bd"))
def ssm_scan(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, *,
             bs: int = 128, bd: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Model-layout selective scan.

    a, b: (B, S, D, N); c: (B, S, N) -> (y (B, S, D), h_last (B, D, N)).
    The kernel wants the lane axis on D, so transpose to (B, S, N, D).
    """
    at = a.transpose(0, 1, 3, 2)
    bt = b.transpose(0, 1, 3, 2)
    y, h_last = _ls.ssm_scan_pallas(at, bt, c, bs=bs, bd=bd,
                                    interpret=_interpret())
    return y, h_last.transpose(0, 2, 1)


@functools.partial(jax.jit, static_argnames=("eps", "bt"))
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
            bt: int = 256) -> jnp.ndarray:
    """x: (..., D); scale: (D,)."""
    shape = x.shape
    out = _rn.rmsnorm_pallas(x.reshape(-1, shape[-1]), scale, eps=eps, bt=bt,
                             interpret=_interpret())
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("bt",))
def quantize_int8(x: jnp.ndarray, *, bt: int = 256):
    """x: (T, D) -> (q int8, scale f32 (T, 1))."""
    return _qz.quantize_int8_pallas(x, bt=bt, interpret=_interpret())


dequantize_int8 = _qz.dequantize_int8
