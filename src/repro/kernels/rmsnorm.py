"""Fused RMSNorm Pallas kernel: one HBM read + one write per element.

Row tiles of (bt, D) are normalized entirely in VMEM with fp32 statistics;
the unfused jnp version reads x three times (square, mean, scale) before
XLA fusion — the kernel makes the single-pass structure explicit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                     # (bt, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (normed * scale_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def rmsnorm_pallas(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
                   bt: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (T, D); scale: (D,) -> (T, D) in x.dtype."""
    T, D = x.shape
    bt = min(bt, T)
    nt = -(-T // bt)
    pt = nt * bt - T
    if pt:
        x = jnp.pad(x, ((0, pt), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((bt, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt * bt, D), x.dtype),
        interpret=interpret,
    )(x, scale)
    return out[:T]
