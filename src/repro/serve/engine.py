"""Serving engine with AMOEBA dynamic group splitting.

The engine drives real ``prefill``/``decode_step`` calls.  A *group* is the
serving analogue of an SM: the fused group decodes its whole batch in
lockstep, so every tick costs ``capacity`` slot-steps and the batch runs
until its **longest** member finishes — the warp-waits-for-the-last-thread
pathology.  The AMOEBA controller watches the remaining-length divergence
and, past the threshold, splits the group into two halves that admit and
drain **independently** (the paper's SM split; ``warp_regroup`` sorts by
remaining work first, ``direct_split`` cuts in arrival order).  Halves
re-fuse when the divergence signal drops.

Costs are counted in slot-steps (decode slots x ticks — the hardware-time
unit): a fused tick costs ``capacity``; two split halves tick concurrently
for the same total.  Useful work is generated tokens, so

    efficiency = useful tokens / slot-steps

is directly comparable across policies, and makespan (ticks) measures
latency.  Prefill is batched per distinct prompt length (no padding, no
cross-request contamination).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AmoebaConfig, ModelConfig
from repro.core.controller import AmoebaController
from repro.core.regroup import POLICIES, divergence_score
from repro.models import transformer as T
from repro.serve import state_utils as su


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)

    @property
    def done(self) -> bool:
        return self.remaining <= 0


@dataclass
class ServeStats:
    ticks: int = 0                 # wall-time units
    slot_steps: int = 0            # decode slots x ticks consumed
    useful_tokens: int = 0
    prefill_tokens: int = 0
    splits: int = 0
    fuses: int = 0
    completed: int = 0

    @property
    def efficiency(self) -> float:
        return self.useful_tokens / max(self.slot_steps, 1)


class _Group:
    """One decode group: live requests + their merged DecodeState."""

    def __init__(self, requests: List[Request], state: T.DecodeState,
                 last_tokens: jnp.ndarray):
        self.requests = requests
        self.state = state
        self.last = last_tokens            # (B, 1) next input token per row

    @property
    def remaining(self) -> np.ndarray:
        return np.array([r.remaining for r in self.requests], np.float64)


class ServeEngine:
    def __init__(self, model_cfg: ModelConfig, params,
                 rt: T.Runtime = T.Runtime(production=False, remat=False),
                 amoeba: AmoebaConfig = AmoebaConfig(),
                 capacity: int = 8, window: int = 256):
        self.cfg = model_cfg
        self.params = params
        self.rt = rt
        self.acfg = amoeba
        self.capacity = capacity
        self.window = window
        self.queue: collections.deque[Request] = collections.deque()
        self.stats = ServeStats()
        self.controller = AmoebaController(amoeba)
        self._decode = jax.jit(
            lambda p, s, t: T.decode_step(p, s, t, model_cfg, rt))

    # -- admission -------------------------------------------------------------

    def submit(self, requests: Sequence[Request]) -> None:
        self.queue.extend(requests)

    def _prefill_wave(self, n_slots: int) -> Optional[_Group]:
        """Admit up to n_slots queued requests: batch prefill per length."""
        wave: List[Request] = []
        while self.queue and len(wave) < n_slots:
            wave.append(self.queue.popleft())
        if not wave:
            return None
        by_len: Dict[int, List[Request]] = collections.defaultdict(list)
        for r in wave:
            by_len[len(r.prompt)].append(r)
        states, lasts, ordered = [], [], []
        for plen, reqs in sorted(by_len.items()):
            toks = jnp.asarray([r.prompt for r in reqs], jnp.int32)
            logits, st = T.prefill(self.params, {"tokens": toks}, self.cfg,
                                   self.rt, window=self.window)
            nxt = jnp.argmax(logits, axis=-1)
            for r, t in zip(reqs, np.asarray(nxt)):
                r.generated.append(int(t))
            self.stats.prefill_tokens += plen * len(reqs)
            self.stats.useful_tokens += len(reqs)
            states.append(st)
            lasts.append(nxt[:, None].astype(jnp.int32))
            ordered.extend(reqs)
        return _Group(ordered, su.concat(states),
                      jnp.concatenate(lasts, axis=0))

    # -- decode ----------------------------------------------------------------

    def _tick_group(self, g: _Group, slots: int) -> None:
        """One decode step for every live request in the group."""
        live = [i for i, r in enumerate(g.requests) if not r.done]
        if not live:
            return
        logits, new_state = self._decode(self.params, g.state, g.last)
        nxt = jnp.argmax(logits, axis=-1)
        arr = np.asarray(nxt)
        for i, r in enumerate(g.requests):
            if not r.done:
                r.generated.append(int(arr[i]))
                self.stats.useful_tokens += 1
        g.state = new_state
        g.last = nxt[:, None].astype(jnp.int32)
        self.stats.slot_steps += slots

    def _split_group(self, g: _Group) -> Tuple[_Group, _Group]:
        idx = list(range(len(g.requests)))
        fast, slow = POLICIES[self.acfg.regroup_policy](idx, g.remaining)
        mk = lambda ids: _Group([g.requests[i] for i in ids],
                                su.take(g.state, ids),
                                jnp.take(g.last, jnp.asarray(ids), axis=0))
        return mk(fast), mk(slow)

    # -- main loop ----------------------------------------------------------------

    def run(self, dynamic: bool = True, max_ticks: int = 100_000) -> ServeStats:
        """Drain the queue.  ``dynamic=False`` = fused-only baseline."""
        fused: Optional[_Group] = self._prefill_wave(self.capacity)
        halves: List[Optional[_Group]] = [None, None]
        split_mode = False

        def group_done(g):
            return g is None or all(r.done for r in g.requests)

        while self.stats.ticks < max_ticks:
            if not split_mode:
                if group_done(fused):
                    for r in (fused.requests if fused else []):
                        self.stats.completed += 1
                    fused = self._prefill_wave(self.capacity)
                    if fused is None:
                        break
                div = divergence_score(fused.remaining)
                want_split = (dynamic and self.acfg.enabled
                              and self.controller.observe(
                                  div, fused.remaining)
                              and len(fused.requests) >= 2)
                if want_split:
                    a, b = self._split_group(fused)
                    halves = [a, b]
                    fused = None
                    split_mode = True
                    self.stats.splits += 1
                else:
                    self._tick_group(fused, self.capacity)
                    self.stats.ticks += 1
            else:
                # both halves tick concurrently (one wall tick); each half
                # admits new work independently the moment it drains
                for h in range(2):
                    if group_done(halves[h]):
                        for r in (halves[h].requests if halves[h] else []):
                            self.stats.completed += 1
                        halves[h] = self._prefill_wave(self.capacity // 2)
                live = [h for h in halves if h is not None]
                if not live:
                    break
                rem = np.concatenate([h.remaining for h in live])
                div = divergence_score(rem[rem > 0]) if (rem > 0).any() else 0.
                if not self.controller.observe(div, rem):
                    # re-fuse: merge surviving requests into one group
                    self.stats.fuses += 1
                    fused = _Group(
                        sum((h.requests for h in live), []),
                        su.concat([h.state for h in live]),
                        jnp.concatenate([h.last for h in live], axis=0))
                    halves = [None, None]
                    split_mode = False
                    continue
                for h in live:
                    self._tick_group(h, self.capacity // 2)
                self.stats.ticks += 1
        # drain accounting
        for g in ([fused] if fused else []) + [h for h in halves if h]:
            for r in g.requests:
                if r.done:
                    self.stats.completed += 1
        return self.stats
