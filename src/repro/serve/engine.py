"""Serving engine with AMOEBA dynamic group splitting.

The engine drives real ``prefill``/``decode_step`` calls.  A *group* is the
serving analogue of an SM: the fused group decodes its whole batch in
lockstep, so every tick costs ``capacity`` slot-steps and the batch runs
until its **longest** member finishes — the warp-waits-for-the-last-thread
pathology.  The control plane (``repro.control``) watches the
remaining-length divergence and, when its policy fires, partitions the
group into independent parts that admit and drain on their own (the
paper's SM split; ``warp_regroup`` sorts by remaining work first,
``direct_split`` cuts in arrival order).  Parts re-fuse when the
divergence signal drops.

Topologies generalize the paper's binary pair to the full composition
lattice of :class:`repro.control.ConfigSpace`: a capacity-8 group may
run fused ``(8,)``, as the equal pair ``(4, 4)``, or as a heterogeneous
cut like ``(5, 3)`` — each part owns its slot count, admits from the
queue on its own, and drains independently.  The fused/split lifecycle
decisions live in :class:`repro.control.GroupController` — this module
only *executes* them (prefill waves, KV-state partitioning, decode
ticks).
:class:`ReconfigurableGroup` is the unit the fleet scheduler
(``repro.fleet``) replicates N times; :class:`ServeEngine` is the N=1
case and keeps the original public API.

Costs are counted in slot-steps (decode slots x ticks — the hardware-time
unit): a fused tick costs ``capacity``; k split parts tick concurrently
for the same total.  Useful work is generated tokens, so

    efficiency = useful tokens / slot-steps

is directly comparable across policies, and makespan (ticks) measures
latency.  Prefill is batched per distinct prompt length (no padding, no
cross-request contamination).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AmoebaConfig, ModelConfig
from repro.control import (ArrivalRateTracker, ConfigSpace, FeatureVector,
                           GroupController, ReplayBuffer, Topology,
                           balanced, make_policy)
from repro.control.policies import ReconfigPolicy
from repro.core.predictor import LogisticModel
from repro.models import transformer as T
from repro.obs.events import NULL_LOG, EventLog
from repro.serve import state_utils as su


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    # fleet metadata (defaults keep the original constructor signature)
    tenant: str = "default"
    arrival: int = 0                   # wall tick the request entered the system
    finish: Optional[int] = None       # wall tick the last token was generated
    # router shard for sticky (affinity) routing; None = unsharded
    shard: Optional[int] = None
    # soft preference for one part of the admitting group (set by
    # part-addressable routing and by migration steals); cleared on admit
    part_affinity: Optional[int] = None

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)

    @property
    def done(self) -> bool:
        return self.remaining <= 0

    @property
    def latency(self) -> Optional[int]:
        return None if self.finish is None else self.finish - self.arrival + 1


@dataclass
class ServeStats:
    ticks: int = 0                 # wall-time units
    slot_steps: int = 0            # decode slots x ticks consumed
    useful_tokens: int = 0
    prefill_tokens: int = 0
    splits: int = 0
    fuses: int = 0
    resizes: int = 0               # same part count, re-cut slot budgets
    completed: int = 0
    # -- cross-group migration (repro.fleet.migrate) ------------------------
    stall_ticks: int = 0           # part-ticks spent receiving migrated KV
    steals_in: int = 0             # queued requests stolen into this group
    steals_out: int = 0            # queued requests stolen away
    migrations_in: int = 0         # live requests migrated into this group
    migrations_out: int = 0        # live requests migrated away
    # -- slack leases (repro.fleet.lease) -----------------------------------
    leases_out: int = 0            # leases granted as lender
    leases_in: int = 0             # leases received as borrower

    @property
    def efficiency(self) -> float:
        return self.useful_tokens / max(self.slot_steps, 1)


class _Group:
    """One decode group: live requests + their merged DecodeState."""

    def __init__(self, requests: List[Request], state: T.DecodeState,
                 last_tokens: jnp.ndarray):
        self.requests = requests
        self.state = state
        self.last = last_tokens            # (B, 1) next input token per row

    @property
    def remaining(self) -> np.ndarray:
        return np.array([r.remaining for r in self.requests], np.float64)


def _group_done(g: Optional[_Group]) -> bool:
    return g is None or all(r.done for r in g.requests)


def make_decode_fn(model_cfg: ModelConfig, rt: T.Runtime) -> Callable:
    """One jitted ``decode_step`` closure — the single place its jit options
    live, shared by the N=1 engine, the fleet, and benchmark comparisons."""
    return jax.jit(lambda p, s, t: T.decode_step(p, s, t, model_cfg, rt))


# group step outcomes
TICKED = "ticked"        # one decode wall-tick of progress
RECONF = "reconfig"      # split or fuse happened; no decode this call
IDLE = "idle"            # no live work and nothing admissible from the queue


class ReconfigurableGroup:
    """One reconfigurable group: ``ways`` independent partitions of
    ``capacity // ways`` decode slots each.

    The serving analogue of one AMOEBA SM pair, generalized to the k-way
    topology ladder of :class:`repro.control.ConfigSpace`.  It owns its
    admission queue, its :class:`repro.control.GroupController` (policy +
    hysteresis + dwell + amortization check), its partitions, and its
    :class:`ServeStats`.  ``mode`` selects the configurations the group
    may take:

    * ``"dynamic"`` — fused by default; the control-plane policy walks
      the topology ladder on live telemetry (the paper's AMOEBA).
    * ``"fused"``   — never splits (static fused baseline).
    * ``"split"``   — permanently two halves (static split baseline; the
      paper's scale-out-only configuration).

    ``step`` advances the group by at most one wall tick; the caller (the
    N=1 :class:`ServeEngine` or the N-group ``repro.fleet.FleetEngine``)
    owns the wall clock and passes it in as ``now`` so request completion
    times are stamped consistently across groups.
    """

    def __init__(self, model_cfg: ModelConfig, params,
                 rt: T.Runtime = T.Runtime(production=False, remat=False),
                 amoeba: AmoebaConfig = AmoebaConfig(),
                 capacity: int = 8, window: int = 256,
                 mode: str = "dynamic", gid: int = 0,
                 decode_fn: Optional[Callable] = None,
                 policy: Optional[ReconfigPolicy] = None,
                 model: Optional[LogisticModel] = None,
                 replay: Optional[ReplayBuffer] = None,
                 obs: Optional[EventLog] = None):
        if mode not in ("dynamic", "fused", "split"):
            raise ValueError(f"unknown group mode {mode!r}")
        if mode == "split" and capacity < 2:
            raise ValueError("mode='split' needs capacity >= 2 "
                             "(each half needs at least one decode slot)")
        self.cfg = model_cfg
        self.params = params
        self.rt = rt
        self.acfg = amoeba
        self.capacity = capacity
        self.window = window
        self.mode = mode
        self.gid = gid
        # structured event stream (repro.obs); every emission site below
        # is shared control-plane code so the vec engine inherits it
        self.obs = obs if obs is not None else NULL_LOG
        self.queue: collections.deque[Request] = collections.deque()
        self.stats = ServeStats()
        self.space = ConfigSpace(
            capacity=capacity,
            max_ways=amoeba.max_ways if mode == "dynamic" else 2,
            min_gain=amoeba.min_gain,
            hetero=amoeba.hetero if mode == "dynamic" else False)
        if mode == "dynamic":
            self._policy = policy or make_policy(
                amoeba.policy, space=self.space,
                split_threshold=amoeba.split_threshold,
                fuse_threshold=amoeba.fuse_threshold,
                regroup_policy=amoeba.regroup_policy,
                model=model, model_path=amoeba.predictor_path,
                replay=replay, proba_band=amoeba.proba_band,
                oracle_margin=amoeba.oracle_margin,
                refit_every=amoeba.refit_every)
        else:
            # static modes never consult the controller — don't build a
            # policy (a predictor config would demand a model that a
            # static baseline run has no use for)
            self._policy = policy
        # label logging costs a full topology-ladder evaluation per tick,
        # so only wire a replay buffer when something consumes it: the
        # caller's explicit buffer, or the policy's own (OnlinePolicy)
        grp_replay = replay if replay is not None \
            else getattr(self._policy, "replay", None)
        self.controller = GroupController(
            self._policy, self.space, dwell=amoeba.min_phase_steps,
            replay=grp_replay, label_margin=amoeba.label_margin,
            regroup_policy=amoeba.regroup_policy,
            obs=self.obs, gid=gid)
        self._decode = decode_fn or make_decode_fn(model_cfg, rt)
        self._arrivals = ArrivalRateTracker()
        # the current topology: one entry per partition (None = drained)
        # and the matching per-part decode-slot budget — parts always
        # sum to capacity, so non-power-of-two capacities waste nothing
        if mode == "split":
            self._slots: List[int] = list(balanced(capacity, 2))
        else:
            self._slots = [capacity]
        self._parts: List[Optional[_Group]] = [None] * len(self._slots)
        # per-part stall ticks: a part receiving migrated KV holds its
        # slots busy (repro.fleet.migrate charges the transfer here)
        self._stall: List[int] = [0] * len(self._slots)
        # slack-lease books (repro.fleet.lease): slots this part lent
        # away / borrowed in.  The partition budget ``_slots`` never
        # changes under a lease — only the *effective* admission and
        # charge width does — so lent + resident always sum to the
        # budget.  ``_lease_book`` is the owning LeasePlanner (assigned
        # by the fleet engine); a reconfiguration force-revokes through
        # it before re-cutting, so no slots leak across the boundary.
        self._lent: List[int] = [0] * len(self._slots)
        self._borrowed: List[int] = [0] * len(self._slots)
        self._lease_book = None
        self._lease_touched = False
        self._now_tick = 0             # stamped each step; lease accrual

    # -- admission -------------------------------------------------------------

    def submit(self, requests: Sequence[Request], now: int = 0,
               part: Optional[int] = None) -> None:
        """Queue requests; ``part`` records a soft part preference."""
        for r in requests:
            if part is not None:
                r.part_affinity = part
            self.queue.append(r)
        self._arrivals.record(now, len(requests))

    def _admission_scan(self, n_slots: int,
                        part_idx: Optional[int] = None) -> List[Request]:
        """Pop up to ``n_slots`` admissible requests off the queue.

        Part affinity is a *soft* preference: requests affine to a
        different live part are passed over first, but an otherwise idle
        part takes them rather than stranding its slots (work
        conservation — affinity biases placement, never availability).
        The scan is bounded so a deep backlog of foreign-affine
        requests costs O(capacity) churn per part-tick, not O(queue).
        Shared by the jax prefill path and the vectorized engine, so
        both admit byte-identical waves.
        """
        wave: List[Request] = []
        deferred: List[Request] = []
        scan_budget = n_slots + 2 * self.capacity
        while self.queue and len(wave) < n_slots \
                and len(wave) + len(deferred) < scan_budget:
            r = self.queue.popleft()
            aff = r.part_affinity
            if aff is not None and (part_idx is None
                                    or aff >= len(self._slots)):
                aff = r.part_affinity = None   # stale affinity: topology moved
            if aff is not None and aff != part_idx:
                deferred.append(r)
                continue
            r.part_affinity = None
            wave.append(r)
        while deferred and len(wave) < n_slots:
            r = deferred.pop(0)
            r.part_affinity = None
            wave.append(r)
        for r in reversed(deferred):
            self.queue.appendleft(r)
        return wave

    def _prefill_wave(self, n_slots: int, now: int,
                      part_idx: Optional[int] = None) -> Optional[_Group]:
        """Admit up to n_slots queued requests: batch prefill per length."""
        wave = self._admission_scan(n_slots, part_idx)
        if not wave:
            return None
        by_len: Dict[int, List[Request]] = collections.defaultdict(list)
        for r in wave:
            by_len[len(r.prompt)].append(r)
        states, lasts, ordered = [], [], []
        for plen, reqs in sorted(by_len.items()):
            toks = jnp.asarray([r.prompt for r in reqs], jnp.int32)
            logits, st = T.prefill(self.params, {"tokens": toks}, self.cfg,
                                   self.rt, window=self.window)
            nxt = jnp.argmax(logits, axis=-1)
            for r, t in zip(reqs, np.asarray(nxt)):
                r.generated.append(int(t))
                if r.done:
                    r.finish = now
            self.stats.prefill_tokens += plen * len(reqs)
            self.stats.useful_tokens += len(reqs)
            states.append(st)
            lasts.append(nxt[:, None].astype(jnp.int32))
            ordered.extend(reqs)
        return _Group(ordered, su.concat(states),
                      jnp.concatenate(lasts, axis=0))

    # -- decode ----------------------------------------------------------------

    def _tick_group(self, g: _Group, slots: int, now: int,
                    part_idx: int = 0) -> None:
        """One decode step for every live request in the group."""
        live = [i for i, r in enumerate(g.requests) if not r.done]
        if not live:
            return
        logits, new_state = self._decode(self.params, g.state, g.last)
        nxt = jnp.argmax(logits, axis=-1)
        arr = np.asarray(nxt)
        for i, r in enumerate(g.requests):
            if not r.done:
                r.generated.append(int(arr[i]))
                self.stats.useful_tokens += 1
                if r.done:
                    r.finish = now
        g.state = new_state
        g.last = nxt[:, None].astype(jnp.int32)
        self.stats.slot_steps += slots

    def _credit(self, r: Request) -> None:
        """Count a completion exactly once, even across resumed runs."""
        if not getattr(r, "_credited", False):
            r._credited = True
            self.stats.completed += 1

    def _retire(self, g: Optional[_Group]) -> None:
        for r in (g.requests if g else []):
            self._credit(r)

    def _part_done(self, g) -> bool:
        """Is this part drained (empty or all members done)?

        Overridable data-plane hook: the vectorized engine answers from
        its arrays instead of per-request ``generated`` lists.
        """
        return _group_done(g)

    # -- topology --------------------------------------------------------------

    def _reconfigure(self, target: Topology) -> None:
        """Merge all live partitions and re-partition onto ``target``.

        Executes the controller's decision: the KV states of the live
        parts are concatenated and re-sliced along the batch axis into
        parts sized to the target composition's slot budgets (a
        ``(5, 3)`` cut quarantines the long tail on 3 slots), so
        reconfiguration never changes any request's results — only which
        rows decode in lockstep and how many slots each cohort owns.
        """
        # leases are defined against the *current* composition; a new cut
        # invalidates every book entry, so the planner force-revokes both
        # directions (ours and our counterparties') before parts move
        if self._lease_book is not None:
            self._lease_book.force_revoke(self.gid, reason="reconfig",
                                          tick=self._now_tick)
        self._lent = [0] * len(self._slots)
        self._borrowed = [0] * len(self._slots)
        target = self.space.as_topology(target)
        live = [p for p in self._parts if p is not None]
        merged = self._merge_parts(live)
        if len(target) > len(self._parts):
            self.stats.splits += 1
        elif len(target) < len(self._parts):
            self.stats.fuses += 1
        else:
            self.stats.resizes += 1
        # an in-flight KV transfer spans the re-laid-out state: every new
        # part waits out the worst remaining stall (conservative, and a
        # reconfiguration can never shed transfer cost)
        pending_stall = max(self._stall, default=0)
        if len(target) == 1:
            self._parts = [merged]
            self._slots = [self.capacity]
            self._stall = [pending_stall]
            self._lent, self._borrowed = [0], [0]
            return
        parts_idx = self.space.partition(
            list(range(len(merged.requests))), merged.remaining, target,
            self.acfg.regroup_policy)
        self._parts = [self._make_part(merged, ids) for ids in parts_idx]
        self._slots = list(target)
        self._stall = [pending_stall] * len(self._slots)
        self._lent = [0] * len(self._slots)
        self._borrowed = [0] * len(self._slots)

    def _merge_parts(self, live: List[_Group]) -> _Group:
        """Concatenate live parts (in part order) into one batch."""
        if len(live) == 1:
            return live[0]
        return _Group(
            sum((p.requests for p in live), []),
            su.concat([p.state for p in live]),
            jnp.concatenate([p.last for p in live], axis=0))

    def _make_part(self, merged: _Group, ids: List[int]) -> Optional[_Group]:
        """Slice one re-partitioned part out of the merged batch."""
        if not ids:
            return None
        return _Group([merged.requests[i] for i in ids],
                      su.take(merged.state, ids),
                      jnp.take(merged.last, jnp.asarray(ids), axis=0))

    # -- introspection (used by the fleet router and telemetry) ----------------

    @property
    def ways(self) -> int:
        return len(self._parts)

    @property
    def topology(self) -> Topology:
        """The live composition: decode slots per part."""
        return tuple(self._slots)

    @property
    def is_split(self) -> bool:
        return len(self._parts) > 1

    def live_requests(self) -> List[Request]:
        out: List[Request] = []
        for g in self._parts:
            if g is not None:
                out.extend(r for r in g.requests if not r.done)
        return out

    def live_count(self) -> int:
        """In-flight request count — the metrics registry's live-load
        gauge.  Overridden O(capacity) by the vec engine; both answers
        are identical, so per-tick samples match across engines."""
        return len(self.live_requests())

    def part_live(self, i: int) -> List[Request]:
        """Live (not-done) requests currently decoding on part ``i``."""
        g = self._parts[i]
        if g is None:
            return []
        return [r for r in g.requests if not r.done]

    def load(self) -> float:
        """Outstanding decode work: live remaining + queued budgets."""
        return (sum(r.remaining for r in self.live_requests())
                + sum(r.max_new_tokens for r in self.queue))

    # -- slack leases (driven by repro.fleet.lease) ----------------------------

    def effective_slots(self, part: int) -> int:
        """Admission/charge width of ``part`` under the lease books."""
        return self._slots[part] - self._lent[part] + self._borrowed[part]

    def _part_live_n(self, part: int) -> int:
        """Live member count of ``part`` — overridable O(1) in the vec
        engine; both answers are identical, so charges stay bit-equal."""
        return len(self.part_live(part))

    def _slot_charge(self, part: int) -> int:
        """Slot-steps one tick of ``part`` costs.

        Normally the effective width.  After a lease releases while the
        borrowed cohort is still decoding, the part transiently holds
        more live rows than its effective width — those rows still
        occupy physical slots, so the charge follows the occupancy.
        Untouched groups keep the original constant-width charge.
        """
        if not self._lease_touched:
            return self._slots[part]   # books are all-zero: eff == slots
        return max(self.effective_slots(part), self._part_live_n(part))

    def lease_out(self, part: int, n: int) -> None:
        """Lender side of a grant: ``n`` slots leave the resident budget."""
        assert 0 < n and self._lent[part] + n < self._slots[part] \
            + self._borrowed[part], (self.gid, part, n, self._lent)
        self._lent[part] += n
        self._lease_touched = True

    def lease_back(self, part: int, n: int) -> None:
        """Lender side of a release: ``n`` slots return home."""
        assert 0 < n <= self._lent[part], (self.gid, part, n, self._lent)
        self._lent[part] -= n

    def lease_in(self, part: int, n: int) -> None:
        """Borrower side of a grant: ``n`` foreign slots widen the part."""
        assert n > 0, (self.gid, part, n)
        self._borrowed[part] += n
        self._lease_touched = True

    def lease_return(self, part: int, n: int) -> None:
        """Borrower side of a release."""
        assert 0 < n <= self._borrowed[part], \
            (self.gid, part, n, self._borrowed)
        self._borrowed[part] -= n

    # -- cross-group migration (driven by repro.fleet.migrate) -----------------

    def can_insert(self, part: int) -> bool:
        """True when part ``part`` has a free decode slot for a live row."""
        return (0 <= part < len(self._slots)
                and len(self.part_live(part)) < self.effective_slots(part))

    def extract_live(self, req: Request):
        """Remove one in-flight request and return its decode state.

        Returns ``(state_row, last_row)`` — the request's KV slice and
        next-token row, batch axis kept — or ``None`` when the request is
        not live here (already finished or never admitted).  The source
        part keeps its other members untouched; a part drained by the
        extraction frees its slots immediately.
        """
        for i, g in enumerate(self._parts):
            if g is None:
                continue
            for j, r in enumerate(g.requests):
                if r is req and not r.done:
                    rest = [k for k in range(len(g.requests)) if k != j]
                    state_row, rest_state = su.split(g.state, [j], rest)
                    last_row = g.last[j:j + 1]
                    if rest:
                        self._parts[i] = _Group(
                            [g.requests[k] for k in rest], rest_state,
                            jnp.take(g.last, jnp.asarray(rest), axis=0))
                    else:
                        self._parts[i] = None
                    self.stats.migrations_out += 1
                    return state_row, last_row
        return None

    def insert_live(self, req: Request, state, last, part: int,
                    stall: int = 0) -> bool:
        """Graft a migrated in-flight request onto part ``part``.

        The destination part's slots stall for ``stall`` ticks — the KV
        transfer cost — before decoding resumes.  Done-but-unretired
        rows are compacted out first so the part's decode batch never
        outgrows its slot budget.  Returns False (no state change) when
        the part has no free slot.
        """
        if not self.can_insert(part):
            return False
        req.part_affinity = None
        g = self._parts[part]
        if g is not None:
            live = [k for k, r in enumerate(g.requests) if not r.done]
            if len(live) < len(g.requests):
                for r in g.requests:
                    if r.done:
                        self._credit(r)
                g = _Group([g.requests[k] for k in live],
                           su.take(g.state, live),
                           jnp.take(g.last, jnp.asarray(live), axis=0)) \
                    if live else None
        if g is None:
            self._parts[part] = _Group([req], state, last)
        else:
            self._parts[part] = _Group(
                g.requests + [req], su.concat([g.state, state]),
                jnp.concatenate([g.last, last], axis=0))
        self._stall[part] = max(self._stall[part], int(stall))
        self.stats.migrations_in += 1
        return True

    # -- one wall tick -----------------------------------------------------------

    def step(self, dynamic: bool = True, now: int = 0) -> str:
        """Advance the group: admit, maybe reconfigure, maybe decode.

        Returns ``TICKED`` after a decode step, ``RECONF`` after a
        topology change (reconfiguration consumes the call but no decode
        happens), ``IDLE`` when there is nothing to do.
        """
        if self.mode == "fused":
            dynamic = False
        self._now_tick = now
        # each partition admits new work independently the moment it
        # drains, up to its own slot budget; a stalled part's slots are
        # busy receiving migrated KV and admit nothing
        for i, p in enumerate(self._parts):
            if self._stall[i] > 0:
                continue
            if self._part_done(p):
                self._retire(p)
                wave = self._prefill_wave(self.effective_slots(i), now,
                                          part_idx=i)
                self._parts[i] = wave
                if wave is not None and self.obs.enabled:
                    self.obs.emit("admission", gid=self.gid, part=i,
                                  tick=now, n=len(wave.requests),
                                  rids=[r.rid for r in wave.requests])
        live = [p for p in self._parts if p is not None]
        if not live:
            return IDLE
        if self.mode == "dynamic" and dynamic and self.acfg.enabled:
            rem = np.concatenate([p.remaining for p in live])
            fv = FeatureVector.from_group(rem, len(self.queue),
                                          self._arrivals.rate(now),
                                          self.capacity)
            # a group can only be partitioned as far as it has requests
            cap = min(self.space.max_ways, rem.size)
            self.controller.observe(fv, max_ways_now=cap)
            desired = self.controller.state.topology
            if desired != self.topology:
                prev = self.topology
                self._reconfigure(desired)
                if self.obs.enabled:
                    tr = self.controller.state.transitions
                    gain, reason = 0.0, ""
                    if tr and tuple(tr[-1][2]) == tuple(desired):
                        gain, reason = float(tr[-1][3]), tr[-1][4]
                    self.obs.emit("reconfig", gid=self.gid, tick=now,
                                  to=desired, gain=gain, reason=reason,
                                  **{"from": prev})
                return RECONF
        for i, p in enumerate(self._parts):
            if self._stall[i] > 0:
                # the transfer occupies the part's slots for this tick:
                # full slot-step cost, zero useful tokens.  A part left
                # empty by a mid-transfer reconfigure stays blocked but
                # charges nothing — it holds no work to stall
                self._stall[i] -= 1
                if p is not None:
                    self.stats.slot_steps += self._slot_charge(i)
                    self.stats.stall_ticks += 1
                    if self.obs.enabled:
                        self.obs.emit("stall", gid=self.gid, part=i,
                                      tick=now, remaining=self._stall[i])
                continue
            if p is not None:
                self._tick_group(p, self._slot_charge(i), now, part_idx=i)
        self.stats.ticks += 1
        return TICKED

    def finalize(self) -> None:
        """Drain accounting: credit completion for done-but-unretired work.

        Idempotent — groups persist on the engine, so a run may be
        resumed after a ``max_ticks`` cutoff and finalized again.
        """
        for g in self._parts:
            if g is None:
                continue
            for r in g.requests:
                if r.done:
                    self._credit(r)


class ServeEngine:
    """The N=1 fleet: one reconfigurable group behind the original API."""

    def __init__(self, model_cfg: ModelConfig, params,
                 rt: T.Runtime = T.Runtime(production=False, remat=False),
                 amoeba: AmoebaConfig = AmoebaConfig(),
                 capacity: int = 8, window: int = 256,
                 policy: Optional[ReconfigPolicy] = None,
                 model: Optional[LogisticModel] = None):
        self.group = ReconfigurableGroup(
            model_cfg, params, rt=rt, amoeba=amoeba,
            capacity=capacity, window=window, mode="dynamic",
            policy=policy, model=model)
        # aliases: the engine's queue/stats/controller ARE the group's
        self.queue = self.group.queue
        self.stats = self.group.stats
        self.controller = self.group.controller

    # the group owns all engine state; forward reads so there is one copy
    @property
    def cfg(self) -> ModelConfig:
        return self.group.cfg

    @property
    def params(self):
        return self.group.params

    @property
    def rt(self) -> T.Runtime:
        return self.group.rt

    @property
    def acfg(self) -> AmoebaConfig:
        return self.group.acfg

    @property
    def capacity(self) -> int:
        return self.group.capacity

    @property
    def window(self) -> int:
        return self.group.window

    # -- admission -------------------------------------------------------------

    def submit(self, requests: Sequence[Request]) -> None:
        self.group.submit(requests, now=self.stats.ticks)

    # -- main loop ----------------------------------------------------------------

    def run(self, dynamic: bool = True, max_ticks: int = 100_000) -> ServeStats:
        """Drain the queue.  ``dynamic=False`` = fused-only baseline."""
        while self.stats.ticks < max_ticks:
            if self.group.step(dynamic=dynamic, now=self.stats.ticks) == IDLE:
                break
        self.group.finalize()
        return self.stats
