"""Serving engine with AMOEBA dynamic group splitting.

The engine drives real ``prefill``/``decode_step`` calls.  A *group* is the
serving analogue of an SM: the fused group decodes its whole batch in
lockstep, so every tick costs ``capacity`` slot-steps and the batch runs
until its **longest** member finishes — the warp-waits-for-the-last-thread
pathology.  The AMOEBA controller watches the remaining-length divergence
and, past the threshold, splits the group into two halves that admit and
drain **independently** (the paper's SM split; ``warp_regroup`` sorts by
remaining work first, ``direct_split`` cuts in arrival order).  Halves
re-fuse when the divergence signal drops.

The fused/split/re-fuse lifecycle of one pair lives in
:class:`ReconfigurableGroup` — the unit the fleet scheduler
(``repro.fleet``) replicates N times, the serving analogue of the paper's
full chip of independently reconfigurable SM pairs.  :class:`ServeEngine`
is the N=1 case and keeps the original public API.

Costs are counted in slot-steps (decode slots x ticks — the hardware-time
unit): a fused tick costs ``capacity``; two split halves tick concurrently
for the same total.  Useful work is generated tokens, so

    efficiency = useful tokens / slot-steps

is directly comparable across policies, and makespan (ticks) measures
latency.  Prefill is batched per distinct prompt length (no padding, no
cross-request contamination).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AmoebaConfig, ModelConfig
from repro.core.controller import AmoebaController
from repro.core.regroup import POLICIES, divergence_score
from repro.models import transformer as T
from repro.serve import state_utils as su


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    # fleet metadata (defaults keep the original constructor signature)
    tenant: str = "default"
    arrival: int = 0                   # wall tick the request entered the system
    finish: Optional[int] = None       # wall tick the last token was generated

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)

    @property
    def done(self) -> bool:
        return self.remaining <= 0

    @property
    def latency(self) -> Optional[int]:
        return None if self.finish is None else self.finish - self.arrival + 1


@dataclass
class ServeStats:
    ticks: int = 0                 # wall-time units
    slot_steps: int = 0            # decode slots x ticks consumed
    useful_tokens: int = 0
    prefill_tokens: int = 0
    splits: int = 0
    fuses: int = 0
    completed: int = 0

    @property
    def efficiency(self) -> float:
        return self.useful_tokens / max(self.slot_steps, 1)


class _Group:
    """One decode group: live requests + their merged DecodeState."""

    def __init__(self, requests: List[Request], state: T.DecodeState,
                 last_tokens: jnp.ndarray):
        self.requests = requests
        self.state = state
        self.last = last_tokens            # (B, 1) next input token per row

    @property
    def remaining(self) -> np.ndarray:
        return np.array([r.remaining for r in self.requests], np.float64)


def _group_done(g: Optional[_Group]) -> bool:
    return g is None or all(r.done for r in g.requests)


def make_decode_fn(model_cfg: ModelConfig, rt: T.Runtime) -> Callable:
    """One jitted ``decode_step`` closure — the single place its jit options
    live, shared by the N=1 engine, the fleet, and benchmark comparisons."""
    return jax.jit(lambda p, s, t: T.decode_step(p, s, t, model_cfg, rt))


# group step outcomes
TICKED = "ticked"        # one decode wall-tick of progress
RECONF = "reconfig"      # split or fuse happened; no decode this call
IDLE = "idle"            # no live work and nothing admissible from the queue


class ReconfigurableGroup:
    """One reconfigurable pair: a fused group or two independent halves.

    The serving analogue of one AMOEBA SM pair.  It owns its admission
    queue, its :class:`AmoebaController` (split/fuse hysteresis + dwell),
    its split state, and its :class:`ServeStats`.  ``mode`` selects the
    hardware configuration the pair is allowed to take:

    * ``"dynamic"`` — fused by default, splits/fuses on the divergence
      signal (the paper's AMOEBA).
    * ``"fused"``   — never splits (static fused baseline).
    * ``"split"``   — permanently split into two halves (static split
      baseline; the paper's scale-out-only configuration).

    ``step`` advances the pair by at most one wall tick; the caller (the
    N=1 :class:`ServeEngine` or the N-group ``repro.fleet.FleetEngine``)
    owns the wall clock and passes it in as ``now`` so request completion
    times are stamped consistently across groups.
    """

    def __init__(self, model_cfg: ModelConfig, params,
                 rt: T.Runtime = T.Runtime(production=False, remat=False),
                 amoeba: AmoebaConfig = AmoebaConfig(),
                 capacity: int = 8, window: int = 256,
                 mode: str = "dynamic", gid: int = 0,
                 decode_fn: Optional[Callable] = None):
        if mode not in ("dynamic", "fused", "split"):
            raise ValueError(f"unknown group mode {mode!r}")
        if mode == "split" and capacity < 2:
            raise ValueError("mode='split' needs capacity >= 2 "
                             "(each half needs at least one decode slot)")
        self.cfg = model_cfg
        self.params = params
        self.rt = rt
        self.acfg = amoeba
        self.capacity = capacity
        self.window = window
        self.mode = mode
        self.gid = gid
        self.queue: collections.deque[Request] = collections.deque()
        self.stats = ServeStats()
        self.controller = AmoebaController(amoeba)
        self._decode = decode_fn or make_decode_fn(model_cfg, rt)
        self._fused: Optional[_Group] = None
        self._halves: List[Optional[_Group]] = [None, None]
        self._split_mode = (mode == "split")

    # -- admission -------------------------------------------------------------

    def submit(self, requests: Sequence[Request]) -> None:
        self.queue.extend(requests)

    def _prefill_wave(self, n_slots: int, now: int) -> Optional[_Group]:
        """Admit up to n_slots queued requests: batch prefill per length."""
        wave: List[Request] = []
        while self.queue and len(wave) < n_slots:
            wave.append(self.queue.popleft())
        if not wave:
            return None
        by_len: Dict[int, List[Request]] = collections.defaultdict(list)
        for r in wave:
            by_len[len(r.prompt)].append(r)
        states, lasts, ordered = [], [], []
        for plen, reqs in sorted(by_len.items()):
            toks = jnp.asarray([r.prompt for r in reqs], jnp.int32)
            logits, st = T.prefill(self.params, {"tokens": toks}, self.cfg,
                                   self.rt, window=self.window)
            nxt = jnp.argmax(logits, axis=-1)
            for r, t in zip(reqs, np.asarray(nxt)):
                r.generated.append(int(t))
                if r.done:
                    r.finish = now
            self.stats.prefill_tokens += plen * len(reqs)
            self.stats.useful_tokens += len(reqs)
            states.append(st)
            lasts.append(nxt[:, None].astype(jnp.int32))
            ordered.extend(reqs)
        return _Group(ordered, su.concat(states),
                      jnp.concatenate(lasts, axis=0))

    # -- decode ----------------------------------------------------------------

    def _tick_group(self, g: _Group, slots: int, now: int) -> None:
        """One decode step for every live request in the group."""
        live = [i for i, r in enumerate(g.requests) if not r.done]
        if not live:
            return
        logits, new_state = self._decode(self.params, g.state, g.last)
        nxt = jnp.argmax(logits, axis=-1)
        arr = np.asarray(nxt)
        for i, r in enumerate(g.requests):
            if not r.done:
                r.generated.append(int(arr[i]))
                self.stats.useful_tokens += 1
                if r.done:
                    r.finish = now
        g.state = new_state
        g.last = nxt[:, None].astype(jnp.int32)
        self.stats.slot_steps += slots

    def _split_group(self, g: _Group) -> Tuple[_Group, _Group]:
        idx = list(range(len(g.requests)))
        fast, slow = POLICIES[self.acfg.regroup_policy](idx, g.remaining)
        mk = lambda ids: _Group([g.requests[i] for i in ids],
                                su.take(g.state, ids),
                                jnp.take(g.last, jnp.asarray(ids), axis=0))
        return mk(fast), mk(slow)

    def _credit(self, r: Request) -> None:
        """Count a completion exactly once, even across resumed runs."""
        if not getattr(r, "_credited", False):
            r._credited = True
            self.stats.completed += 1

    def _retire(self, g: Optional[_Group]) -> None:
        for r in (g.requests if g else []):
            self._credit(r)

    # -- introspection (used by the fleet router and telemetry) ----------------

    @property
    def is_split(self) -> bool:
        return self._split_mode

    def live_requests(self) -> List[Request]:
        out: List[Request] = []
        for g in ([self._fused] if self._fused else []) \
                + [h for h in self._halves if h]:
            out.extend(r for r in g.requests if not r.done)
        return out

    def load(self) -> float:
        """Outstanding decode work: live remaining + queued budgets."""
        return (sum(r.remaining for r in self.live_requests())
                + sum(r.max_new_tokens for r in self.queue))

    # -- one wall tick -----------------------------------------------------------

    def step(self, dynamic: bool = True, now: int = 0) -> str:
        """Advance the pair: admit, maybe reconfigure, maybe decode.

        Returns ``TICKED`` after a decode step, ``RECONF`` after a
        split/fuse (reconfiguration consumes the call but no decode
        happens), ``IDLE`` when there is nothing to do.
        """
        if self.mode == "fused":
            dynamic = False
        if not self._split_mode:
            if _group_done(self._fused):
                self._retire(self._fused)
                self._fused = self._prefill_wave(self.capacity, now)
                if self._fused is None:
                    return IDLE
            fused = self._fused
            div = divergence_score(fused.remaining)
            want_split = (dynamic and self.acfg.enabled
                          and self.controller.observe(div, fused.remaining)
                          and len(fused.requests) >= 2)
            if want_split:
                a, b = self._split_group(fused)
                self._halves = [a, b]
                self._fused = None
                self._split_mode = True
                self.stats.splits += 1
                return RECONF
            self._tick_group(fused, self.capacity, now)
            self.stats.ticks += 1
            return TICKED
        # split mode: each half admits new work independently the moment it
        # drains; both halves tick concurrently (one wall tick)
        for h in range(2):
            if _group_done(self._halves[h]):
                self._retire(self._halves[h])
                self._halves[h] = self._prefill_wave(self.capacity // 2, now)
        live = [h for h in self._halves if h is not None]
        if not live:
            return IDLE
        if self.mode != "split":
            rem = np.concatenate([h.remaining for h in live])
            div = divergence_score(rem[rem > 0]) if (rem > 0).any() else 0.
            if not self.controller.observe(div, rem):
                # re-fuse: merge surviving requests into one group
                self.stats.fuses += 1
                self._fused = _Group(
                    sum((h.requests for h in live), []),
                    su.concat([h.state for h in live]),
                    jnp.concatenate([h.last for h in live], axis=0))
                self._halves = [None, None]
                self._split_mode = False
                return RECONF
        for h in live:
            self._tick_group(h, self.capacity // 2, now)
        self.stats.ticks += 1
        return TICKED

    def finalize(self) -> None:
        """Drain accounting: credit completion for done-but-unretired work.

        Idempotent — groups persist on the engine, so a run may be
        resumed after a ``max_ticks`` cutoff and finalized again.
        """
        for g in ([self._fused] if self._fused else []) \
                + [h for h in self._halves if h]:
            for r in g.requests:
                if r.done:
                    self._credit(r)


class ServeEngine:
    """The N=1 fleet: one reconfigurable pair behind the original API."""

    def __init__(self, model_cfg: ModelConfig, params,
                 rt: T.Runtime = T.Runtime(production=False, remat=False),
                 amoeba: AmoebaConfig = AmoebaConfig(),
                 capacity: int = 8, window: int = 256):
        self.group = ReconfigurableGroup(
            model_cfg, params, rt=rt, amoeba=amoeba,
            capacity=capacity, window=window, mode="dynamic")
        # aliases: the engine's queue/stats/controller ARE the group's
        self.queue = self.group.queue
        self.stats = self.group.stats
        self.controller = self.group.controller

    # the group owns all engine state; forward reads so there is one copy
    @property
    def cfg(self) -> ModelConfig:
        return self.group.cfg

    @property
    def params(self):
        return self.group.params

    @property
    def rt(self) -> T.Runtime:
        return self.group.rt

    @property
    def acfg(self) -> AmoebaConfig:
        return self.group.acfg

    @property
    def capacity(self) -> int:
        return self.group.capacity

    @property
    def window(self) -> int:
        return self.group.window

    # -- admission -------------------------------------------------------------

    def submit(self, requests: Sequence[Request]) -> None:
        self.group.submit(requests)

    # -- main loop ----------------------------------------------------------------

    def run(self, dynamic: bool = True, max_ticks: int = 100_000) -> ServeStats:
        """Drain the queue.  ``dynamic=False`` = fused-only baseline."""
        while self.stats.ticks < max_ticks:
            if self.group.step(dynamic=dynamic, now=self.stats.ticks) == IDLE:
                break
        self.group.finalize()
        return self.stats
