from repro.serve.engine import (ReconfigurableGroup, Request, ServeEngine,
                                ServeStats)

__all__ = ["ReconfigurableGroup", "Request", "ServeEngine", "ServeStats"]
