"""Batch-dimension surgery on DecodeState pytrees.

DecodeState has three differently-shaped regions:
  * ``pos`` / ``rope_offset``: (B, ...)
  * ``reps``: leaves stacked (R, B, ...) — scan-stacked layer states
  * ``rest``: leaves (B, ...)
so generic tree_map can't slice the batch axis uniformly; these helpers
apply a function to the correct axis per region.
"""
from __future__ import annotations

from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import DecodeState


def _map_batch(state: DecodeState, f0: Callable, f1: Callable) -> DecodeState:
    """f0 applied to batch-leading leaves, f1 to scan-stacked (R, B, ...)"""
    return DecodeState(
        pos=f0(state.pos),
        rope_offset=f0(state.rope_offset),
        reps=jax.tree.map(f1, state.reps),
        rest=jax.tree.map(f0, state.rest),
    )


def take(state: DecodeState, idx: Sequence[int]) -> DecodeState:
    i = jnp.asarray(list(idx), jnp.int32)
    return _map_batch(state,
                      lambda x: jnp.take(x, i, axis=0),
                      lambda x: jnp.take(x, i, axis=1))


def concat(states: List[DecodeState]) -> DecodeState:
    if len(states) == 1:
        return states[0]
    first = states[0]
    return DecodeState(
        pos=jnp.concatenate([s.pos for s in states], axis=0),
        rope_offset=jnp.concatenate([s.rope_offset for s in states], axis=0),
        reps=jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                          *[s.reps for s in states]),
        rest=jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                          *[s.rest for s in states]),
    )


def split(state: DecodeState, take_ids: Sequence[int],
          keep_ids: Sequence[int]):
    """Partition the batch axis into (taken, kept) states.

    The extraction primitive of live migration: the migrating rows
    travel as ``taken`` while ``kept`` stays on the source part.
    """
    return take(state, take_ids), take(state, keep_ids)


def batch_size(state: DecodeState) -> int:
    return int(state.pos.shape[0])
