"""Sharded checkpointing with elastic (re-meshed) restore.

Fault-tolerance contract for the 1000-node deployment:

* **Atomic**: a checkpoint directory is written under ``step_K.tmp`` and
  renamed to ``step_K`` only after every array and the manifest have
  synced — a job killed mid-save can never leave a half-readable latest.
* **Async**: ``save()`` snapshots to host RAM synchronously (cheap) and
  writes to disk on a background thread, overlapping I/O with compute —
  the trainer blocks only if a previous save is still in flight.
* **Elastic restore**: arrays are stored UNsharded (gathered) with the
  PartitionSpec tree alongside; ``restore(mesh=...)`` re-lays them onto
  any mesh, so a job that lost a pod restarts on 256 chips from a 512-chip
  checkpoint (and vice versa).  This is the checkpoint/restart half of the
  AMOEBA story: mesh reconfiguration survives process death.
* **Retention**: ``keep`` newest checkpoints are retained; older ones are
  deleted only after a newer one is durable.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from repro.parallel import resolve

# dtypes numpy can't serialize natively: stored as a same-width integer view
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_", 1)[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Snapshot now, write in the background (unless blocking)."""
        self.wait()                     # one save in flight at a time
        host, dtypes = {}, {}
        for k, v in _flatten_with_paths(tree).items():
            arr = np.asarray(jax.device_get(v))
            if arr.dtype.name in _EXOTIC:
                dtypes[k] = arr.dtype.name
                arr = arr.view(_EXOTIC[arr.dtype.name][1])
            host[k] = arr
        meta = {"step": step, "extra": extra or {}, "dtypes": dtypes,
                "keys": sorted(host.keys()), "time": time.time()}

        def write():
            try:
                tmp = os.path.join(self.directory, f"step_{step}.tmp")
                final = os.path.join(self.directory, f"step_{step}")
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, "arrays.npz"), **host)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.isdir(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:       # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {err!r}")

    def _gc(self) -> None:
        steps = sorted(s for s in (latest_step(self.directory),)
                       if s is not None)
        all_steps = sorted(
            int(n.split("_", 1)[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def restore(self, step: Optional[int] = None, *, like: Any = None,
                pspecs: Any = None, mesh=None,
                batch_size: Optional[int] = None) -> Tuple[int, Any, Dict]:
        """Load (step, tree, extra).

        ``like`` gives the pytree structure; ``pspecs``+``mesh`` re-shard
        each array onto the (possibly different) target mesh — the elastic
        path.  Without a mesh, plain host arrays are returned.
        """
        self.wait()
        if step is None:
            step = latest_step(self.directory)
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        blob = np.load(os.path.join(d, "arrays.npz"))
        flat = {}
        for k in blob.files:
            arr = blob[k]
            name = meta.get("dtypes", {}).get(k)
            if name:
                arr = arr.view(_EXOTIC[name][0])
            flat[k] = arr

        if like is None:
            return step, flat, meta["extra"]

        ref = _flatten_with_paths(like)
        missing = set(ref) - set(flat)
        if missing:
            raise KeyError(f"checkpoint missing arrays: {sorted(missing)[:5]}")
        shardings = None
        if mesh is not None and pspecs is not None:
            shardings = _flatten_with_paths(
                resolve.resolve_tree(pspecs, mesh, batch_size))

        leaves_order = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = flat[key]
            want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
            arr = arr.astype(want_dtype)
            if shardings is not None:
                arr = jax.device_put(arr, shardings[key])
            leaves_order.append(arr)
        treedef = jax.tree.structure(like)
        return step, jax.tree.unflatten(treedef, leaves_order), meta["extra"]
