"""recurrentgemma-9b — hybrid: RG-LRU recurrent blocks + local attention, 2:1.

[arXiv:2402.19427; unverified]  38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; sliding window 2048 on the attention blocks.
Pattern: (rglru, rglru, attn) repeating — the paper's 1 attention per 2
recurrent blocks.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12_288,
    vocab_size=256_000,
    head_dim=256,
    activation="swiglu",
    rope_theta=10_000.0,
    attn_window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
)
