"""qwen2-vl-7b — VLM backbone with M-RoPE; vision frontend is a STUB
(input_specs provides precomputed patch embeddings merged into the stream).

[arXiv:2409.12191; hf]  28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064. M-RoPE sections (temporal, h, w) = (16, 24, 24) of head_dim/2.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18_944,
    vocab_size=152_064,
    head_dim=128,
    activation="swiglu",
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    vision_stub=True,
    max_vision_tokens=1024,
)
