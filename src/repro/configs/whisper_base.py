"""whisper-base — encoder-decoder audio backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings of shape (B, S, d_model)).

[arXiv:2212.04356; unverified]  6L d_model=512 8H (kv=8) d_ff=2048
vocab=51865.  6 encoder layers + 6 decoder layers, cross attention,
sinusoidal positions, non-gated GELU MLP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    head_dim=64,
    activation="gelu",
    encoder_layers=6,
    cross_attention=True,
)
