"""Paper Table 1 — GPGPU-Sim v3.2.2 baseline configuration for the
faithful-reproduction simulator (repro.core.gpusim).

The baseline GPU is a *scale-out* machine: 48 SMs, warp size 32, SIMD
pipeline width 8.  AMOEBA fuses two neighboring SMs into one scale-up SM
(64-wide warp issue, shared L1/coalescer, one NoC router bypassed).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class GPUConfig:
    num_sms: int = 48                 # "Number of Computing Cores" (scale-out SMs)
    num_memory_controllers: int = 8
    mshr_per_core: int = 64
    warp_size: int = 32
    simd_width: int = 8
    threads_per_core: int = 1024
    ctas_per_core: int = 8
    l1_cache_bytes: int = 16 * 1024
    l2_cache_bytes: int = 128 * 1024   # per-core share
    shared_mem_bytes: int = 48 * 1024
    registers_per_core: int = 16384
    constant_cache_bytes: int = 8 * 1024
    texture_cache_bytes: int = 8 * 1024
    warp_scheduler: str = "gto"        # greedy-then-oldest
    memory_scheduler: str = "fr_fcfs"
    mem_clock_mhz: float = 924.0
    core_clock_mhz: float = 700.0
    noc_channel_bits: int = 128
    noc_topology: str = "mesh"
    noc_router_stages: int = 2
    # derived mesh side for SMs+MCs placed on a 2D mesh NoC
    dram_latency_cycles: int = 220
    l2_latency_cycles: int = 32
    l1_latency_cycles: int = 1
    # AMOEBA additions (paper §4.2): +1 cycle on fused L1 access
    fused_l1_extra_cycles: int = 1


PAPER_GPU = GPUConfig()
