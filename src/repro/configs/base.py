"""Config schema for models, shapes, meshes, and the AMOEBA runtime.

Every assigned architecture gets one module in this package exporting
``CONFIG: ModelConfig``. The registry in ``__init__`` maps the dashed public
ids (``--arch deepseek-moe-16b``) onto those modules.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Fine-grained MoE: ``shared`` always-on experts + ``routed`` top-k."""
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    # arctic-style: a dense FFN residual branch that runs in parallel with MoE
    dense_residual: bool = False
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 block hyperparameters."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default: d_model // 16

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank is not None else max(1, d_model // 16)


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block hyperparameters."""
    lru_width: Optional[int] = None   # default: d_model
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None            # default d_model // num_heads
    activation: str = "swiglu"                # swiglu | relu2 | gelu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False                       # qwen2-vl 3-section M-RoPE
    mrope_sections: Sequence[int] = (16, 24, 24)  # fractions of head_dim//2
    attn_window: Optional[int] = None         # local (sliding window) attention
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # block pattern for hybrid archs: tokens 'attn' | 'rglru' | 'ssm';
    # pattern tiles to num_layers.  None => all 'attn' (or all 'ssm' for ssm family)
    block_pattern: Optional[Sequence[str]] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # encoder-decoder (whisper): number of encoder layers; frontend is a stub
    # that consumes precomputed frame embeddings of shape (B, S, d_model).
    encoder_layers: int = 0
    cross_attention: bool = False
    # vlm: precomputed patch embeddings merged into the token stream.
    vision_stub: bool = False
    max_vision_tokens: int = 1024
    dtype: str = "bfloat16"

    # ---- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def layer_kinds(self) -> tuple:
        if self.block_pattern is None:
            kind = "ssm" if self.family == "ssm" else "attn"
            return tuple(kind for _ in range(self.num_layers))
        pat = list(self.block_pattern)
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def uses_rope(self) -> bool:
        """Whisper-style enc-dec stacks use sinusoidal positions, not RoPE."""
        return self.encoder_layers == 0

    @property
    def is_attention_free(self) -> bool:
        return all(k == "ssm" for k in self.layer_kinds)

    @property
    def supports_long_context(self) -> bool:
        """True when attention history is bounded (SSM state / local window)."""
        for k in self.layer_kinds:
            if k == "attn" and self.attn_window is None:
                return False
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytics ---------------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count of the JAX implementation (repro.models)."""
        d, hd = self.d_model, self.resolved_head_dim
        q_dim = self.num_heads * hd
        kv_dim = self.num_kv_heads * hd
        n = 0
        # embeddings
        n += self.vocab_size * d
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer_attn = d * q_dim + 2 * d * kv_dim + q_dim * d
        if self.qk_norm:
            per_layer_attn += 2 * hd
        if self.activation == "swiglu":
            per_layer_ffn = 3 * d * self.d_ff
        else:  # relu2 / gelu: up + down
            per_layer_ffn = 2 * d * self.d_ff
        for kind in self.layer_kinds:
            # pre-norms: ssm blocks are mixer-only (1 norm); others norm1+norm2
            n += d if kind == "ssm" else 2 * d
            if kind == "attn":
                n += per_layer_attn
            elif kind == "rglru":
                cfg = self.rglru or RGLRUConfig()
                w = cfg.lru_width or d
                # in/out proj (2 branches) + conv + gates (2) + lambda params
                n += 2 * d * w + w * d + cfg.conv_width * w + 2 * w * w + 2 * w
            elif kind == "ssm":
                cfg = self.ssm or SSMConfig()
                di = cfg.expand * d
                dtr = cfg.resolved_dt_rank(d)
                n += d * 2 * di            # in_proj (x and z branches)
                n += cfg.d_conv * di       # depthwise conv
                n += di * (dtr + 2 * cfg.d_state)  # x_proj
                n += dtr * di + di         # dt_proj
                n += di * cfg.d_state + di  # A_log, D
                n += di * d                # out_proj
            if kind != "ssm":
                if self.moe is not None:
                    m = self.moe
                    e_p = 3 * d * m.d_ff_expert if self.activation == "swiglu" \
                        else 2 * d * m.d_ff_expert
                    n += (m.num_experts + m.num_shared) * e_p
                    n += d * m.num_experts  # router
                    if m.dense_residual:
                        n += per_layer_ffn
                elif kind in ("attn", "rglru"):
                    # griffin-style blocks: every non-ssm block has an MLP
                    n += per_layer_ffn
        # encoder stack (whisper): same attn+ffn blocks + cross-attn in decoder
        if self.encoder_layers:
            enc = self.encoder_layers * (2 * d + per_layer_attn + per_layer_ffn)
            n += enc + d  # + encoder final norm
            if self.cross_attention:
                n += self.num_layers * (d + per_layer_attn)  # cross-attn + norm
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        e_p = (3 if self.activation == "swiglu" else 2) * self.d_model * m.d_ff_expert
        inactive = (m.num_experts - m.top_k) * e_p * sum(
            1 for k in self.layer_kinds if k != "ssm")
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Input shapes (assigned per-arch set)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES = {s.name: s for s in LM_SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    """long_* decode needs sub-quadratic attention (see DESIGN.md §4)."""
    if shape.name.startswith("long_") and not model.supports_long_context:
        return False
    return True


# ---------------------------------------------------------------------------
# Hardware model (TPU v5e target; the container only dry-runs on CPU)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HardwareConfig:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bandwidth: float = 819e9        # B/s per chip
    ici_bandwidth: float = 50e9         # B/s per link
    hbm_bytes: float = 16 * 2**30       # per chip
    vmem_bytes: float = 128 * 2**20


V5E = HardwareConfig()


# ---------------------------------------------------------------------------
# Runtime / AMOEBA controller configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AmoebaConfig:
    """Paper §4: controller + split/fuse policy knobs.

    ``policy`` selects the repro.control decision stack: ``threshold``
    (fixed-ratio hysteresis), ``predictor`` (logistic inference; needs
    ``predictor_path`` or an injected model), ``oracle`` (true
    slot-cost argmax — the upper bound), ``online`` (predictor with
    periodic refits from the replay buffer).
    """
    enabled: bool = True
    # fraction of divergent warps (mesh level: divergent requests / tokens)
    # above which a fused group splits — paper's fixed-ratio threshold.
    split_threshold: float = 0.25
    # hysteresis: re-fuse when divergence drops below this.
    fuse_threshold: float = 0.10
    # minimum steps between reconfigurations (amortize resharding cost).
    min_phase_steps: int = 8
    regroup_policy: str = "warp_regroup"   # "direct_split" | "warp_regroup"
    predictor_path: Optional[str] = None   # trained coefficient file
    # -- repro.control plane ------------------------------------------------
    policy: str = "threshold"       # threshold | predictor | oracle | online
    max_ways: int = 2               # max parts per group topology
    # heterogeneous compositions: allow unequal part sizes like (5, 3)
    # with per-part split/fuse moves; False pins the balanced
    # power-of-two ladder (1x8/2x4/4x2) with whole-group moves
    hetero: bool = True
    min_gain: float = 0.0           # amortization floor for further splits
    proba_band: float = 0.10        # predictor hysteresis band around 0.5
    oracle_margin: float = 0.02     # oracle's required improvement to move
    refit_every: int = 64           # online: decisions between refits
    replay_capacity: int = 4096     # online: replay buffer size
    label_margin: float = 0.02      # realized-win labeling threshold

    def replace(self, **kw) -> "AmoebaConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class MigrationConfig:
    """Chip-level work stealing and KV-costed request migration.

    Knobs for :class:`repro.fleet.migrate.MigrationPlanner`.  Queue
    steals move *queued* requests from an overflowing group to a
    starving group's best-fitting part (no state travels, only the
    prompt).  Live migrations move *in-flight* requests with their
    decode state; the KV transfer is priced by
    :class:`repro.fleet.migrate.KVTransferCost` — bytes follow from the
    request's sequence length and the model config, the configured
    ``link_bandwidth`` converts them into stall ticks charged to the
    destination part — and the move must clear ``min_gain`` on the same
    normalized move-gain scale the topology lattice uses.
    """
    enabled: bool = False
    # plan cadence in wall ticks when FleetConfig.rebalance_every == 0
    # (when rebalancing is on, plans ride the rebalance tick instead)
    every: int = 4
    steal_threshold: int = 2        # donor queue depth that opens stealing
    max_steals: int = 4             # queue steals per plan tick
    live: bool = True               # allow KV-costed live migrations
    max_live: int = 1               # live migrations per plan tick
    link_bandwidth: float = 4e9     # KV bytes per wall tick over the link
    kv_dtype_bytes: int = 2         # bf16 KV cache entries
    # ship the KV cache int8-quantized (kernels/quantize.py row layout:
    # one int8 code per entry + one fp32 scale per row) — ~4x fewer
    # migration bytes, so live moves amortize at lower bandwidths
    quantized_kv: bool = False
    min_gain: float = 0.02          # amortization floor (move_gain scale)
    # admission spill: when a router-pinned group's expected ticks-to-
    # drain (the planner's pressure view) exceeds this, sticky admissions
    # spill to the least-pressured group instead; 0 disables
    spill_threshold: float = 0.0

    def replace(self, **kw) -> "MigrationConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class LeaseConfig:
    """Slack leases: sub-reconfiguration slot borrowing between parts.

    Knobs for :class:`repro.fleet.lease.LeasePlanner`.  A part with idle
    slots lends them to a sibling part — same group, or an adjacent
    same-chip group over the NoC — for a bounded term: no topology
    move, no dwell clock, no reconfiguration stall.  The borrowed slots
    widen the borrower part's next admission wave; the lender's
    resident budget shrinks by the same amount, so fleet-wide effective
    capacity is conserved.  Each grant must clear ``min_gain`` on the
    same normalized ``move_gain`` scale the topology lattice and the
    migration planner use: gain = borrowed-queue drain minus the
    lender's expected backfill loss over the term, over the lender's
    fused cost.
    """
    enabled: bool = False
    # ticks a lease may run before it expires (the bounded term)
    max_term: int = 16
    # max fraction of a part's slot budget out on lease at once; the
    # planner additionally always keeps >= 1 resident slot per part
    max_frac: float = 0.5
    # lender pressure (expected ticks-to-drain) that force-revokes its
    # outstanding leases early — the lender's own queue heated up
    revoke_threshold: float = 4.0
    max_grants: int = 2             # new grants per plan tick
    min_gain: float = 0.02          # amortization floor (move_gain scale)

    def replace(self, **kw) -> "LeaseConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ClusterConfig:
    """Hierarchical fleet-of-fleets on a 2D chip mesh with tiered links.

    Knobs for ``repro.cluster``: groups sit at 2D coordinates and are
    partitioned into chips (optionally grouped further into nodes);
    moving state between two groups is priced by the *tier* of the pair
    — intra-chip NoC, inter-chip link, or inter-node network — with a
    per-hop latency on top of the bandwidth term (see
    :class:`repro.cluster.TieredTransferCost`).  The
    :class:`repro.cluster.ClusterController` steers each chip's
    split-mix, authorizes cross-chip steals/live-migrations only when
    the tiered cost amortizes, and gathers regions of adjacent groups
    for long-context tail mass (``region_*``).
    """
    groups_per_chip: int = 4
    chips_per_node: Optional[int] = None   # None = every chip on one node
    # per-tier transfer: bytes per wall tick + per-hop latency ticks
    noc_bandwidth: float = 4e9      # intra-chip network-on-chip
    noc_latency: float = 0.0
    link_bandwidth: float = 2e8     # inter-chip link (same node)
    link_latency: float = 1.0
    net_bandwidth: float = 5e7      # inter-node network
    net_latency: float = 4.0
    # A/B baseline: plan with the flat (distance-blind) cost model over
    # one global pool; execution still pays the true tiered costs
    distance_blind: bool = False
    max_cross_steals: int = 2       # cross-chip steals per plan tick
    # region gather: fuse adjacent same-chip groups into one deep
    # logical group while the chip's long-tail mass persists
    region_gather: bool = True
    region_long_frac: float = 0.5   # chip long fraction that opens a region
    region_release_frac: float = 0.2
    region_max_groups: int = 2
    region_dwell: int = 24          # min ticks a region stays gathered

    def replace(self, **kw) -> "ClusterConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class FleetConfig:
    """A serving fleet of N independently reconfigurable pairs.

    The serving analogue of the paper's full chip (24 SM pairs, each free
    to fuse or split on its own): ``num_groups`` pairs behind one request
    router.  ``mode`` pins every pair's allowed configuration — ``fused``
    and ``split`` are the static baselines, ``dynamic`` is AMOEBA.
    """
    num_groups: int = 4
    capacity: int = 8               # decode slots per pair (fused width)
    window: int = 256               # KV window passed to prefill
    # round_robin | least_loaded | length_aware | sticky
    router: str = "least_loaded"
    mode: str = "dynamic"           # dynamic | fused | split
    # tick engine: "object" decodes real tokens through the jitted model
    # (per-part jax calls); "vec" is the struct-of-arrays core
    # (repro.fleet.vec) — same control plane, same summary stats, no
    # model, orders of magnitude faster for scheduling-only sweeps
    engine: str = "object"
    long_threshold: int = 24        # length_aware: predicted-long cutoff
    telemetry_window: int = 256     # rolling-stat window, wall ticks
    # chip-level FleetController: re-evaluate the fleet's split mix every
    # N wall ticks (0 = no chip-wide rebalancing; groups act alone)
    rebalance_every: int = 0
    # cross-group work stealing / live migration (repro.fleet.migrate)
    migrate: MigrationConfig = MigrationConfig()
    # slack leases: bounded slot borrowing below the reconfiguration
    # layer (repro.fleet.lease)
    lease: LeaseConfig = LeaseConfig()
    # reserve a 1-slot quarantine part on this group (exact-composition
    # fleet hint); reserved parts are steal-ineligible for the planner
    quarantine_group: Optional[int] = None
    amoeba: AmoebaConfig = AmoebaConfig()
    # the hierarchical layer above the fleet (repro.cluster): groups on
    # a 2D chip mesh with tiered transfer costs; None = flat fleet
    cluster: Optional[ClusterConfig] = None
    # structured event tracing (repro.obs): "off" keeps summaries
    # bit-identical, "summary" counts events, "full" retains the ring
    # buffer + per-tick metrics for the exporters and decision audit
    obs: str = "off"

    def replace(self, **kw) -> "FleetConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    zero1: bool = True                      # shard optimizer state over data axis
    remat: str = "full"                     # none | full
    micro_steps: int = 1                    # gradient-accumulation microbatches
    grad_compression: bool = False          # int8 DP all-reduce compression
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    """A named factorization of the chip grid (an AMOEBA 'plan')."""
    name: str
    shape: tuple
    axes: tuple

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n
