"""falcon-mamba-7b — attention-free Mamba-1 SSM stack.

[arXiv:2410.05355; unverified]  64L d_model=4096 vocab=65024, ssm_state=16,
expand=2 (d_inner=8192), conv=4, dt_rank=d_model/16=256.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,          # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=65_024,
    head_dim=64,
    activation="swiglu",  # unused
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=True,
)
