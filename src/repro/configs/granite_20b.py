"""granite-20b — code model with MQA (kv=1), 2-matrix GELU MLP.

[arXiv:2405.04324; hf]  52L d_model=6144 48H (GQA kv=1) d_ff=24576
vocab=49152.  (gpt-bigcode-style MQA + non-gated MLP reproduces the 20B
param count; a gated swiglu MLP at d_ff=24576 would be 28B.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24_576,
    vocab_size=49_152,
    head_dim=128,
    activation="gelu",
    rope_theta=10_000.0,
)
