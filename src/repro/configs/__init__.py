"""Architecture registry: dashed public ids -> ModelConfig.

Usage::

    from repro.configs import get_config, ARCH_IDS
    cfg = get_config("deepseek-moe-16b")
    small = get_config("qwen3-14b", reduced=True)   # smoke-test scale
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import (
    LM_SHAPES,
    SHAPES,
    V5E,
    AmoebaConfig,
    HardwareConfig,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    shape_applicable,
)

_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "arctic-480b": "arctic_480b",
    "nemotron-4-340b": "nemotron_4_340b",
    "granite-20b": "granite_20b",
    "qwen3-14b": "qwen3_14b",
    "starcoder2-15b": "starcoder2_15b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-base": "whisper_base",
    "qwen2-vl-7b": "qwen2_vl_7b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg: ModelConfig = mod.CONFIG
    return reduce_config(cfg) if reduced else cfg


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Same family/topology at smoke-test scale (CPU-runnable)."""
    updates = dict(
        num_layers=min(cfg.num_layers, 3 * max(
            1, len(cfg.block_pattern) if cfg.block_pattern else 1)),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads > 1 else 1,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        attn_window=min(cfg.attn_window, 64) if cfg.attn_window else None,
        max_vision_tokens=16,
    )
    if cfg.mrope:
        # keep section proportions but fit the reduced head_dim (32 -> half 16)
        half = 32 // 2
        total = sum(cfg.mrope_sections)
        secs = [max(1, s * half // total) for s in cfg.mrope_sections]
        secs[0] += half - sum(secs)
        updates["mrope_sections"] = tuple(secs)
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64)
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(cfg.ssm, d_state=8)
    if cfg.rglru is not None:
        updates["rglru"] = dataclasses.replace(cfg.rglru, lru_width=128)
    if cfg.encoder_layers:
        updates["encoder_layers"] = 2
    return cfg.replace(**updates)


def arch_shapes(arch: str) -> List[ShapeConfig]:
    """The assigned shape set for this arch (all LM shapes)."""
    return list(LM_SHAPES)


def all_cells() -> List[tuple]:
    """All 40 assigned (arch, shape) cells, with applicability flag."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in arch_shapes(arch):
            cells.append((arch, shape.name, shape_applicable(cfg, shape)))
    return cells


__all__ = [
    "ARCH_IDS", "get_config", "reduce_config", "arch_shapes", "all_cells",
    "ModelConfig", "MoEConfig", "SSMConfig", "RGLRUConfig", "ShapeConfig",
    "SHAPES", "LM_SHAPES", "shape_applicable", "HardwareConfig", "V5E",
    "AmoebaConfig", "TrainConfig", "MeshConfig",
]
