from repro.train.trainer import Trainer, TrainState
from repro.train.stragglers import StragglerMonitor

__all__ = ["Trainer", "TrainState", "StragglerMonitor"]
