"""Fault-tolerant trainer.

One class drives every assigned architecture at every scale: bare CPU for
the smoke tests, the 16x16 / 2x16x16 production meshes for the dry-run.
The step function is a single jit (loss -> grad -> clip -> AdamW) with
in/out shardings resolved from the model's PartitionSpec tree; donation
keeps params/opt-state memory flat.

Fault tolerance (the 1000-node contract):
* periodic **async atomic checkpoints** (repro.ckpt) of params + optimizer
  + data-iterator step; ``train()`` auto-resumes from the newest valid one,
  and a ``failure_injector`` hook lets tests kill arbitrary steps to prove
  the resume path is exact (same data order, same loss curve).
* a **StragglerMonitor** flags slow steps for the control plane.
* **elastic restarts**: checkpoints are mesh-agnostic, so a resume may use
  a different plan (repro.ckpt re-lays arrays out; the AMOEBA controller
  picks the plan).

Divergence telemetry (MoE expert imbalance / dropped-token fraction) is fed
to the AMOEBA controller each step when one is attached — the training-side
analogue of warp divergence.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core.controller import AmoebaController
from repro.core.regroup import moe_divergence
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.optim import adamw_pspecs, adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import AdamWState, global_norm
from repro.parallel import resolve, shardctx
from repro.train.stragglers import StragglerMonitor


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    data_step: jnp.ndarray       # () int32 — exact-resume data cursor
    residuals: Any = None        # grad-compression error feedback


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class StepMetrics:
    step: int
    loss: float
    grad_norm: float
    lr: float
    dt: float
    divergence: float = 0.0


class Trainer:
    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 tcfg: TrainConfig = TrainConfig(),
                 rt: Optional[T.Runtime] = None, mesh=None,
                 controller: Optional[AmoebaController] = None,
                 data_cfg: DataConfig = DataConfig(),
                 state_dtype: Optional[str] = None):
        self.model_cfg = model_cfg
        self.shape = shape
        self.tcfg = tcfg
        self.rt = rt or T.Runtime(production=mesh is not None,
                                  remat=tcfg.remat != "none")
        self.mesh = mesh
        self.controller = controller
        self.data = SyntheticLM(model_cfg, shape, data_cfg)
        self.state_dtype = state_dtype
        self._pspecs = None
        self._step_fn = None

    # -- state ----------------------------------------------------------------

    def _fresh_state(self, seed: int) -> TrainState:
        params, pspecs = T.init_model(jax.random.PRNGKey(seed),
                                      self.model_cfg)
        self._pspecs = pspecs
        opt = adamw_init(params, self.state_dtype)
        residuals = None
        if self.tcfg.grad_compression:
            residuals = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return TrainState(params=params, opt=opt,
                          data_step=jnp.zeros((), jnp.int32),
                          residuals=residuals)

    def init_state(self, seed: int = 0) -> TrainState:
        with shardctx.use_mesh(self.mesh):
            state = self._fresh_state(seed)
            if self.mesh is not None:
                shard = resolve.resolve_tree_for(
                    jax.eval_shape(lambda: self._fresh_state(seed)),
                    self.state_pspecs(), self.mesh)
                state = jax.tree.map(jax.device_put, state, shard)
        return state

    def state_pspecs(self) -> TrainState:
        if self._pspecs is None:
            _, self._pspecs = T.model_pspecs(self.model_cfg)
        residual_specs = self._pspecs if self.tcfg.grad_compression else None
        return TrainState(params=self._pspecs,
                          opt=adamw_pspecs(self._pspecs),
                          data_step=P(), residuals=residual_specs)

    def _restore_template(self) -> TrainState:
        return jax.eval_shape(lambda: self._fresh_state(self.tcfg.seed))

    # -- the step ----------------------------------------------------------------

    def make_step_body(self):
        """The raw (unjitted) step function — the dry-run re-jits it with
        explicit in/out shardings."""
        cfg, rt, tcfg = self.model_cfg, self.rt, self.tcfg

        def step_fn(state: TrainState, batch):
            if tcfg.micro_steps > 1:
                # gradient accumulation: scan over microbatches keeps the
                # activation peak to one microbatch's worth
                k = tcfg.micro_steps

                def micro(carry, mb):
                    gacc, lacc, macc = carry
                    (l, m), g = jax.value_and_grad(
                        lambda p: T.loss_fn(p, mb, cfg, rt),
                        has_aux=True)(state.params)
                    gacc = jax.tree.map(
                        lambda a, b: a + b.astype(a.dtype), gacc, g)
                    if "expert_load" in m:
                        macc = {"expert_load":
                                macc["expert_load"] + m["expert_load"],
                                "dropped_frac":
                                macc["dropped_frac"] + m["dropped_frac"]}
                    return (gacc, lacc + l, macc), None

                mbs = jax.tree.map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                    batch)
                # accumulate in the params' storage dtype (bf16): an f32
                # accumulator tree both doubles gradient memory and trips
                # the SPMD partitioner when combined with the FSDP gather
                # inside the scan (dynamic-slice verifier failure)
                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, p.dtype), state.params)
                m0 = {}
                if cfg.moe is not None:
                    m0 = {"expert_load":
                          jnp.zeros((cfg.moe.num_experts,), jnp.float32),
                          "dropped_frac": jnp.zeros((), jnp.float32)}
                (gsum, lsum, msum), _ = jax.lax.scan(
                    micro, (g0, jnp.zeros((), jnp.float32), m0), mbs)
                grads = jax.tree.map(lambda g: g / k, gsum)
                loss = lsum / k
                metrics = {kk: v / k for kk, v in msum.items()}
            else:
                def loss_of(p):
                    return T.loss_fn(p, batch, cfg, rt)

                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(state.params)
            gnorm = global_norm(grads)
            gscale = jnp.minimum(1.0, tcfg.grad_clip
                                 / jnp.maximum(gnorm, 1e-9))
            new_res = state.residuals
            if tcfg.grad_compression:
                # int8 wire-format roundtrip with error feedback: the
                # numerics of the compressed DP all-reduce (see
                # repro.parallel.compression for the collective itself)
                from repro.parallel import compression as C
                flat_g, td = jax.tree.flatten(grads)
                flat_r = td.flatten_up_to(state.residuals)
                gs, rs = [], []
                for g, r in zip(flat_g, flat_r):
                    gf = g.astype(jnp.float32) + r
                    q, s, shp = C.compress_leaf(gf)
                    deq = C.decompress_leaf(q, s, shp)
                    gs.append(deq.astype(g.dtype))
                    rs.append(gf - deq)
                grads = td.unflatten(gs)
                new_res = td.unflatten(rs)
            lr = cosine_schedule(state.opt.step, base_lr=tcfg.learning_rate,
                                 warmup=tcfg.warmup_steps,
                                 total=tcfg.total_steps)
            params, opt = adamw_update(
                state.params, grads, state.opt, lr=lr,
                weight_decay=tcfg.weight_decay, grad_scale=gscale)
            new_state = TrainState(params=params, opt=opt,
                                   data_step=state.data_step + 1,
                                   residuals=new_res)
            out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
            if "expert_load" in metrics:
                out["expert_load"] = metrics["expert_load"]
                out["dropped_frac"] = metrics["dropped_frac"]
            return new_state, out

        return step_fn

    def step_fn(self):
        if self._step_fn is None:
            self._step_fn = jax.jit(self.make_step_body(),
                                    donate_argnums=(0,))
        return self._step_fn

    def place_batch(self, batch: Dict[str, np.ndarray]):
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            spec = resolve.resolve_spec(P("batch"), self.mesh, v.shape[0])
            out[k] = jax.device_put(jnp.asarray(v),
                                    NamedSharding(self.mesh, spec))
        return out

    # -- the loop -------------------------------------------------------------------

    def train(self, steps: int, state: Optional[TrainState] = None,
              ckpt=None, log_every: int = 10,
              failure_injector: Optional[Callable[[int], bool]] = None,
              monitor: Optional[StragglerMonitor] = None
              ) -> Dict[str, Any]:
        """Run up to ``steps`` optimizer steps with checkpoint/restart.

        Returns {"state", "history", "monitor", "resumes"}.
        """
        monitor = monitor or StragglerMonitor()
        history: List[StepMetrics] = []
        resumes = 0

        if state is None:
            restored = False
            if ckpt is not None:
                try:
                    _, state, _ = ckpt.restore(
                        like=self._restore_template(),
                        pspecs=self.state_pspecs() if self.mesh else None,
                        mesh=self.mesh)
                    restored = True
                    resumes += 1
                except FileNotFoundError:
                    pass
            if not restored:
                state = self.init_state(self.tcfg.seed)

        fn = self.step_fn()
        with shardctx.use_mesh(self.mesh):
            k = int(jax.device_get(state.data_step))
            while k < steps:
                try:
                    if failure_injector is not None and failure_injector(k):
                        raise SimulatedFailure(f"injected failure at step {k}")
                    batch = self.place_batch(self.data.batch_at(k))
                    monitor.start()
                    state, out = fn(state, batch)
                    loss = float(jax.device_get(out["loss"]))
                    dt = monitor.stop(k)
                    div = 0.0
                    if "expert_load" in out:
                        div = moe_divergence(
                            np.asarray(jax.device_get(out["expert_load"])))
                        if self.controller is not None:
                            self.controller.observe(div)
                    history.append(StepMetrics(
                        step=k, loss=loss,
                        grad_norm=float(jax.device_get(out["grad_norm"])),
                        lr=float(jax.device_get(out["lr"])), dt=dt,
                        divergence=div))
                    k += 1
                    if ckpt is not None and k % self.tcfg.checkpoint_every == 0:
                        ckpt.save(k, state, extra={"k": k})
                except SimulatedFailure:
                    # crash/restart path: reload newest durable checkpoint
                    if ckpt is None:
                        raise
                    ckpt.wait()
                    try:
                        _, state, _ = ckpt.restore(
                            like=self._restore_template(),
                            pspecs=self.state_pspecs() if self.mesh else None,
                            mesh=self.mesh)
                    except FileNotFoundError:
                        state = self.init_state(self.tcfg.seed)
                    k = int(jax.device_get(state.data_step))
                    resumes += 1
            if ckpt is not None:
                ckpt.save(steps, state, extra={"k": steps}, blocking=True)
        return {"state": state, "history": history, "monitor": monitor,
                "resumes": resumes}
