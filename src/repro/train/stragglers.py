"""Straggler detection for the synchronous training loop.

At 1000-node scale one slow host gates every step (synchronous SPMD).  The
monitor tracks a robust EWMA of step wall-time and flags steps beyond
``threshold`` x the moving estimate.  On a real fleet the flag feeds the
control plane (re-shard input files away from the slow host, evict it, or let
the elastic restore shrink the mesh — repro.ckpt handles that path); here
it records and reports, and the trainer exposes the hook.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x EWMA that counts as a straggle
    alpha: float = 0.1              # EWMA factor
    warmup: int = 3                 # ignore compile/first steps
    on_straggle: Optional[Callable[[int, float, float], None]] = None

    ewma: float = 0.0
    seen: int = 0
    events: List[dict] = field(default_factory=list)
    _t0: float = 0.0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        self.seen += 1
        if self.seen <= self.warmup:
            self.ewma = dt
            return dt
        if dt > self.threshold * self.ewma and self.ewma > 0:
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma})
            if self.on_straggle:
                self.on_straggle(step, dt, self.ewma)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return dt

    @property
    def straggle_rate(self) -> float:
        denom = max(self.seen - self.warmup, 1)
        return len(self.events) / denom
