"""Straggler detection for the synchronous training loop.

At 1000-node scale one slow host gates every step (synchronous SPMD).  The
monitor tracks a robust EWMA of step wall-time and flags steps beyond
``threshold`` x the moving estimate.  On a real fleet the flag feeds the
control plane (re-shard input files away from the slow host, evict it, or let
the elastic restore shrink the mesh — repro.ckpt handles that path); here
it records and reports, and the trainer exposes the hook.

The *decision* of when a straggling phase warrants a mesh reconfiguration
is not hand-rolled here: each step's excess-time fraction (how much of
the step ran beyond the EWMA — the training analogue of the divergent
slot fraction) feeds a shared :class:`repro.control.GroupController`
running the same :class:`~repro.control.ThresholdPolicy` hysteresis the
serving engine uses.  ``recommend_scale_out`` is True while the
controller holds the split state: sustained straggling past the
threshold, with dwell so one slow step never triggers a reshard.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.control import (ConfigSpace, FeatureVector, GroupController,
                           ThresholdPolicy)


@dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x EWMA that counts as a straggle
    alpha: float = 0.1              # EWMA factor
    warmup: int = 3                 # ignore compile/first steps
    dwell: int = 4                  # controller dwell between recommendations
    on_straggle: Optional[Callable[[int, float, float], None]] = None

    ewma: float = 0.0
    seen: int = 0
    events: List[dict] = field(default_factory=list)
    _t0: float = 0.0

    def __post_init__(self):
        # excess fraction 1 - ewma/dt crosses this exactly when
        # dt > threshold * ewma — the same trigger as the event log,
        # but run through the shared hysteresis+dwell state machine
        split_at = 1.0 - 1.0 / max(self.threshold, 1.0 + 1e-9)
        self.controller = GroupController(
            policy=ThresholdPolicy(split_threshold=split_at,
                                   fuse_threshold=0.5 * split_at),
            space=ConfigSpace(capacity=2, max_ways=2),
            dwell=self.dwell)

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        self.seen += 1
        if self.seen <= self.warmup:
            self.ewma = dt
            return dt
        if dt > self.threshold * self.ewma and self.ewma > 0:
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma})
            if self.on_straggle:
                self.on_straggle(step, dt, self.ewma)
        excess = max(0.0, 1.0 - self.ewma / dt) if dt > 0 else 0.0
        self.controller.observe(FeatureVector(divergence=excess))
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return dt

    @property
    def recommend_scale_out(self) -> bool:
        """True while sustained straggling says: shrink/re-split the mesh."""
        return self.controller.state.split

    @property
    def straggle_rate(self) -> float:
        denom = max(self.seen - self.warmup, 1)
        return len(self.events) / denom
