"""Cross-group work stealing and KV-costed request migration.

AMOEBA's chip-level scheduler exists so reconfigurable cores never idle
while work queues elsewhere overflow; the fleet analogue is a group whose
drained split part can only backfill from its *own* queue while a
neighbor's queue — and p99 — blows up.  This module is the chip-level
work mover: each rebalance tick a :class:`MigrationPlanner` inspects
every group's queue depth, drain rate, and remaining-length mix, and
emits :class:`Migration` plans of two kinds:

* **queue steals** — a queued request moves from an overflowing group to
  a starving group's best-fitting part.  Nothing but the prompt travels,
  so a steal is free; the only constraints are the donor's backlog, the
  recipient's free slots, and reserved (quarantine) parts being
  steal-ineligible.

* **live migrations** — an in-flight request moves *with its decode
  state*.  The KV transfer is not free: :class:`KVTransferCost` prices
  the request's cache (bytes follow from its sequence length and the
  model config) over a configurable link bandwidth, and the resulting
  stall ticks are charged to the destination part, whose slots sit busy
  receiving state before decoding resumes.  A live move must clear the
  same normalized amortization bar the topology lattice applies to its
  moves: the predicted slot-step saving (donor part finishes earlier)
  minus the added cost (destination slots spent on stall + drain),
  normalized by the donor group's fused cost exactly like
  :meth:`repro.control.ConfigSpace.move_gain`, must exceed
  ``MigrationConfig.min_gain``.  Zero link bandwidth therefore disables
  live migration outright (infinite stall never amortizes) while steals
  keep flowing — the Langhammer soft-GPGPU lesson that dynamic
  reallocation must be cost-aware to pay off.

The planner is pure decision logic over a small group *protocol* —
``queue``, ``topology``, ``part_live(i)``, ``stats``, ``can_insert``,
``extract_live``, ``insert_live``, ``submit(..., part=)`` — implemented
by :class:`repro.serve.engine.ReconfigurableGroup` and by lightweight
fakes in the test suite.  Execution (the actual KV-slice surgery via
``repro.serve.state_utils``) happens in :meth:`MigrationPlanner.execute`,
invoked by ``FleetEngine.run`` between ticks with the plans the
``FleetController`` gathered on its rebalance tick.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.configs.base import MigrationConfig, ModelConfig
from repro.kernels.quantize import INT8_CODE_BYTES, INT8_SCALE_BYTES
from repro.obs.events import NULL_LOG
from repro.serve.engine import Request

# (group index, part index); part None = no part preference
Addr = Tuple[int, Optional[int]]


def charge_ticks(stall: float) -> int:
    """Integer stall charge for a fractional transfer time.

    The wall tick is the cost quantum, so a transfer that takes any
    fraction of a tick past a whole boundary occupies the destination
    for the *next* whole tick — ``int()`` truncation billed a 2.9-tick
    transfer as 2, systematically under-pricing live migrations and
    flipping amortization vetoes near the margin.  Sub-tick transfers
    stay free (the ``TieredTransferCost`` rule: a NoC hop hides behind
    the decode tick).  Infinite stalls must be vetoed before charging.
    """
    if math.isinf(stall):
        raise ValueError("infinite stall must be vetoed, not charged")
    if stall < 1.0:
        return 0
    return int(math.ceil(stall - 1e-9))


def fit_part(topology: Sequence[int], is_long: bool,
             free: Optional[Sequence[int]] = None) -> Optional[int]:
    """The length-aware part choice shared by admissions and steals.

    Predicted-long requests go to the narrowest eligible part (the
    tail-quarantine slice wastes the fewest slot-steps), short requests
    to the widest (the lockstep drain).  ``free`` restricts candidates
    to parts with free slots; without it every part is eligible (the
    router's soft-affinity case).
    """
    cands = [i for i in range(len(topology))
             if free is None or free[i] > 0]
    if not cands:
        return None
    if is_long:
        return min(cands, key=lambda i: (topology[i], i))
    return max(cands, key=lambda i: (topology[i], -i))


# -- the transfer-cost model ---------------------------------------------------

@dataclass(frozen=True)
class KVTransferCost:
    """Bytes-on-the-wire model for moving one request's decode state.

    ``bytes = f(seq_len, model_cfg)``: every attention layer contributes
    K and V rows (``2 * num_kv_heads * head_dim``) per cached position —
    capped by the KV window and any sliding-window attention — and every
    recurrent layer (SSM / RG-LRU) contributes its constant-size state.
    ``link_bandwidth`` (bytes per wall tick) converts bytes into the
    stall ticks charged to the destination part; a non-positive
    bandwidth prices every transfer at infinity, which makes every live
    migration fail its amortization check.

    ``quantized`` ships the cache in the int8 wire layout of
    ``repro.kernels.quantize`` — one int8 code per entry plus one fp32
    scale per row — so transfer bytes drop ~4x against bf16 and live
    moves that a given bandwidth vetoed start amortizing.
    """
    # defaults mirror MigrationConfig — the planner always rebuilds this
    # from the config, so the config is the single source of truth
    link_bandwidth: float = MigrationConfig.link_bandwidth
    dtype_bytes: int = MigrationConfig.kv_dtype_bytes
    quantized: bool = MigrationConfig.quantized_kv

    def _cache_bytes(self, rows: int, row_width: int) -> int:
        """Bytes for ``rows`` cache-dtype rows of ``row_width`` entries."""
        if self.quantized:
            return rows * (row_width * INT8_CODE_BYTES + INT8_SCALE_BYTES)
        return rows * row_width * self.dtype_bytes

    def kv_bytes(self, seq_len: int, model_cfg: ModelConfig,
                 window: Optional[int] = None) -> int:
        cached = max(int(seq_len), 1)
        if window is not None:
            cached = min(cached, int(window))
        d = model_cfg.resolved_head_dim
        total = 0
        for kind in model_cfg.layer_kinds:
            if kind == "attn":
                span = cached if model_cfg.attn_window is None \
                    else min(cached, model_cfg.attn_window)
                # K and V: one cache-dtype row of num_kv_heads * d per
                # cached position each
                total += self._cache_bytes(2 * span,
                                           model_cfg.num_kv_heads * d)
            elif kind == "ssm":
                ssm = model_cfg.ssm
                if ssm is not None:
                    # SSMState: conv tail (d_conv-1, d_inner) in the
                    # cache dtype, scan state h in float32
                    di = ssm.expand * model_cfg.d_model
                    total += self._cache_bytes(ssm.d_conv - 1, di)
                    total += di * ssm.d_state * 4
            elif kind == "rglru":
                rg = model_cfg.rglru
                w = (rg.lru_width if rg and rg.lru_width
                     else model_cfg.d_model)
                conv = rg.conv_width if rg else 4
                # RGLRUState: conv tail (conv_width-1, W) in the cache
                # dtype, hidden h (W,) in float32
                total += self._cache_bytes(conv - 1, w)
                total += w * 4
        return total

    def stall_ticks(self, seq_len: int, model_cfg: ModelConfig,
                    window: Optional[int] = None,
                    src: Optional[int] = None,
                    dst: Optional[int] = None) -> float:
        """Wall ticks the destination part stalls for one transfer.

        ``src``/``dst`` (group indices) are accepted so distance-aware
        subclasses (``repro.cluster.TieredTransferCost``) can price by
        the tier of the pair; the flat model ignores them.
        """
        if self.link_bandwidth <= 0:
            return math.inf
        return math.ceil(
            self.kv_bytes(seq_len, model_cfg, window) / self.link_bandwidth)


# -- plans ---------------------------------------------------------------------

STEAL = "steal"
LIVE = "live"


@dataclass
class Migration:
    """One planned move: a queued steal or a live KV-costed migration."""
    kind: str                      # STEAL | LIVE
    request: Request
    src: Addr
    dst: Addr
    stall: int = 0                 # destination stall ticks (LIVE only)
    gain: float = 0.0              # normalized amortization gain (LIVE only)

    def as_dict(self) -> Dict:
        return {"kind": self.kind, "rid": self.request.rid,
                "src": list(self.src), "dst": list(self.dst),
                "stall": self.stall, "gain": round(self.gain, 4)}


# -- the planner ---------------------------------------------------------------

@dataclass
class _GroupView:
    """One plan tick's snapshot of a group's pressure."""
    gi: int
    queue_len: int
    free: List[int]                # free decode slots per part
    drain_rate: float              # completions per tick since last plan
    topology: Tuple[int, ...]

    @property
    def total_free(self) -> int:
        return sum(self.free)


class MigrationPlanner:
    """Chip-level work-stealing and migration policy.

    ``plan`` ranks donors by expected time-to-drain (queue depth over
    recent drain rate — a deep queue on a fast group is less urgent than
    the same queue on a slow one) and matches their excess against
    starving groups' free slots, fitting each stolen request to the
    recipient part the length-aware router would pick (predicted-long
    requests to the narrowest free part, short to the widest).  Live
    migrations then move the worst tail request of a crowded part onto
    an idle part elsewhere when the amortization check clears.  Reserved
    parts (quarantine slices the :class:`repro.control.FleetController`
    pinned via exact-composition hints) are never a steal or migration
    destination.
    """

    def __init__(self, cfg: MigrationConfig, model_cfg: ModelConfig,
                 long_threshold: int = 24, window: Optional[int] = None,
                 cost: Optional[KVTransferCost] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.long_threshold = long_threshold
        self.window = window
        self.cost = cost if cost is not None else KVTransferCost(
            link_bandwidth=cfg.link_bandwidth,
            dtype_bytes=cfg.kv_dtype_bytes,
            quantized=cfg.quantized_kv)
        # counters surfaced in FleetTelemetry.summary
        self.plan_ticks = 0
        self.planned = 0
        self.steals = 0
        self.live_migrations = 0
        self.rejected_amortization = 0
        self.stall_ticks_charged = 0
        self._drain: Dict[int, Tuple[int, int]] = {}   # gi -> (tick, done)
        # expected ticks-to-drain per group, refreshed each plan tick —
        # the pressure view routers consult for admission spill
        self._pressure: Dict[int, float] = {}
        # event stream (repro.obs); the owning engine assigns its log
        # after construction so steal/migrate executions are traced
        self.obs = NULL_LOG

    # -- telemetry -------------------------------------------------------------

    def summary(self) -> Dict:
        return {
            "plan_ticks": self.plan_ticks,
            "planned": self.planned,
            "steals": self.steals,
            "live_migrations": self.live_migrations,
            "rejected_amortization": self.rejected_amortization,
            "stall_ticks_charged": self.stall_ticks_charged,
        }

    # -- the pressure view (router admission spill) ----------------------------

    def pressure(self) -> Dict[int, float]:
        """Expected ticks-to-drain per group, as of the last plan tick.

        The same donor-urgency signal :meth:`_plan_steals` ranks by
        (queue depth over recent drain rate), exported so routers can
        spill *admissions* off a hot group before its queue overflows —
        steals then only handle the residual.  Empty until the first
        plan tick.
        """
        return self._pressure

    # -- snapshots -------------------------------------------------------------

    def _drain_rate(self, tick: int, gi: int, completed: int) -> float:
        prev = self._drain.get(gi)
        self._drain[gi] = (tick, completed)
        if prev is None or tick <= prev[0]:
            return 0.0
        return (completed - prev[1]) / (tick - prev[0])

    def _view(self, tick: int, gi: int, g,
              reserved: Set[Addr]) -> _GroupView:
        topo = tuple(getattr(g, "topology", (1,)))
        # free slots are measured against the lease-adjusted width: a
        # lent slot is not available to steals, a borrowed one is
        eff = getattr(g, "effective_slots", None)
        free = []
        for i, slots in enumerate(topo):
            if (gi, i) in reserved:
                free.append(0)     # quarantine slice: steal-ineligible
            else:
                width = eff(i) if eff is not None else slots
                free.append(max(width - len(g.part_live(i)), 0))
        return _GroupView(gi=gi, queue_len=len(g.queue), free=free,
                          drain_rate=self._drain_rate(
                              tick, gi, g.stats.completed),
                          topology=topo)

    # -- part fitting ----------------------------------------------------------

    def _fit_part(self, view: _GroupView, req: Request) -> Optional[int]:
        return fit_part(view.topology,
                        req.max_new_tokens >= self.long_threshold,
                        free=view.free)

    # -- planning --------------------------------------------------------------

    def plan(self, tick: int, groups: Sequence,
             reserved: Optional[Iterable[Addr]] = None) -> List[Migration]:
        """One rebalance tick's worth of migration plans."""
        self.plan_ticks += 1
        res: Set[Addr] = set(reserved or ())
        views = [self._view(tick, gi, g, res)
                 for gi, g in enumerate(groups)]
        self._pressure = {v.gi: v.queue_len / max(v.drain_rate, 1e-3)
                          if v.queue_len else 0.0 for v in views}
        plans = self._plan_steals(views, groups)
        if self.cfg.live:
            plans += self._plan_live(views, groups, res)
        self.planned += len(plans)
        return plans

    def _recip_priority(self, v: _GroupView) -> Tuple:
        """Recipient ordering key (higher first): most free slots.

        Overridable — the cluster planner boosts gathered region groups
        so tail work lands on the slices reserved for it.
        """
        return (v.total_free,)

    def _plan_steals(self, views: List[_GroupView],
                     groups: Sequence) -> List[Migration]:
        thresh = self.cfg.steal_threshold
        # donors by urgency: expected ticks-to-drain of the backlog
        donors = sorted(
            (v for v in views if v.queue_len > thresh),
            key=lambda v: v.queue_len / max(v.drain_rate, 1e-3),
            reverse=True)
        # recipients starve: free slots, a queue short of filling them,
        # and — so no group is donor and recipient in one plan tick,
        # which would just swap requests in circles — no steal-worthy
        # backlog of their own
        recips = sorted(
            (v for v in views
             if v.total_free > 0 and v.queue_len < v.total_free
             and v.queue_len <= thresh),
            key=self._recip_priority, reverse=True)
        plans: List[Migration] = []
        budget = self.cfg.max_steals
        for donor in donors:
            if budget <= 0:
                break
            queue = list(groups[donor.gi].queue)
            # steal from the tail: the donor keeps FIFO order for the
            # requests it has already promised earliest service
            queue.reverse()
            for recip in recips:
                if recip.gi == donor.gi:
                    continue
                while (budget > 0 and queue
                       and donor.queue_len > thresh
                       and recip.total_free > 0):
                    # peek before popping: a victim this recipient can't
                    # place stays available for the other recipients
                    victim = queue[0]
                    part = self._fit_part(recip, victim)
                    if part is None:
                        break
                    queue.pop(0)
                    plans.append(Migration(STEAL, victim,
                                           src=(donor.gi, None),
                                           dst=(recip.gi, part)))
                    recip.free[part] -= 1
                    donor.queue_len -= 1
                    budget -= 1
        return plans

    def _plan_live(self, views: List[_GroupView], groups: Sequence,
                   reserved: Set[Addr]) -> List[Migration]:
        plans: List[Migration] = []
        budget = self.cfg.max_live
        for donor in views:
            if budget <= 0:
                break
            g = groups[donor.gi]
            for pi, slots in enumerate(donor.topology):
                if budget <= 0:
                    break
                live = g.part_live(pi)
                if len(live) < 2:
                    continue       # a lone request gains nothing by moving
                rem = sorted((r.remaining for r in live), reverse=True)
                victim = max(live, key=lambda r: r.remaining)
                m = self._best_live_move(donor, pi, slots, rem, victim,
                                         views, reserved)
                if m is not None:
                    plans.append(m)
                    # the chosen part is no longer idle for later plans
                    views[m.dst[0]].free[m.dst[1]] = 0
                    budget -= 1
        return plans

    def _best_live_move(self, donor: _GroupView, pi: int, slots: int,
                        rem: List[float], victim: Request,
                        views: List[_GroupView],
                        reserved: Set[Addr]) -> Optional[Migration]:
        """Pick the destination maximizing the amortized gain, or None.

        The gain is priced exactly like a lattice move
        (:meth:`repro.control.ConfigSpace.move_gain`): predicted
        slot-step saving of the move, normalized by the donor group's
        fused drain cost, against the same ``min_gain`` floor.  Here the
        "move" spans two groups: the donor part sheds its longest tail
        (its cost drops from ``slots * max`` to ``slots * second_max``)
        while the destination part — idle by construction — spends
        ``dst_slots * (stall + remaining)`` slot-steps hosting it.
        """
        seq_len = len(victim.prompt) + len(victim.generated)
        saved = slots * (rem[0] - rem[1])
        fused = float(sum(donor.topology)) * max(rem[0], 1.0)
        best: Optional[Migration] = None
        considered = False
        for v in views:
            if v.gi == donor.gi:
                continue
            # the stall is per destination *group*: a tiered cost model
            # (repro.cluster) prices a same-chip hop differently from a
            # cross-chip or cross-node one; the flat model is constant
            stall = self._stall_ticks(seq_len, donor.gi, v.gi)
            for qi, dslots in enumerate(v.topology):
                if (v.gi, qi) in reserved or v.free[qi] < dslots:
                    continue       # only fully idle parts host a transfer
                considered = True
                if math.isinf(stall):
                    gain = -math.inf
                    charged = 0
                else:
                    # price the move at the stall actually charged (the
                    # whole-tick quantum), so the amortization check and
                    # the destination's bill agree
                    charged = charge_ticks(stall)
                    added = dslots * (charged + victim.remaining)
                    gain = (saved - added) / fused
                if gain <= self.cfg.min_gain:
                    continue
                if best is None or gain > best.gain:
                    best = Migration(LIVE, victim, src=(donor.gi, pi),
                                     dst=(v.gi, qi),
                                     stall=charged, gain=gain)
        if considered and best is None:
            # one vetoed *move* (not one per candidate destination)
            self.rejected_amortization += 1
        return best

    def _stall_ticks(self, seq_len: int, src_gi: int, dst_gi: int) -> float:
        """Transfer stall for moving ``seq_len`` of state src -> dst."""
        return self.cost.stall_ticks(seq_len, self.model_cfg, self.window,
                                     src=src_gi, dst=dst_gi)

    # -- execution -------------------------------------------------------------

    def execute(self, plans: Sequence[Migration], groups: Sequence,
                now: int = 0) -> int:
        """Apply plans against the live groups; returns moves executed.

        Every step re-validates against current state (the request must
        still be queued / live, the destination slot still free), so a
        stale plan is dropped rather than corrupting the books — no
        request is ever lost or duplicated.
        """
        done = 0
        for m in plans:
            if m.kind == STEAL:
                done += self._execute_steal(m, groups, now)
            else:
                done += self._execute_live(m, groups)
        return done

    def _execute_steal(self, m: Migration, groups: Sequence,
                       now: int) -> int:
        src, dst = groups[m.src[0]], groups[m.dst[0]]
        idx = next((i for i, q in enumerate(src.queue)
                    if q is m.request), None)
        if idx is None:
            return 0
        del src.queue[idx]
        dst.submit([m.request], now=now, part=m.dst[1])
        src.stats.steals_out += 1
        dst.stats.steals_in += 1
        self.steals += 1
        if self.obs.enabled:
            self.obs.emit("steal", gid=m.dst[0], part=m.dst[1], tick=now,
                          rid=m.request.rid, src=m.src, dst=m.dst,
                          gain=float(m.gain))
        return 1

    def _execute_live(self, m: Migration, groups: Sequence) -> int:
        src, dst = groups[m.src[0]], groups[m.dst[0]]
        if m.dst[1] is None or not dst.can_insert(m.dst[1]):
            return 0
        row = src.extract_live(m.request)
        if row is None:
            return 0
        state, last = row
        ok = dst.insert_live(m.request, state, last,
                             part=m.dst[1], stall=m.stall)
        assert ok, "insert_live failed after can_insert passed"
        self.live_migrations += 1
        self.stall_ticks_charged += m.stall
        if self.obs.enabled:
            self.obs.emit("migrate", gid=m.dst[0], part=m.dst[1],
                          rid=m.request.rid, src=m.src, dst=m.dst,
                          stall=int(m.stall), gain=float(m.gain))
        return 1
