"""Multi-group reconfigurable serving: the chip-level AMOEBA layer.

``repro.serve`` models one SM pair; this package scales it to a fleet of
N independently reconfigurable pairs behind a request router, fed by
trace-driven workloads, rebalanced by cross-group work stealing and
KV-costed live migration (``repro.fleet.migrate``), topped up by
bounded slot leases (``repro.fleet.lease``), and measured by
fleet-wide telemetry.
"""
from repro.fleet.lease import Lease, LeasePlanner
from repro.fleet.migrate import (KVTransferCost, Migration,
                                 MigrationPlanner)
from repro.fleet.scheduler import (DEFAULT_MODES, ROUTERS, FleetEngine,
                                   replay_modes, replay_policies)
from repro.fleet.telemetry import FleetTelemetry, RollingWindow
from repro.fleet.traffic import (TenantProfile, bursty_longtail_trace,
                                 imbalanced_trace, make_trace,
                                 multichip_imbalanced_trace,
                                 poisson_trace, skewed_longtail_trace,
                                 transient_burst_trace, uniform_trace)
from repro.fleet.vec import TrackedQueue, VecGroup, VecState

__all__ = [
    "FleetEngine", "ROUTERS", "DEFAULT_MODES", "replay_modes",
    "replay_policies", "FleetTelemetry", "RollingWindow",
    "VecState", "VecGroup", "TrackedQueue",
    "KVTransferCost", "Migration", "MigrationPlanner",
    "Lease", "LeasePlanner",
    "TenantProfile", "make_trace", "poisson_trace",
    "bursty_longtail_trace", "skewed_longtail_trace",
    "imbalanced_trace", "multichip_imbalanced_trace",
    "transient_burst_trace", "uniform_trace",
]
