"""Fleet scheduler: N reconfigurable groups behind a request router.

This is the serving translation of the paper's full chip: AMOEBA's 24 SM
pairs each fuse or split *independently*, so at any instant the chip is a
heterogeneous mix of big fused SMs and nimble split halves.  Here each
:class:`~repro.serve.engine.ReconfigurableGroup` is one pair (own
controller, own admission queue, own topology) and the
:class:`FleetEngine` is the chip-level layer the single-pair
``ServeEngine`` could not express: a shared arrival stream, a routing
policy that decides *which* pair absorbs each request, and a wall clock
that ticks all pairs concurrently.

Two control-plane layers from ``repro.control`` operate here:

* every group's :class:`~repro.control.GroupController` runs the
  fleet-wide reconfiguration policy (``FleetConfig.amoeba.policy``:
  threshold / predictor / oracle / online) — one shared policy object, so
  an ``online`` fleet learns from every group's replay samples at once;
* an optional chip-level :class:`~repro.control.FleetController`
  (``FleetConfig.rebalance_every > 0``) nudges the fused/split mix to
  track the fleet's long-request fraction — the paper's chip-wide
  heterogeneity as a managed quantity.

Routing policies (pluggable via ``FleetConfig.router`` or the
``ROUTERS`` registry):

* ``round_robin``   — arrival order striped across groups.
* ``least_loaded``  — minimize outstanding decode work (live remaining +
  queued budgets).
* ``length_aware``  — the heterogeneous-SM assignment: predicted-long
  requests go to already-split groups (whose slow halves quarantine
  tails), short requests prefer fused groups (which drain lockstep
  batches at full width); ties fall back to least-loaded, then
  least-recently-assigned.
* ``sticky``        — ``Request.shard`` pins the group (session/cache
  affinity); the imbalance regime ``repro.fleet.migrate`` exists for.

Routers address ``(group, part)`` — the same scheme migration steals
use — so a length-aware admission can target the narrowest quarantine
slice directly; the part half is a soft affinity the group honors under
contention.  When ``FleetConfig.migrate.enabled``, the chip-level
``FleetController`` additionally gathers work-stealing and KV-costed
live-migration plans each rebalance tick and the engine executes them
between decode ticks (see :mod:`repro.fleet.migrate`).

All pairs share one jitted ``decode_step`` (same params, same model), so
the XLA compile cache is shared across the fleet exactly as the paper's
SMs share one instruction front-end.
"""
from __future__ import annotations

import heapq
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import FleetConfig, ModelConfig
from repro.control import ConfigSpace, FleetController, make_policy
from repro.control.policies import ReconfigPolicy
from repro.core.predictor import LogisticModel
from repro.fleet.lease import LeasePlanner
from repro.fleet.migrate import MigrationPlanner, fit_part
from repro.fleet.telemetry import FleetTelemetry
from repro.fleet.vec import VecGroup, VecState
from repro.models import transformer as T
from repro.obs.events import OBS_MODES, EventLog
from repro.obs.metrics import MetricsRegistry
from repro.serve.engine import (IDLE, TICKED, ReconfigurableGroup, Request,
                                make_decode_fn)


# -- routing policies ----------------------------------------------------------
# signature: (request, groups, state) -> (group index, part index | None);
# ``state`` is a dict the policy may use to persist across calls (the
# round-robin cursor, the least-recently-assigned tie-break clocks).  The
# part index is the same (group, part) addressing scheme migration steals
# use, so admissions and steals target parts uniformly; legacy routers
# returning a bare group index are still accepted by the engine.

def _mark_assigned(state: Dict, gi: int) -> None:
    """Stamp ``gi`` as most-recently-assigned for the LRU tie-break."""
    seq = state.get("assign_seq", 0) + 1
    state["assign_seq"] = seq
    state.setdefault("last_assigned", {})[gi] = seq


def _lru(state: Dict, gi: int) -> int:
    """Tie-break key: least-recently-assigned group wins.

    Breaking ties by group index biased steady-state load onto low-index
    groups (every tie went to group 0); the LRU clock rotates them.
    """
    return state.get("last_assigned", {}).get(gi, -1)


def route_round_robin(req: Request, groups: Sequence[ReconfigurableGroup],
                      state: Dict):
    i = (state.get("rr", -1) + 1) % len(groups)
    state["rr"] = i
    return i, None


def route_least_loaded(req: Request, groups: Sequence[ReconfigurableGroup],
                       state: Dict):
    gi = min(range(len(groups)),
             key=lambda i: (groups[i].load(), _lru(state, i), i))
    _mark_assigned(state, gi)
    return gi, None


def route_length_aware(req: Request, groups: Sequence[ReconfigurableGroup],
                       state: Dict):
    """Bin by predicted length onto the heterogeneous group mix.

    Predicted-long requests go to split groups, preferring the one whose
    smallest part — the tail-quarantine slice — is tightest (a long
    request in an s-slot part wastes s x length slot-steps, so the
    narrowest fitting part wins); short requests prefer fused groups and,
    among them, the widest lockstep slice.  Ties fall back to
    least-loaded, then least-recently-assigned.  Returns the chosen
    ``(group, part)`` — the part the fit logic picked, as a soft
    affinity the group honors under contention.
    """
    thresh = state.get("long_threshold", FleetConfig.long_threshold)
    is_long = req.max_new_tokens >= thresh
    pref = [i for i, g in enumerate(groups) if g.is_split == is_long]
    pool = pref if pref else range(len(groups))

    def part_fit(g) -> int:
        topo = getattr(g, "topology", None)
        if not topo:
            return 0
        return min(topo) if is_long and len(topo) > 1 else -max(topo)

    gi = min(pool, key=lambda i: (part_fit(groups[i]), groups[i].load(),
                                  _lru(state, i), i))
    _mark_assigned(state, gi)
    topo = getattr(groups[gi], "topology", None)
    if not topo or len(topo) < 2:
        return gi, None
    return gi, fit_part(topo, is_long)


def _spill(gi: int, groups: Sequence[ReconfigurableGroup],
           state: Dict) -> int:
    """Admission spill: reroute off ``gi`` when its pressure is hot.

    Closes the router/planner loop: the engine publishes its
    ``MigrationPlanner`` into the router state, and any pinned-group
    router consults the planner's pressure view (expected ticks-to-
    drain) before committing an admission.  When the pinned group's
    pressure exceeds ``MigrationConfig.spill_threshold`` the admission
    goes to the least-pressured group instead — so steals only handle
    the residual imbalance instead of re-homing requests the router
    could have placed right the first time.  Returns the (possibly
    unchanged) group index.

    Every outcome stamps the LRU clock: a pinned admission that *stays*
    is still an assignment, and skipping the stamp left the spill
    tie-break ranking cold groups by stale timestamps (two alternating
    hot shards would ping-pong onto the same cold group).
    """
    planner = state.get("planner")
    thresh = state.get("spill_threshold", 0.0)
    if planner is None or thresh <= 0:
        _mark_assigned(state, gi)
        return gi
    p = planner.pressure()
    if p.get(gi, 0.0) <= thresh:
        _mark_assigned(state, gi)
        return gi
    gj = min(range(len(groups)),
             key=lambda i: (p.get(i, 0.0), groups[i].load(),
                            _lru(state, i), i))
    if gj == gi or p.get(gj, 0.0) >= p.get(gi, 0.0):
        _mark_assigned(state, gi)
        return gi                  # nowhere strictly cooler to spill to
    state["spills"] = state.get("spills", 0) + 1
    obs = state.get("obs")
    if obs is not None and obs.enabled:
        # gid is the acting group (the spill source), like every other
        # event kind; the destination rides the payload
        obs.emit("spill", gid=gi, src=gi, dst=gj,
                 pressure=float(p.get(gi, 0.0)))
    _mark_assigned(state, gj)
    return gj


def route_sticky(req: Request, groups: Sequence[ReconfigurableGroup],
                 state: Dict):
    """Shard-affinity routing: ``Request.shard`` pins the group.

    The session/cache-affinity pattern that creates the imbalance the
    migration planner exists to fix — a hot shard's group overflows
    while its neighbors starve.  Unsharded requests fall back to
    least-loaded.  With ``MigrationConfig.spill_threshold`` set, a
    pinned admission spills off a hot group via :func:`_spill`.
    """
    if req.shard is not None:
        return _spill(req.shard % len(groups), groups, state), None
    return route_least_loaded(req, groups, state)


ROUTERS: Dict[str, Callable] = {
    "round_robin": route_round_robin,
    "least_loaded": route_least_loaded,
    "length_aware": route_length_aware,
    "sticky": route_sticky,
}


class FleetEngine:
    """N independently reconfigurable groups draining a shared arrival stream.

    ``submit`` accepts requests with ``arrival`` ticks (a trace from
    ``repro.fleet.traffic``) or plain requests (arrive immediately).  The
    router assigns each request to a group's queue the tick it arrives —
    so ``length_aware`` sees the fleet's *current* split topology, which
    is the point of routing onto a heterogeneous chip.
    """

    def __init__(self, model_cfg: ModelConfig, params,
                 rt: T.Runtime = T.Runtime(production=False, remat=False),
                 fleet: FleetConfig = FleetConfig(),
                 decode_fn: Optional[Callable] = None,
                 model: Optional[LogisticModel] = None,
                 policy: Optional[ReconfigPolicy] = None):
        if fleet.num_groups < 1:
            raise ValueError("fleet needs at least one group")
        if fleet.router not in ROUTERS:
            raise ValueError(f"unknown router {fleet.router!r}; "
                             f"have {sorted(ROUTERS)}")
        if fleet.engine not in ("object", "vec"):
            raise ValueError(f"unknown engine {fleet.engine!r}; "
                             f"have ('object', 'vec')")
        if fleet.obs not in OBS_MODES:
            raise ValueError(f"unknown obs mode {fleet.obs!r}; "
                             f"have {OBS_MODES}")
        # structured event stream + per-tick metrics (repro.obs); every
        # component below shares this one log so the trace is fleet-wide
        self.obs = EventLog(mode=fleet.obs)
        self._metrics = MetricsRegistry() if self.obs.full else None
        self.cfg = model_cfg
        self.params = params
        self.rt = rt
        self.fleet = fleet
        # one compiled decode shared by every group (per batch shape);
        # callers comparing several fleets can pass one in to share it
        # wider.  The vec engine never decodes tokens, so it skips the
        # jit entirely (and tolerates params=None).
        self._vec = VecState(fleet.num_groups, fleet.capacity) \
            if fleet.engine == "vec" else None
        self._decode = decode_fn if self._vec is not None \
            else (decode_fn or make_decode_fn(model_cfg, rt))
        # chip-wide control plane: one replay buffer and one policy object
        # shared by every group, so online learning pools all samples
        self.telemetry = FleetTelemetry(
            fleet.telemetry_window,
            replay_capacity=fleet.amoeba.replay_capacity)
        acfg = fleet.amoeba
        self.policy = policy
        if self.policy is None and fleet.mode == "dynamic":
            self.policy = make_policy(
                acfg.policy,
                space=ConfigSpace(capacity=fleet.capacity,
                                  max_ways=acfg.max_ways,
                                  min_gain=acfg.min_gain,
                                  hetero=acfg.hetero),
                split_threshold=acfg.split_threshold,
                fuse_threshold=acfg.fuse_threshold,
                regroup_policy=acfg.regroup_policy,
                model=model, model_path=acfg.predictor_path,
                replay=self.telemetry.replay, proba_band=acfg.proba_band,
                oracle_margin=acfg.oracle_margin,
                refit_every=acfg.refit_every)
        # only an online policy consumes the replay buffer; wiring it to
        # every group would pay the per-tick labeling cost for nothing
        grp_replay = getattr(self.policy, "replay", None)
        if self.policy is not None and hasattr(self.policy, "obs"):
            # refit/drift-reset events land in the same trace
            self.policy.obs = self.obs
        grp_kw = dict(rt=rt, amoeba=fleet.amoeba, capacity=fleet.capacity,
                      window=fleet.window, mode=fleet.mode,
                      policy=self.policy, replay=grp_replay,
                      obs=self.obs)
        if self._vec is not None:
            self.groups = [
                VecGroup(model_cfg, params, gid=i, vec_state=self._vec,
                         **grp_kw)
                for i in range(fleet.num_groups)]
        else:
            self.groups = [
                ReconfigurableGroup(model_cfg, params, gid=i,
                                    decode_fn=self._decode, **grp_kw)
                for i in range(fleet.num_groups)]
        self._router = ROUTERS[fleet.router]
        self._router_state: Dict = {"long_threshold": fleet.long_threshold,
                                    "obs": self.obs}
        if fleet.quarantine_group is not None and not (
                0 <= fleet.quarantine_group < fleet.num_groups):
            raise ValueError(
                f"quarantine_group {fleet.quarantine_group} out of range "
                f"for {fleet.num_groups} groups")
        if fleet.mode != "dynamic" and (fleet.migrate.enabled
                                        or fleet.lease.enabled
                                        or fleet.quarantine_group is not None):
            # the chip-level control loop only runs on dynamic fleets;
            # fail loudly rather than report all-zero steal counters
            raise ValueError(
                "migrate.enabled / lease.enabled / quarantine_group need "
                f"mode='dynamic' (got mode={fleet.mode!r})")
        self.planner = MigrationPlanner(
            fleet.migrate, model_cfg,
            long_threshold=fleet.long_threshold,
            window=fleet.window) if fleet.migrate.enabled else None
        if self.planner is not None:
            self.planner.obs = self.obs
            # close the router/planner loop: routers consult the
            # planner's pressure view for admission spill (see _spill)
            self._router_state["planner"] = self.planner
            self._router_state["spill_threshold"] = \
                fleet.migrate.spill_threshold
        self.leases = LeasePlanner(
            fleet.lease, long_threshold=fleet.long_threshold) \
            if fleet.lease.enabled else None
        if self.leases is not None:
            self.leases.obs = self.obs
            # the planner is every group's lease book: reconfiguration
            # force-revokes through it before a composition changes
            self.leases.bind(self.groups)
        # the chip-level controller runs whenever any chip-wide concern
        # exists: split-mix rebalancing, migration planning, slack
        # leasing, or a quarantine reservation to maintain
        need_controller = (fleet.rebalance_every > 0
                           or self.planner is not None
                           or self.leases is not None
                           or fleet.quarantine_group is not None)
        self.controller = FleetController(
            long_threshold=fleet.long_threshold,
            every=fleet.rebalance_every if fleet.rebalance_every > 0
            else max(fleet.migrate.every, 1),
            planner=self.planner,
            quarantine=fleet.quarantine_group,
            mix=fleet.rebalance_every > 0,
            leases=self.leases) if need_controller else None
        self.requests: List[Request] = []
        # min-heap of (arrival, seq, request): O(log n) per submit, and the
        # monotone seq keeps delivery FIFO-stable within an arrival tick
        self._pending: List[Tuple[int, int, Request]] = []
        self._seq = 0
        self._last_delivered: Tuple[int, int] = (-1, -1)
        self.wall = 0
        self._run_seconds = 0.0        # cumulative wall-clock inside run()

    # -- admission -------------------------------------------------------------

    def submit(self, requests: Sequence[Request]) -> None:
        """Queue requests for delivery at their ``arrival`` tick.

        Negative arrivals are normalized here, at the submission
        boundary, so delivery never mutates a caller's trace objects —
        a trace can be replayed across engines without aliasing
        surprises.
        """
        for r in requests:
            if r.arrival < 0:
                r.arrival = 0
            self.requests.append(r)
            self._seq += 1
            heapq.heappush(self._pending, (r.arrival, self._seq, r))

    def _deliver(self) -> None:
        while self._pending and self._pending[0][0] <= self.wall:
            arrival, seq, r = heapq.heappop(self._pending)
            # micro-invariant: within one arrival tick, delivery follows
            # submission order (a late submission whose arrival already
            # passed is delivered now and starts a fresh tick, so only
            # equal-arrival pops are comparable)
            if arrival == self._last_delivered[0]:
                assert seq > self._last_delivered[1], \
                    (arrival, seq, self._last_delivered)
            self._last_delivered = (arrival, seq)
            dest = self._router(r, self.groups, self._router_state)
            gi, pi = dest if isinstance(dest, tuple) else (dest, None)
            self.groups[gi].submit([r], now=self.wall, part=pi)

    def _next_event(self) -> Optional[int]:
        """Tick of the next externally scheduled event, or None.

        The idle fast-forward target: the base engine only has pending
        arrivals; subclasses with other deferred events (the cluster
        engine's in-flight cross-chip transfers) fold them in here so
        an idle fleet never terminates with work still in the air.
        """
        return self._pending[0][0] if self._pending else None

    # -- main loop ----------------------------------------------------------------

    def _step_groups(self, dynamic: bool) -> List[str]:
        """Advance every group one tick; vec mode batches the decode.

        In vec mode each group's ``step()`` only runs control flow
        (admission, controller, stall bookkeeping) and *marks* its
        decoding parts; the single ``decode_tick`` then applies every
        mark with one masked array pass.  Deferring is equivalent to the
        object engine's in-loop decodes because a decode only touches
        its own group's rows and nothing reads another group's
        post-decode state within the same tick.
        """
        statuses = [g.step(dynamic=dynamic, now=self.wall)
                    for g in self.groups]
        if self._vec is not None:
            self._vec.decode_tick(self.wall, self.groups)
        return statuses

    def run(self, dynamic: bool = True,
            max_ticks: int = 1_000_000) -> Dict:
        """Drive the fleet until the trace is fully drained (or max_ticks)."""
        t0 = time.perf_counter()
        while self.wall < max_ticks:
            if self.obs.enabled:
                # one clock for every emitter that has no tick in scope
                # (controller.observe, policy refits, live migrations)
                self.obs.set_tick(self.wall)
            self._deliver()
            if self.controller is not None and dynamic \
                    and self.fleet.mode == "dynamic":
                if self._vec is not None \
                        and self.wall % self.controller.every == 0:
                    # rebalance ticks read Request.generated lengths
                    # (KV-transfer pricing, long-fraction mix); make the
                    # lazily-materialized lists truthful first
                    self._vec.sync_generated()
                self.controller.rebalance(self.wall, self.groups)
                plans = self.controller.take_plans()
                if plans:
                    # execute between ticks: steals re-queue, live
                    # migrations splice KV rows before anyone decodes
                    self.planner.execute(plans, self.groups, now=self.wall)
            statuses = self._step_groups(dynamic)
            ticked = sum(s == TICKED for s in statuses)
            if all(s == IDLE for s in statuses):
                nxt_evt = self._next_event()
                if nxt_evt is None:
                    # terminal probe: the trace is drained, not an idle tick
                    break
                # fast-forward the idle gap to the next event, never
                # past the caller's tick bound
                nxt = min(max(self.wall + 1, nxt_evt), max_ticks)
                self.telemetry.on_tick(self.wall, self.groups, 0,
                                       all_idle=True)
                self.telemetry.on_idle_gap(nxt - self.wall - 1,
                                           len(self.groups))
                self.wall = nxt
                continue
            self.telemetry.on_tick(self.wall, self.groups, ticked)
            if self._metrics is not None:
                # vec: one fleet-wide sum instead of a slice per group
                live = int(self._vec.part_live_n.sum()) \
                    if self._vec is not None else None
                self._metrics.sample_fleet(self.wall, self.groups,
                                           planner=self.planner, live=live)
            self.wall += 1
        if self._vec is not None:
            self._vec.sync_generated()
        for g in self.groups:
            g.finalize()
        self.obs.meta.setdefault("obs_mode", self.obs.mode)
        self.obs.meta["wall_ticks"] = self.wall
        summary = self.telemetry.summary(self.groups, self.requests,
                                         policy=self.policy,
                                         fleet_controller=self.controller,
                                         router_state=self._router_state,
                                         obs=self.obs,
                                         metrics=self._metrics)
        # perf trajectory: every summary (and thus every BENCH entry)
        # carries measured wall seconds and simulated ticks per second;
        # cumulative across run() calls on the same engine
        self._run_seconds += time.perf_counter() - t0
        summary["wall_s"] = round(self._run_seconds, 4)
        summary["ticks_per_sec"] = round(
            summary["wall_ticks"] / max(self._run_seconds, 1e-9), 1)
        return summary

    # -- aggregates -------------------------------------------------------------

    @property
    def completed(self) -> int:
        return sum(g.stats.completed for g in self.groups)

    @property
    def useful_tokens(self) -> int:
        return sum(g.stats.useful_tokens for g in self.groups)

    @property
    def slot_steps(self) -> int:
        return sum(g.stats.slot_steps for g in self.groups)


# -- chip-configuration comparison ---------------------------------------------

# (label, group mode, router): the three chip configurations of Fig 12 —
# big-SMs-only, small-SMs-only, and AMOEBA free to pick per pair.
DEFAULT_MODES = (
    ("static_fused", "fused", "least_loaded"),
    ("static_split", "split", "least_loaded"),
    ("amoeba_dynamic", "dynamic", "length_aware"),
)


def replay_modes(model_cfg: ModelConfig, params, rt: T.Runtime,
                 trace_factory: Callable[[], Sequence[Request]], *,
                 groups: int, capacity: int,
                 amoeba=None, window: int = 256,
                 modes: Sequence = DEFAULT_MODES,
                 verbose: bool = True) -> Dict[str, Dict]:
    """Replay identical traces through several fleet configurations.

    ``trace_factory`` must return a *fresh* trace per call (replaying
    mutates the requests); same factory + same seed = byte-identical
    load for every mode.  One compiled decode is shared across modes so
    differences are purely scheduling.  Used by both the fleet benchmark
    and the demo — raises if any mode fails to drain its trace.
    """
    from repro.configs.base import AmoebaConfig
    amoeba = amoeba or AmoebaConfig()
    decode = make_decode_fn(model_cfg, rt)
    out: Dict[str, Dict] = {}
    for label, mode, router in modes:
        trace = trace_factory()
        eng = FleetEngine(model_cfg, params, rt=rt, decode_fn=decode,
                          fleet=FleetConfig(
                              num_groups=groups, capacity=capacity,
                              router=router, mode=mode, window=window,
                              amoeba=amoeba))
        eng.submit(trace)
        s = eng.run()
        if s["completed"] != len(trace):
            raise RuntimeError(f"{label}: completed {s['completed']} of "
                               f"{len(trace)} requests")
        out[label] = s
        if verbose:
            lat = s["latency"]
            print(f"{label:15s} ticks={s['wall_ticks']:4d} "
                  f"eff={s['efficiency']:.3f} "
                  f"p50={lat['p50']:5.1f} p95={lat['p95']:5.1f} "
                  f"p99={lat['p99']:5.1f} util={s['utilization']:.2f} "
                  f"churn/kt={s['churn_per_kilotick']:.0f} "
                  f"done={s['completed']}/{s['submitted']}")
    return out


def replay_policies(model_cfg: ModelConfig, params, rt: T.Runtime,
                    trace_factory: Callable[[], Sequence[Request]], *,
                    groups: int, capacity: int, amoeba=None,
                    window: int = 256,
                    policies: Sequence[str] = ("threshold", "predictor",
                                               "oracle", "online"),
                    model: Optional[LogisticModel] = None,
                    router: str = "length_aware",
                    verbose: bool = True) -> Dict[str, Dict]:
    """Replay identical traces under several reconfiguration policies.

    The policy-sweep companion of :func:`replay_modes`: every run is a
    fully dynamic fleet; only the decision stack differs.  ``predictor``
    needs a trained serve-level model (see
    ``repro.control.offline.train_serve_predictor``); when ``model`` is
    None it is trained on the fly from the synthetic corpus.
    """
    from repro.configs.base import AmoebaConfig
    amoeba = amoeba or AmoebaConfig()
    if model is None and "predictor" in policies:
        from repro.control import train_serve_predictor
        model, _ = train_serve_predictor(capacity=capacity,
                                         max_ways=amoeba.max_ways,
                                         label_margin=amoeba.label_margin,
                                         regroup_policy=amoeba.regroup_policy,
                                         hetero=amoeba.hetero)
    decode = make_decode_fn(model_cfg, rt)
    out: Dict[str, Dict] = {}
    for name in policies:
        trace = trace_factory()
        eng = FleetEngine(
            model_cfg, params, rt=rt, decode_fn=decode, model=model,
            fleet=FleetConfig(num_groups=groups, capacity=capacity,
                              router=router, mode="dynamic", window=window,
                              amoeba=amoeba.replace(policy=name)))
        eng.submit(trace)
        s = eng.run()
        if s["completed"] != len(trace):
            raise RuntimeError(f"policy {name}: completed {s['completed']} "
                               f"of {len(trace)} requests")
        out[name] = s
        if verbose:
            lat = s["latency"]
            print(f"policy={name:10s} ticks={s['wall_ticks']:4d} "
                  f"eff={s['efficiency']:.3f} "
                  f"p50={lat['p50']:5.1f} p95={lat['p95']:5.1f} "
                  f"p99={lat['p99']:5.1f} "
                  f"churn/kt={s['churn_per_kilotick']:.0f}")
    return out
