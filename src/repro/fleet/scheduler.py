"""Fleet scheduler: N reconfigurable pairs behind a request router.

This is the serving translation of the paper's full chip: AMOEBA's 24 SM
pairs each fuse or split *independently*, so at any instant the chip is a
heterogeneous mix of big fused SMs and nimble split halves.  Here each
:class:`~repro.serve.engine.ReconfigurableGroup` is one pair (own
controller, own admission queue, own split state) and the
:class:`FleetEngine` is the chip-level layer the single-pair
``ServeEngine`` could not express: a shared arrival stream, a routing
policy that decides *which* pair absorbs each request, and a wall clock
that ticks all pairs concurrently.

Routing policies (pluggable via ``FleetConfig.router`` or the
``ROUTERS`` registry):

* ``round_robin``   — arrival order striped across groups.
* ``least_loaded``  — minimize outstanding decode work (live remaining +
  queued budgets).
* ``length_aware``  — the heterogeneous-SM assignment: predicted-long
  requests go to already-split groups (whose slow halves quarantine
  tails), short requests prefer fused groups (which drain lockstep
  batches at full width); ties fall back to least-loaded.

All pairs share one jitted ``decode_step`` (same params, same model), so
the XLA compile cache is shared across the fleet exactly as the paper's
SMs share one instruction front-end.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional, Sequence

from repro.configs.base import FleetConfig, ModelConfig
from repro.fleet.telemetry import FleetTelemetry
from repro.models import transformer as T
from repro.serve.engine import (IDLE, TICKED, ReconfigurableGroup, Request,
                                make_decode_fn)


# -- routing policies ----------------------------------------------------------
# signature: (request, groups, state) -> group index; ``state`` is a dict the
# policy may use to persist across calls (e.g. the round-robin cursor).

def route_round_robin(req: Request, groups: Sequence[ReconfigurableGroup],
                      state: Dict) -> int:
    i = (state.get("rr", -1) + 1) % len(groups)
    state["rr"] = i
    return i


def route_least_loaded(req: Request, groups: Sequence[ReconfigurableGroup],
                       state: Dict) -> int:
    return min(range(len(groups)), key=lambda i: (groups[i].load(), i))


def route_length_aware(req: Request, groups: Sequence[ReconfigurableGroup],
                       state: Dict) -> int:
    """Bin by predicted length onto the heterogeneous group mix."""
    thresh = state.get("long_threshold", FleetConfig.long_threshold)
    is_long = req.max_new_tokens >= thresh
    pref = [i for i, g in enumerate(groups) if g.is_split == is_long]
    pool = pref if pref else range(len(groups))
    return min(pool, key=lambda i: (groups[i].load(), i))


ROUTERS: Dict[str, Callable] = {
    "round_robin": route_round_robin,
    "least_loaded": route_least_loaded,
    "length_aware": route_length_aware,
}


class FleetEngine:
    """N independently reconfigurable groups draining a shared arrival stream.

    ``submit`` accepts requests with ``arrival`` ticks (a trace from
    ``repro.fleet.traffic``) or plain requests (arrive immediately).  The
    router assigns each request to a group's queue the tick it arrives —
    so ``length_aware`` sees the fleet's *current* split topology, which
    is the point of routing onto a heterogeneous chip.
    """

    def __init__(self, model_cfg: ModelConfig, params,
                 rt: T.Runtime = T.Runtime(production=False, remat=False),
                 fleet: FleetConfig = FleetConfig(),
                 decode_fn: Optional[Callable] = None):
        if fleet.num_groups < 1:
            raise ValueError("fleet needs at least one group")
        if fleet.router not in ROUTERS:
            raise ValueError(f"unknown router {fleet.router!r}; "
                             f"have {sorted(ROUTERS)}")
        self.cfg = model_cfg
        self.params = params
        self.rt = rt
        self.fleet = fleet
        # one compiled decode shared by every group (per batch shape);
        # callers comparing several fleets can pass one in to share it wider
        self._decode = decode_fn or make_decode_fn(model_cfg, rt)
        self.groups = [
            ReconfigurableGroup(
                model_cfg, params, rt=rt, amoeba=fleet.amoeba,
                capacity=fleet.capacity, window=fleet.window,
                mode=fleet.mode, gid=i, decode_fn=self._decode)
            for i in range(fleet.num_groups)]
        self._router = ROUTERS[fleet.router]
        self._router_state: Dict = {"long_threshold": fleet.long_threshold}
        self.telemetry = FleetTelemetry(fleet.telemetry_window)
        self.requests: List[Request] = []
        self._pending: collections.deque[Request] = collections.deque()
        self.wall = 0

    # -- admission -------------------------------------------------------------

    def submit(self, requests: Sequence[Request]) -> None:
        """Queue requests for delivery at their ``arrival`` tick."""
        self.requests.extend(requests)
        merged = sorted(list(self._pending) + list(requests),
                        key=lambda r: r.arrival)
        self._pending = collections.deque(merged)

    def _deliver(self) -> None:
        while self._pending and self._pending[0].arrival <= self.wall:
            r = self._pending.popleft()
            r.arrival = max(r.arrival, 0)
            gi = self._router(r, self.groups, self._router_state)
            self.groups[gi].submit([r])

    # -- main loop ----------------------------------------------------------------

    def run(self, dynamic: bool = True,
            max_ticks: int = 1_000_000) -> Dict:
        """Drive the fleet until the trace is fully drained (or max_ticks)."""
        while self.wall < max_ticks:
            self._deliver()
            statuses = [g.step(dynamic=dynamic, now=self.wall)
                        for g in self.groups]
            ticked = sum(s == TICKED for s in statuses)
            if all(s == IDLE for s in statuses):
                if not self._pending:
                    # terminal probe: the trace is drained, not an idle tick
                    break
                # fast-forward the idle gap to the next arrival, never
                # past the caller's tick bound
                nxt = min(max(self.wall + 1, self._pending[0].arrival),
                          max_ticks)
                self.telemetry.on_tick(self.wall, self.groups, 0,
                                       all_idle=True)
                self.telemetry.on_idle_gap(nxt - self.wall - 1,
                                           len(self.groups))
                self.wall = nxt
                continue
            self.telemetry.on_tick(self.wall, self.groups, ticked)
            self.wall += 1
        for g in self.groups:
            g.finalize()
        return self.telemetry.summary(self.groups, self.requests)

    # -- aggregates -------------------------------------------------------------

    @property
    def completed(self) -> int:
        return sum(g.stats.completed for g in self.groups)

    @property
    def useful_tokens(self) -> int:
        return sum(g.stats.useful_tokens for g in self.groups)

    @property
    def slot_steps(self) -> int:
        return sum(g.stats.slot_steps for g in self.groups)


# -- chip-configuration comparison ---------------------------------------------

# (label, group mode, router): the three chip configurations of Fig 12 —
# big-SMs-only, small-SMs-only, and AMOEBA free to pick per pair.
DEFAULT_MODES = (
    ("static_fused", "fused", "least_loaded"),
    ("static_split", "split", "least_loaded"),
    ("amoeba_dynamic", "dynamic", "length_aware"),
)


def replay_modes(model_cfg: ModelConfig, params, rt: T.Runtime,
                 trace_factory: Callable[[], Sequence[Request]], *,
                 groups: int, capacity: int,
                 amoeba=None, window: int = 256,
                 modes: Sequence = DEFAULT_MODES,
                 verbose: bool = True) -> Dict[str, Dict]:
    """Replay identical traces through several fleet configurations.

    ``trace_factory`` must return a *fresh* trace per call (replaying
    mutates the requests); same factory + same seed = byte-identical
    load for every mode.  One compiled decode is shared across modes so
    differences are purely scheduling.  Used by both the fleet benchmark
    and the demo — raises if any mode fails to drain its trace.
    """
    from repro.configs.base import AmoebaConfig
    amoeba = amoeba or AmoebaConfig()
    decode = make_decode_fn(model_cfg, rt)
    out: Dict[str, Dict] = {}
    for label, mode, router in modes:
        trace = trace_factory()
        eng = FleetEngine(model_cfg, params, rt=rt, decode_fn=decode,
                          fleet=FleetConfig(
                              num_groups=groups, capacity=capacity,
                              router=router, mode=mode, window=window,
                              amoeba=amoeba))
        eng.submit(trace)
        s = eng.run()
        if s["completed"] != len(trace):
            raise RuntimeError(f"{label}: completed {s['completed']} of "
                               f"{len(trace)} requests")
        out[label] = s
        if verbose:
            lat = s["latency"]
            print(f"{label:15s} ticks={s['wall_ticks']:4d} "
                  f"eff={s['efficiency']:.3f} "
                  f"p50={lat['p50']:5.1f} p95={lat['p95']:5.1f} "
                  f"p99={lat['p99']:5.1f} util={s['utilization']:.2f} "
                  f"churn/kt={s['churn_per_kilotick']:.0f} "
                  f"done={s['completed']}/{s['submitted']}")
    return out
