"""Trace-driven workloads for the fleet scheduler.

The hand-rolled batches in ``benchmarks/mesh_amoeba.py`` exercise exactly
one arrival pattern (everything submitted at tick 0).  Real serving load
is a *process*: requests arrive over time, in bursts, from tenants with
very different output-length profiles.  This module generates such traces
as plain ``Request`` lists with ``arrival`` ticks set, so any engine that
understands arrivals (the ``FleetEngine``) can replay them.

Arrivals are per-tick Poisson draws; burstiness is an on/off modulation of
the Poisson intensity (rate ``base`` off-burst, ``base * burst_factor``
during the duty window of each period) — the standard Markov-modulated
Poisson shape of interactive traffic.  Output lengths come from

* ``bimodal``   — short chat turns + a long-generation tail (``p_long``),
* ``lognormal`` — heavy right tail around ``mean_tokens``,
* ``uniform``   — the near-lockstep control case.

Prompt lengths are drawn from a small fixed set so the prefill compile
cache stays bounded.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.serve.engine import Request


@dataclass(frozen=True)
class TenantProfile:
    """One tenant's arrival process + output-length distribution."""
    name: str
    rate: float                        # mean arrivals per tick (off-burst)
    length_dist: str = "bimodal"       # bimodal | lognormal | uniform
    short_tokens: int = 4
    long_tokens: int = 48
    p_long: float = 0.2
    mean_tokens: float = 12.0          # lognormal median / uniform center
    sigma: float = 0.8                 # lognormal shape
    min_tokens: int = 1
    max_tokens: int = 256
    prompt_lengths: Sequence[int] = (8, 16)
    burst_factor: float = 1.0          # >1 turns on on/off modulation
    burst_period: int = 64             # ticks per on/off cycle
    burst_duty: float = 0.25           # fraction of the period at burst rate
    burst_phase: int = 0               # tick offset of the duty window
    # router shard for sticky (affinity) routing; None = unsharded
    shard: "int | None" = None

    def intensity(self, tick: int) -> float:
        if self.burst_factor <= 1.0:
            return self.rate
        on = ((tick - self.burst_phase) % self.burst_period
              < self.burst_duty * self.burst_period)
        return self.rate * (self.burst_factor if on else 1.0)

    def sample_length(self, rng: np.random.Generator) -> int:
        if self.length_dist == "bimodal":
            n = self.long_tokens if rng.random() < self.p_long \
                else self.short_tokens
        elif self.length_dist == "lognormal":
            n = int(round(float(
                rng.lognormal(np.log(self.mean_tokens), self.sigma))))
        elif self.length_dist == "uniform":
            lo = max(self.min_tokens, int(self.mean_tokens * 0.5))
            n = int(rng.integers(lo, int(self.mean_tokens * 1.5) + 1))
        else:
            raise ValueError(f"unknown length_dist {self.length_dist!r}")
        return int(np.clip(n, self.min_tokens, self.max_tokens))


def make_trace(profiles: Sequence[TenantProfile], horizon: int,
               vocab_size: int, seed: int = 0,
               max_requests: int = 10_000) -> List[Request]:
    """Superpose the tenants' arrival processes over ``horizon`` ticks."""
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    for tick in range(horizon):
        for prof in profiles:
            for _ in range(int(rng.poisson(prof.intensity(tick)))):
                plen = int(rng.choice(list(prof.prompt_lengths)))
                prompt = list(map(int, rng.integers(0, vocab_size, plen)))
                out.append(Request(
                    rid=0, prompt=prompt,
                    max_new_tokens=prof.sample_length(rng),
                    tenant=prof.name, arrival=tick, shard=prof.shard))
    out.sort(key=lambda r: r.arrival)
    for i, r in enumerate(out):
        r.rid = i
    if len(out) > max_requests:
        warnings.warn(
            f"trace truncated from {len(out)} to {max_requests} requests "
            f"(raise max_requests to replay the full load)", stacklevel=2)
        out = out[:max_requests]
    return out


# -- canned scenarios ----------------------------------------------------------

def poisson_trace(rate: float, horizon: int, vocab_size: int,
                  seed: int = 0, **length_kw) -> List[Request]:
    """Single-tenant steady Poisson arrivals."""
    prof = TenantProfile(name="steady", rate=rate, **length_kw)
    return make_trace([prof], horizon, vocab_size, seed)


def bursty_longtail_trace(horizon: int, vocab_size: int, seed: int = 0,
                          chat_rate: float = 0.5,
                          batch_rate: float = 0.08) -> List[Request]:
    """The paper's adversarial serving regime as a multi-tenant mix.

    An interactive chat tenant arrives in bursts with mostly-short turns
    but a long tail, while a background batch tenant trickles in
    long-generation jobs — so fused groups keep inheriting divergent
    batches and queues build during bursts.
    """
    chat = TenantProfile(
        name="chat", rate=chat_rate, length_dist="bimodal",
        short_tokens=3, long_tokens=40, p_long=0.2,
        burst_factor=4.0, burst_period=80, burst_duty=0.2)
    batch = TenantProfile(
        name="batch", rate=batch_rate, length_dist="lognormal",
        mean_tokens=32.0, sigma=0.6, max_tokens=96,
        prompt_lengths=(16,))
    return make_trace([chat, batch], horizon, vocab_size, seed)


def skewed_longtail_trace(horizon: int, vocab_size: int, seed: int = 0,
                          rate: float = 0.7,
                          p_long: float = 0.3) -> List[Request]:
    """A steadily skewed mix: most requests are near-lockstep short turns,
    a fat minority are an order of magnitude longer.

    This is the regime where a heterogeneous composition pays: with ~30%
    long mass a capacity-8 group wants the ``(5, 3)`` cut — five slots
    lockstep-draining the short head while three quarantine the tail —
    which no equal-ways ladder (``2x4``/``4x2``) can express.  Used by
    the composition sweep in ``benchmarks/fleet_bench.py``.
    """
    skew = TenantProfile(
        name="chat", rate=rate, length_dist="bimodal",
        short_tokens=3, long_tokens=48, p_long=p_long,
        burst_factor=2.0, burst_period=60, burst_duty=0.3)
    drizzle = TenantProfile(
        name="batch", rate=0.05, length_dist="lognormal",
        mean_tokens=40.0, sigma=0.5, max_tokens=120,
        prompt_lengths=(16,))
    return make_trace([skew, drizzle], horizon, vocab_size, seed)


def imbalanced_trace(horizon: int, vocab_size: int, seed: int = 0,
                     shards: int = 4, hot_shard: int = 0,
                     hot_rate: float = 0.9, cold_rate: float = 0.05,
                     p_long: float = 0.3) -> List[Request]:
    """Shard-skewed load: one router shard takes nearly all the traffic.

    Every tenant is pinned to a shard (``Request.shard``, honored by the
    ``sticky`` router), but the arrival mass hammers ``hot_shard``: a
    bursty tenant with a fat long tail, while the other shards trickle
    short turns.  Under sticky routing the hot shard's group overflows
    while its neighbors starve — the imbalance regime
    ``repro.fleet.migrate``'s work stealing exists to fix, used by the
    work-stealing sweep in ``benchmarks/fleet_bench.py``.
    """
    profs = []
    for s in range(shards):
        hot = s == hot_shard
        profs.append(TenantProfile(
            name=f"shard{s}",
            rate=hot_rate if hot else cold_rate,
            length_dist="bimodal",
            short_tokens=3,
            long_tokens=48 if hot else 12,
            p_long=p_long if hot else 0.1,
            burst_factor=3.0 if hot else 1.0,
            burst_period=50, burst_duty=0.3,
            shard=s))
    return make_trace(profs, horizon, vocab_size, seed)


def transient_burst_trace(horizon: int, vocab_size: int, seed: int = 0,
                          shards: int = 4, burst_len: int = 40,
                          base_rate: float = 0.08,
                          burst_factor: float = 10.0,
                          p_long: float = 0.15) -> List[Request]:
    """A rotating hot shard: each burst too short for a re-cut to pay.

    Every shard trickles short turns at ``base_rate``; the shards take
    turns being hot, each for one ``burst_len`` window of a
    ``shards * burst_len`` cycle (phased duty windows, never two hot at
    once).  By the time a topology move or a steal pipeline spins up for
    one shard's burst, the burst has moved on — while the other shards'
    groups sit with idle slots the whole time.  This is the regime slack
    leases (``repro.fleet.lease``) exist for: the hot group borrows its
    neighbors' idle slots for the burst and hands them back when the
    rotation moves.  Used by the ``slack_lease`` sweep in
    ``benchmarks/fleet_bench.py``.
    """
    period = shards * burst_len
    profs = [TenantProfile(
        name=f"shard{s}", rate=base_rate,
        length_dist="bimodal", short_tokens=3, long_tokens=24,
        p_long=p_long, burst_factor=burst_factor,
        burst_period=period, burst_duty=1.0 / shards,
        burst_phase=s * burst_len, shard=s)
        for s in range(shards)]
    return make_trace(profs, horizon, vocab_size, seed)


def multichip_imbalanced_trace(horizon: int, vocab_size: int, seed: int = 0,
                               chips: int = 2, groups_per_chip: int = 2,
                               hot_chip: int = 0,
                               hot_rate: float = 0.9,
                               warm_rate: float = 0.25,
                               cold_rate: float = 0.04,
                               p_long: float = 0.35) -> List[Request]:
    """Chip-skewed load for the hierarchical (cluster) scheduler.

    One shard per group (``shards = chips * groups_per_chip``); the
    arrival mass hammers ``hot_chip``: its first group takes a bursty
    fat-long-tail stream, its chipmates a warm medium stream, while
    every group on the other chips barely trickles.  Under sticky
    routing the hot chip overflows as a unit — its chipmates can absorb
    some excess over the fast intra-chip NoC, but the residual must
    cross slow inter-chip links, which is exactly the regime where
    distance-blind stealing thrashes and ``repro.cluster``'s tiered
    controller pays.  Used by the ``cluster_hierarchy`` sweep in
    ``benchmarks/fleet_bench.py``.
    """
    profs = []
    for s in range(chips * groups_per_chip):
        chip, local = divmod(s, groups_per_chip)
        if chip == hot_chip and local == 0:
            rate, long_tok, pl_, burst = hot_rate, 48, p_long, 3.0
        elif chip == hot_chip:
            rate, long_tok, pl_, burst = warm_rate, 32, p_long / 2, 1.5
        else:
            rate, long_tok, pl_, burst = cold_rate, 12, 0.1, 1.0
        profs.append(TenantProfile(
            name=f"chip{chip}g{local}", rate=rate,
            length_dist="bimodal", short_tokens=3, long_tokens=long_tok,
            p_long=pl_, burst_factor=burst,
            burst_period=50, burst_duty=0.3, shard=s))
    return make_trace(profs, horizon, vocab_size, seed)


def uniform_trace(rate: float, horizon: int, vocab_size: int,
                  seed: int = 0, tokens: int = 12) -> List[Request]:
    """Near-lockstep lengths — the regime where fused should win."""
    prof = TenantProfile(name="uniform", rate=rate, length_dist="uniform",
                         mean_tokens=float(tokens))
    return make_trace([prof], horizon, vocab_size, seed)
