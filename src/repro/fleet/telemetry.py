"""Per-group and fleet-wide serving telemetry.

The fleet's wall clock is the tick; every tick the engine reports which
groups decoded.  From those samples plus the per-group ``ServeStats`` and
the completion stamps on the requests themselves, this module derives the
quantities the benchmarks compare:

* slot-step efficiency (useful tokens / slot-steps) — the paper's
  utilization metric lifted to the fleet,
* request latency percentiles (p50/p95/p99, per tenant too),
* throughput (tokens and requests per wall tick, plus a rolling window),
* reconfiguration churn (splits+fuses per kilotick),
* utilization (fraction of group-ticks that decoded),
* migration traffic (queue steals, live migrations, KV-transfer stall
  ticks — per group in :class:`GroupSnapshot` and fleet-wide in the
  ``migration`` summary block when a planner is wired).

It also hosts the control plane's :class:`~repro.control.ReplayBuffer`:
every group's ``GroupController`` logs one (features, realized-win)
sample per decision tick into it, and an ``online`` policy refits its
logistic model from the same buffer — telemetry is the training-data
pipe of the monitor -> predict -> reconfigure loop.

Telemetry is the *aggregate* view; the per-decision view lives in
:mod:`repro.obs` — a structured :class:`~repro.obs.events.EventLog`
(reconfig/steal/migrate/... records with tick + (gid, part) address), a
per-tick :class:`~repro.obs.metrics.MetricsRegistry`, and the decision
audit (:mod:`repro.obs.audit`) joining each prediction to its realized
outcome.  When ``FleetConfig.obs`` is enabled, :meth:`summary` carries
the event counts under an ``"obs"`` block; exporters and the text
reports are in :mod:`repro.obs.export` / :mod:`repro.obs.report`.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.control import ReplayBuffer
from repro.serve.engine import Request, ServeStats


class RollingWindow:
    """Cumulative-counter samples over a sliding window of wall ticks."""

    def __init__(self, window: int = 256):
        self.window = window
        self._samples: Deque[Tuple[int, float]] = collections.deque()

    def push(self, tick: int, cumulative: float) -> None:
        self._samples.append((tick, cumulative))
        while self._samples and self._samples[0][0] < tick - self.window:
            self._samples.popleft()

    def push_gap(self, ticks: int) -> None:
        """Carry the last cumulative value across an idle fast-forward.

        Idle ticks produce no tokens/completions, so the counter is flat
        across the gap; pushing a boundary sample at the far edge keeps
        the rate window honest (and expires samples older than the
        window) instead of computing over a stale pre-gap span.  No-op
        before the first real sample — an all-idle prefix has no counter
        to carry.
        """
        if ticks <= 0 or not self._samples:
            return
        t1, v1 = self._samples[-1]
        self.push(t1 + ticks, v1)

    def rate(self) -> float:
        """Mean increase per tick across the retained window."""
        if len(self._samples) < 2:
            return 0.0
        (t0, v0), (t1, v1) = self._samples[0], self._samples[-1]
        return (v1 - v0) / max(t1 - t0, 1)


@dataclass
class GroupSnapshot:
    gid: int
    mode: str
    is_split: bool
    queue_depth: int
    live: int
    stats: ServeStats
    topology: Optional[Tuple[int, ...]] = None

    def as_dict(self) -> Dict:
        return {
            "gid": self.gid, "mode": self.mode, "is_split": self.is_split,
            "topology": list(self.topology) if self.topology else None,
            "queue_depth": self.queue_depth, "live": self.live,
            "ticks": self.stats.ticks, "slot_steps": self.stats.slot_steps,
            "useful_tokens": self.stats.useful_tokens,
            "efficiency": round(self.stats.efficiency, 4),
            "splits": self.stats.splits, "fuses": self.stats.fuses,
            "resizes": self.stats.resizes,
            "completed": self.stats.completed,
            # cross-group migration (repro.fleet.migrate)
            "stall_ticks": self.stats.stall_ticks,
            "steals_in": self.stats.steals_in,
            "steals_out": self.stats.steals_out,
            "migrations_in": self.stats.migrations_in,
            "migrations_out": self.stats.migrations_out,
            # slack leases (repro.fleet.lease): slots granted, cumulative
            "leases_out": self.stats.leases_out,
            "leases_in": self.stats.leases_in,
        }


def percentile(values: Sequence[float], q: float) -> float:
    if not len(values):
        return 0.0
    return float(np.percentile(np.asarray(values, np.float64), q))


class FleetTelemetry:
    """Collects tick samples during a run and summarizes at the end."""

    def __init__(self, window: int = 256, replay_capacity: int = 4096):
        self.window = window
        self.wall_ticks = 0
        self.idle_ticks = 0
        self.active_group_ticks = 0
        self.group_tick_slots = 0
        self.tokens_window = RollingWindow(window)
        self.done_window = RollingWindow(window)
        self.queue_depths: List[int] = []
        # (features, realized-win) decision log; see module docstring
        self.replay = ReplayBuffer(maxlen=replay_capacity)

    # -- during the run --------------------------------------------------------

    def on_tick(self, tick: int, groups, ticked: int,
                all_idle: bool = False) -> None:
        self.wall_ticks = tick + 1
        self.active_group_ticks += ticked
        self.group_tick_slots += len(groups)
        if all_idle:
            # a reconfig-only tick (ticked == 0 but not idle) is churn, not
            # idleness — only a fleet-wide IDLE probe counts here
            self.idle_ticks += 1
        self.tokens_window.push(
            tick, sum(g.stats.useful_tokens for g in groups))
        self.done_window.push(
            tick, sum(g.stats.completed for g in groups))
        self.queue_depths.append(sum(len(g.queue) for g in groups))

    def on_idle_gap(self, ticks: int, n_groups: int) -> None:
        """Account for wall ticks the engine fast-forwarded while idle,
        so utilization/idle_ticks/queue depth stay consistent with
        wall_ticks."""
        if ticks <= 0:
            return
        self.wall_ticks += ticks
        self.idle_ticks += ticks
        self.group_tick_slots += ticks * n_groups
        self.queue_depths.extend([0] * ticks)
        # rolling counters are flat across an idle gap; push the boundary
        # so post-gap rates don't average over a stale pre-gap window
        self.tokens_window.push_gap(ticks)
        self.done_window.push_gap(ticks)

    # -- at the end -------------------------------------------------------------

    @staticmethod
    def latencies(requests: Sequence[Request],
                  tenant: Optional[str] = None) -> np.ndarray:
        lats = [r.latency for r in requests
                if r.finish is not None
                and (tenant is None or r.tenant == tenant)]
        return np.asarray(lats, np.float64)

    def summary(self, groups, requests: Sequence[Request],
                policy=None, fleet_controller=None,
                router_state: Optional[Dict] = None,
                obs=None, metrics=None) -> Dict:
        snaps = [GroupSnapshot(
            gid=g.gid, mode=g.mode, is_split=g.is_split,
            queue_depth=len(g.queue), live=len(g.live_requests()),
            stats=g.stats, topology=getattr(g, "topology", None))
            for g in groups]
        slot_steps = sum(g.stats.slot_steps for g in groups)
        useful = sum(g.stats.useful_tokens for g in groups)
        completed = sum(g.stats.completed for g in groups)
        churn = sum(g.stats.splits + g.stats.fuses + g.stats.resizes
                    for g in groups)
        lats = self.latencies(requests)
        wall = max(self.wall_ticks, 1)
        out = {
            "wall_ticks": self.wall_ticks,
            "idle_ticks": self.idle_ticks,
            "slot_steps": slot_steps,
            "useful_tokens": useful,
            "completed": completed,
            "submitted": len(requests),
            "efficiency": round(useful / max(slot_steps, 1), 4),
            "throughput_tokens_per_tick": round(useful / wall, 3),
            "throughput_requests_per_tick": round(completed / wall, 4),
            "rolling_tokens_per_tick": round(self.tokens_window.rate(), 3),
            "rolling_requests_per_tick": round(self.done_window.rate(), 4),
            "utilization": round(
                self.active_group_ticks / max(self.group_tick_slots, 1), 4),
            "mean_queue_depth": round(float(np.mean(self.queue_depths)), 2)
            if self.queue_depths else 0.0,
            "churn_per_kilotick": round(1000.0 * churn / wall, 2),
            "latency": {
                "mean": round(float(lats.mean()), 2) if lats.size else 0.0,
                "p50": round(percentile(lats, 50), 1),
                "p95": round(percentile(lats, 95), 1),
                "p99": round(percentile(lats, 99), 1),
                "max": round(float(lats.max()), 1) if lats.size else 0.0,
            },
            "groups": [s.as_dict() for s in snaps],
        }
        control: Dict = {"replay_samples": len(self.replay)}
        visited = set()
        for g in groups:
            ctl = getattr(g, "controller", None)
            if ctl is not None:
                for _, _frm, to, _, _ in ctl.state.transitions:
                    visited.add(tuple(to))
        if visited:
            control["topologies_visited"] = [
                list(t) for t in sorted(visited, key=lambda t: (len(t), t))]
            control["hetero_topologies_visited"] = sum(
                1 for t in visited if len(set(t)) > 1)
        if self.replay:
            control["replay_positive_frac"] = round(
                self.replay.label_balance(), 3)
        if policy is not None:
            control["policy"] = getattr(policy, "name", str(policy))
            refits = getattr(policy, "refits", None)
            if refits is not None:
                control["refits"] = refits
                if getattr(policy, "refit_info", None):
                    control["last_refit"] = policy.refit_info[-1]
        if fleet_controller is not None:
            control["fleet_rebalances"] = fleet_controller.rebalances
            reserved = getattr(fleet_controller, "reserved_parts", None)
            if reserved is not None and fleet_controller.quarantine is not None:
                control["reserved_parts"] = sorted(
                    list(a) for a in reserved(groups))
        if router_state is not None and "planner" in router_state:
            # the router/planner loop: pinned admissions rerouted off hot
            # groups via the planner's pressure view (scheduler._spill)
            control["admission_spills"] = router_state.get("spills", 0)
        out["control"] = control
        planner = getattr(fleet_controller, "planner", None)
        if planner is not None:
            mig = planner.summary()
            mig["stall_ticks"] = sum(g.stats.stall_ticks for g in groups)
            out["migration"] = mig
        # slack leases (repro.fleet.lease): grant/revoke/expire counters
        # plus the zero-stall contract counter
        leases = getattr(fleet_controller, "leases", None)
        if leases is not None:
            out["lease"] = leases.summary()
        # the cluster layer (repro.cluster): per-chip pressure, regions,
        # and per-tier byte/stall traffic from the tiered planner
        cluster_summary = getattr(fleet_controller, "cluster_summary", None)
        if cluster_summary is not None:
            out["cluster"] = cluster_summary(groups)
        # the per-decision record (repro.obs): event counts only — full
        # event dumps go through the exporters, not the summary.  Absent
        # entirely when obs is off so summaries stay bit-identical.
        if obs is not None and obs.enabled:
            out["obs"] = obs.summary()
            if metrics is not None:
                out["obs"]["metrics"] = metrics.snapshot()
        tenants = sorted({r.tenant for r in requests})
        if len(tenants) > 1:
            out["per_tenant"] = {}
            for t in tenants:
                tl = self.latencies(requests, tenant=t)
                out["per_tenant"][t] = {
                    "n": int(tl.size),
                    "p50": round(percentile(tl, 50), 1),
                    "p99": round(percentile(tl, 99), 1),
                }
        return out
