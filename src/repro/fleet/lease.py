"""Slack leases: bounded slot borrowing below the reconfiguration layer.

AMOEBA's lattice moves capacity by *re-cutting* a group — merge the live
parts, re-partition, pay the dwell clock and (across groups) the KV
transfer stall.  That price is right when the imbalance is persistent,
and exactly wrong for a transient burst: by the time the cut amortizes,
the burst is gone.  The fleet's work stealing covers part of the gap,
but a steal needs a *free slot on an idle part* at the recipient — a hot
group whose parts are all full can watch a neighbor idle without being
able to use it.

A **slack lease** fills that gap: a part with idle slots lends them to a
sibling part — same group, or an adjacent same-chip group over the NoC —
for a bounded term.  No topology move, no dwell clock, no
reconfiguration stall; the borrowed slots simply widen the borrower
part's next admission wave while the lender's resident budget shrinks by
the same amount, so fleet-wide effective capacity is conserved
(``lent + resident = partition budget``, always).  When the term expires
— or the lender's own queue heats up — the slots go home; rows admitted
into borrowed slots finish where they are (the transient overhang is
charged honestly by ``ReconfigurableGroup._slot_charge``).

Pricing rides the same normalized amortization scale as the topology
lattice (:meth:`repro.control.ConfigSpace.move_gain`) and the migration
planner: the gain of a grant is the borrowed-queue drain it buys, minus
the lender's expected backfill loss over the term, minus any NoC
transfer tax, normalized by the lender group's fused drain cost — and it
must clear ``LeaseConfig.min_gain``.  The lender's loss model is the
*stranded-slot* story: an idle slot on a partially-live part is stranded
until the part's slowest member finishes (admission is per-part, on
drain), so lending it for that window costs nothing — which is what
makes intra-group leases (wide part lends to the quarantine slice's
overflow) profitable at all.

The planner is pure decision logic over the same group protocol the
migration planner uses, plus four lease mutators
(``lease_out`` / ``lease_back`` / ``lease_in`` / ``lease_return``) and
``effective_slots``.  It owns the lease book: outstanding lent/borrowed
totals per part are derived from its active leases, never read from
group internals, and every grant is returned — on expiry, on early
revoke, or force-revoked when a party reconfigures
(``ReconfigurableGroup._reconfigure`` calls :meth:`force_revoke` before
re-cutting, because leases are defined against the current composition).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.configs.base import LeaseConfig
from repro.fleet.migrate import Addr, fit_part
from repro.obs.events import NULL_LOG


@dataclass
class Lease:
    """One outstanding grant: ``slots`` slots from lender to borrower."""
    lid: int
    lender: Addr                   # (group, part) the slots came from
    borrower: Addr                 # (group, part) they widen
    slots: int
    granted: int                   # grant tick
    expires: int                   # tick at which the slots go home
    gain: float                    # normalized amortization gain at grant

    def as_dict(self) -> Dict:
        return {"lid": self.lid, "lender": list(self.lender),
                "borrower": list(self.borrower), "slots": self.slots,
                "granted": self.granted, "expires": self.expires,
                "gain": round(self.gain, 4)}


class LeasePlanner:
    """Grants, revokes, and expires slack leases each rebalance tick.

    ``step`` runs inside the controller's rebalance gate, *after* the
    migration planner (steals are strictly cheaper — a lease only pays
    when stealing can't: no free slot anywhere, or the burst sits on
    admissions rather than a stealable backlog).  One step is:

    1. **expire** — every lease past its term goes home.
    2. **revoke** — a lender whose expected ticks-to-drain exceeds
       ``revoke_threshold`` takes its slots back early; a lease whose
       borrower went idle (empty queue, borrowed width unused) is
       returned rather than held to term.
    3. **grant** — borrowers ranked by pressure; for each, every
       eligible lender part is priced and the best positive-gain grant
       (if any) is taken, up to ``max_grants`` per step.

    ``mesh``/``cost`` are optionally wired by the cluster engine: with a
    mesh, cross-group leases are confined to *adjacent same-chip* pairs
    and priced with the tiered transfer cost (a dead link prices at
    infinity and is vetoed); without one, the flat fleet treats every
    pair as NoC-close and transfer-free.
    """

    def __init__(self, cfg: LeaseConfig, long_threshold: int = 24):
        self.cfg = cfg
        self.long_threshold = long_threshold
        self.active: List[Lease] = []
        # wired by ClusterEngine: adjacency confinement + tiered pricing
        self.mesh = None
        self.cost = None
        self.obs = NULL_LOG
        # counters surfaced in FleetTelemetry.summary
        self.plan_ticks = 0
        self.grants = 0
        self.revokes = 0
        self.expires = 0
        self.rejected_amortization = 0
        self.slot_ticks_lent = 0       # accrued slot·ticks out on lease
        # the contract counter: leases never pay a reconfiguration stall
        # (they move admission capacity, not KV state), so this stays 0
        self.stall_ticks_charged = 0
        self._next_lid = 0
        self._drain: Dict[int, Tuple[int, int]] = {}   # gi -> (tick, done)
        self._pressure: Dict[int, float] = {}
        # bound on first step so force_revoke (called from a group's
        # _reconfigure, outside any step) can reach the counterparties
        self._groups: Optional[Sequence] = None
        self._now = 0

    # -- wiring ----------------------------------------------------------------

    def bind(self, groups: Sequence) -> None:
        """Attach the planner as every group's lease book."""
        self._groups = groups
        for g in groups:
            g._lease_book = self

    # -- telemetry -------------------------------------------------------------

    def summary(self) -> Dict:
        return {
            "plan_ticks": self.plan_ticks,
            "grants": self.grants,
            "revokes": self.revokes,
            "expires": self.expires,
            "active": len(self.active),
            "rejected_amortization": self.rejected_amortization,
            "slot_ticks_lent": self.slot_ticks_lent,
            "stall_ticks_charged": self.stall_ticks_charged,
        }

    # -- book views (the planner's records, never group internals) -------------

    def lent_at(self, addr: Addr) -> int:
        return sum(l.slots for l in self.active if l.lender == addr)

    def borrowed_at(self, addr: Addr) -> int:
        return sum(l.slots for l in self.active if l.borrower == addr)

    # -- pressure (same signal the migration planner ranks donors by) ----------

    def _drain_rate(self, tick: int, gi: int, completed: int) -> float:
        prev = self._drain.get(gi)
        self._drain[gi] = (tick, completed)
        if prev is None or tick <= prev[0]:
            return 0.0
        return (completed - prev[1]) / (tick - prev[0])

    def _refresh_pressure(self, tick: int, groups: Sequence) -> None:
        self._pressure = {}
        for gi, g in enumerate(groups):
            rate = self._drain_rate(tick, gi, g.stats.completed)
            qn = len(g.queue)
            self._pressure[gi] = qn / max(rate, 1e-3) if qn else 0.0

    # -- one rebalance tick ----------------------------------------------------

    def step(self, tick: int, groups: Sequence,
             reserved: Optional[Sequence[Addr]] = None) -> None:
        self._groups = groups
        self._now = tick
        self.plan_ticks += 1
        res: Set[Addr] = set(reserved or ())
        self._refresh_pressure(tick, groups)
        for l in [l for l in self.active if tick >= l.expires]:
            self._release(l, tick, groups, action="expire", reason="term")
        self._revoke(tick, groups)
        self._grant(tick, groups, res)

    # -- revocation ------------------------------------------------------------

    def _revoke(self, tick: int, groups: Sequence) -> None:
        for l in list(self.active):
            gl, _ = l.lender
            gb, pb = l.borrower
            # intra-group leases are exempt from the lender-heat revoke:
            # the "lender's queue" is the borrower's own hot queue, and
            # the widened part is what's draining it
            if gl != gb and \
                    self._pressure.get(gl, 0.0) > self.cfg.revoke_threshold:
                self._release(l, tick, groups, action="revoke",
                              reason="lender_hot")
            elif (not groups[gb].queue
                  and groups[gb]._part_live_n(pb)
                  <= groups[gb].topology[pb]):
                # the burst passed: borrowed width sits unused, go home
                self._release(l, tick, groups, action="revoke",
                              reason="borrower_idle")

    def force_revoke(self, gid: int, reason: str = "reconfig",
                     tick: Optional[int] = None) -> None:
        """Return every lease touching ``gid`` — its composition is
        about to change, so the books it was written against vanish.
        ``tick`` is the caller's wall clock (a reconfigure happens
        between planner steps); without it the last step tick is used.
        """
        if self._groups is None:
            return
        now = self._now if tick is None else max(tick, self._now)
        for l in [l for l in self.active
                  if l.lender[0] == gid or l.borrower[0] == gid]:
            self._release(l, now, self._groups,
                          action="revoke", reason=reason)

    def _release(self, l: Lease, tick: int, groups: Sequence,
                 action: str, reason: str) -> None:
        groups[l.lender[0]].lease_back(l.lender[1], l.slots)
        groups[l.borrower[0]].lease_return(l.borrower[1], l.slots)
        self.active.remove(l)
        self.slot_ticks_lent += l.slots * max(tick - l.granted, 0)
        if action == "expire":
            self.expires += 1
        else:
            self.revokes += 1
        if self.obs.enabled:
            self.obs.emit("lease", gid=l.lender[0], part=l.lender[1],
                          tick=tick, action=action, lid=l.lid,
                          slots=l.slots, dst=l.borrower, reason=reason)

    # -- granting --------------------------------------------------------------

    def _grant(self, tick: int, groups: Sequence, res: Set[Addr]) -> None:
        budget = self.cfg.max_grants
        borrowers = sorted(
            (gi for gi, g in enumerate(groups) if g.queue),
            key=lambda gi: self._pressure.get(gi, 0.0), reverse=True)
        for gb in borrowers:
            if budget <= 0:
                break
            l = self._best_grant(tick, groups, gb, res)
            if l is None:
                continue
            groups[l.lender[0]].lease_out(l.lender[1], l.slots)
            groups[l.borrower[0]].lease_in(l.borrower[1], l.slots)
            groups[l.lender[0]].stats.leases_out += l.slots
            groups[l.borrower[0]].stats.leases_in += l.slots
            self.active.append(l)
            self.grants += 1
            budget -= 1
            if self.obs.enabled:
                self.obs.emit("lease", gid=l.lender[0], part=l.lender[1],
                              tick=tick, action="grant", lid=l.lid,
                              slots=l.slots, dst=l.borrower,
                              term=l.expires - l.granted,
                              gain=float(l.gain))

    def _best_grant(self, tick: int, groups: Sequence, gb: int,
                    res: Set[Addr]) -> Optional[Lease]:
        """Price every eligible lender part for borrower ``gb``."""
        g_b = groups[gb]
        topo_b = tuple(g_b.topology)
        # borrower part through the shared length-aware policy: the
        # burst is short work, so it lands on the widest part (the
        # lockstep drain), skipping reserved quarantine slices
        free_mask = [0 if (gb, i) in res else 1 for i in range(len(topo_b))]
        pb = fit_part(topo_b, is_long=False, free=free_mask)
        if pb is None:
            return None
        wait_b = self._pressure.get(gb, 0.0)
        term = min(self.cfg.max_term, max(1, int(math.ceil(wait_b))))
        need = len(g_b.queue)
        head_b = topo_b[pb] - self.borrowed_at((gb, pb))  # borrow headroom
        best: Optional[Lease] = None
        considered = False
        for gl, g_l in enumerate(groups):
            if not self._pair_ok(gl, gb):
                continue
            xfer = self._xfer_ticks(gl, gb)
            if math.isinf(xfer):
                continue               # dead link: unreachable neighbor
            wait_l = self._pressure.get(gl, 0.0)
            if gl != gb and wait_l > self.cfg.revoke_threshold:
                continue               # would be revoked next step anyway
            topo_l = tuple(g_l.topology)
            fused = float(sum(topo_l)) * max(term, 1)
            for pl, slots in enumerate(topo_l):
                if (gl, pl) == (gb, pb) or (gl, pl) in res:
                    continue
                lent = self.lent_at((gl, pl))
                idle = g_l.effective_slots(pl) - g_l._part_live_n(pl)
                n = min(
                    idle,
                    int(math.floor(self.cfg.max_frac * slots)) - lent,
                    # >= 1 resident slot: a fully-lent part could never
                    # drain its own admissions again
                    slots + self.borrowed_at((gl, pl)) - lent - 1,
                    head_b, need)
                if n <= 0:
                    continue
                considered = True
                live = g_l.part_live(pl)
                eta = max((r.remaining for r in live), default=0)
                saved = n * min(term, wait_b)
                # the stranded-slot loss model: the lender only misses
                # the slots once its part drains (at eta) AND its own
                # queue wants them (wait_l).  Intra-group leases lose
                # nothing — the backfill would pull from the very queue
                # the borrowed slots are draining.
                loss = 0.0 if gl == gb else \
                    n * max(0.0, min(float(term), wait_l) - eta)
                gain = (saved - loss - xfer) / fused
                if gain <= self.cfg.min_gain:
                    continue
                if best is None or gain > best.gain:
                    best = Lease(lid=self._next_lid, lender=(gl, pl),
                                 borrower=(gb, pb), slots=n, granted=tick,
                                 expires=tick + term, gain=gain)
        if considered and best is None:
            self.rejected_amortization += 1
        if best is not None:
            self._next_lid += 1
        return best

    # -- topology confinement + transfer pricing -------------------------------

    def _pair_ok(self, gl: int, gb: int) -> bool:
        if gl == gb:
            return True                # intra-group: always NoC-close
        if self.mesh is None:
            return True                # flat fleet: every pair is close
        return self.mesh.adjacent(gl, gb)

    def _xfer_ticks(self, gl: int, gb: int) -> float:
        """One-time tax on a cross-group grant: the borrower's admits
        land one NoC hop from their KV home, priced like a single-token
        steal.  Intra-group and flat-fleet grants are free."""
        if gl == gb or self.cost is None:
            return 0.0
        return float(self.cost.steal_ticks(1, gl, gb))
