"""Struct-of-arrays fleet core: the vectorized tick engine.

``FleetEngine`` with ``FleetConfig.engine = "object"`` advances one wall
tick via nested Python loops over groups, parts, and requests, paying a
jitted ``decode_step`` call per part per tick.  That is the right
fidelity for token-level work but the wrong cost model for *scheduling*
studies: every quantity the benchmarks compare — completions, latency
percentiles, slot-steps, steal counters — depends only on request
*lengths* and the control plane's decisions, never on which token ids
the model sampled (each live request yields exactly one token per tick
until ``remaining`` hits zero).  This module exploits that: it keeps the
whole fleet's per-request state in flat numpy arrays and advances every
decode of a wall tick with one masked decrement + completion scatter,
with no model, no jax, and no per-token Python.

The split of responsibilities:

* **data plane (vectorized here)** — per-request ``remaining`` /
  ``arrival`` / ``group`` / ``part`` / ``state`` / ``enqueue_tick``
  live in :class:`VecState`; the per-tick decode is a masked
  ``remaining[idx] -= 1`` over the fleet-wide live set, completions
  scatter finish ticks and per-group token counts (``np.bincount``
  segment sums), and ``load()`` becomes an O(1) read of incrementally
  maintained per-group totals.

* **control plane (delegated, bit-identical)** — :class:`VecGroup`
  subclasses :class:`~repro.serve.engine.ReconfigurableGroup` and keeps
  its ``step()`` control flow, admission scan, controller/policy calls,
  and ``_reconfigure`` bookkeeping verbatim; only the data-plane hooks
  (``_prefill_wave``, ``_tick_group``, ``_merge_parts``,
  ``_make_part``, migration splices) are overridden to rewrite array
  indices instead of slicing KV tensors.  Routers, the
  ``FleetController``/``MigrationPlanner``/cluster stack, and telemetry
  therefore run the *same code* against the same views, which is what
  makes the object/vec equivalence suite assert bit-identical summary
  stats rather than merely similar ones.

The one lazily materialized quantity is ``Request.generated``: the
object engine appends one token per tick, the vec engine stores only
``remaining`` and synthesizes a placeholder list (zeros) whenever
shared consumers need ``len(generated)`` — on rebalance ticks (the
planner prices KV transfers by sequence length) and at completion.
Token *values* are the only thing the vec engine does not reproduce.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import ReconfigurableGroup, Request

# Request lifecycle codes (VecState.state)
PENDING = 0      # registered, not yet delivered to any group queue
QUEUED = 1       # sitting in a group's admission queue
LIVE = 2         # admitted: decoding (or stalled) on a part
DONE = 3         # finished; finish tick stamped


def _no_decode(*_a, **_k):  # pragma: no cover - guard, never called
    raise RuntimeError("vec engine has no jax decode path")


class TrackedQueue(collections.deque):
    """A deque of Requests that tracks its summed ``max_new_tokens``.

    The migration planner mutates group queues directly (``del
    src.queue[idx]``), so an O(1) ``load()`` needs the queue itself to
    keep its budget total; every mutator the codebase uses is hooked.
    """

    def __init__(self, it=()):
        super().__init__()
        self.budget = 0
        self.extend(it)

    def append(self, r: Request) -> None:
        super().append(r)
        self.budget += r.max_new_tokens

    def appendleft(self, r: Request) -> None:
        super().appendleft(r)
        self.budget += r.max_new_tokens

    def popleft(self) -> Request:
        r = super().popleft()
        self.budget -= r.max_new_tokens
        return r

    def pop(self) -> Request:
        r = super().pop()
        self.budget -= r.max_new_tokens
        return r

    def extend(self, it) -> None:
        for r in it:
            self.append(r)

    def extendleft(self, it) -> None:
        for r in it:
            self.appendleft(r)

    def remove(self, r: Request) -> None:
        super().remove(r)
        self.budget -= r.max_new_tokens

    def insert(self, i: int, r: Request) -> None:
        super().insert(i, r)
        self.budget += r.max_new_tokens

    def __delitem__(self, i) -> None:
        r = self[i]
        super().__delitem__(i)
        self.budget -= r.max_new_tokens

    def clear(self) -> None:
        super().clear()
        self.budget = 0


class _VecPart:
    """One part's members: aligned Request objects and VecState rows.

    Order matters and is preserved exactly — ``warp_regroup``'s stable
    sort tie-breaks on member order, so any reordering here would
    diverge from the object engine's partitions.
    """

    __slots__ = ("requests", "idx", "vs", "pid")

    def __init__(self, requests: List[Request], idx: List[int],
                 vs: "VecState", pid: int = -1):
        self.requests = requests
        self.idx = idx
        self.vs = vs
        self.pid = pid                 # flat part id: gid * capacity + part

    @property
    def remaining(self) -> np.ndarray:
        return self.vs.remaining[
            np.asarray(self.idx, np.int64)].astype(np.float64)


class VecState:
    """The fleet's struct-of-arrays request store.

    One row per registered request; rows never move.  Per-part occupancy
    lives in flat ``(num_groups * capacity,)`` arrays indexed by
    ``gid * capacity + part`` so a topology change only rewrites the
    group's own slice.
    """

    def __init__(self, num_groups: int, capacity: int):
        self.G = num_groups
        self.C = capacity
        n = 1024
        self.remaining = np.zeros(n, np.int64)
        self.max_new = np.zeros(n, np.int64)
        self.arrival = np.zeros(n, np.int64)
        self.enqueue_tick = np.full(n, -1, np.int64)
        self.group_of = np.full(n, -1, np.int64)
        self.part_flat = np.full(n, -1, np.int64)
        self.state = np.full(n, PENDING, np.int8)
        self.n = 0
        self.reqs: List[Request] = []
        self._rows: Dict[int, int] = {}        # id(request) -> row
        # fleet-wide live set (rows with remaining > 0 on some part)
        self.live_idx = np.empty(0, np.int64)
        self._admitted: List[int] = []         # rows admitted this tick
        # per-part live-member counts and per-group live remaining totals
        self.part_live_n = np.zeros(num_groups * capacity, np.int64)
        self.live_load = np.zeros(num_groups, np.int64)
        # parts marked for decode this tick (cleared by decode_tick)
        self._marked = np.zeros(num_groups * capacity, bool)
        self._any_marked = False

    # -- registration ----------------------------------------------------------

    def _grow(self, need: int) -> None:
        cap = len(self.remaining)
        if need <= cap:
            return
        new = max(cap * 2, need)
        for name in ("remaining", "max_new", "arrival", "enqueue_tick",
                     "group_of", "part_flat", "state"):
            old = getattr(self, name)
            arr = np.full(new, -1, old.dtype) if name in (
                "enqueue_tick", "group_of", "part_flat") \
                else np.zeros(new, old.dtype)
            if name == "state":
                arr[:] = PENDING
            arr[:cap] = old
            setattr(self, name, arr)

    def register(self, r: Request) -> int:
        """Row of ``r``, allocating one on first sight."""
        row = self._rows.get(id(r))
        if row is not None:
            return row
        row = self.n
        self._grow(row + 1)
        self.n += 1
        self.reqs.append(r)
        self._rows[id(r)] = row
        self.remaining[row] = r.remaining
        self.max_new[row] = r.max_new_tokens
        self.arrival[row] = r.arrival
        self.state[row] = PENDING
        return row

    def row(self, r: Request) -> Optional[int]:
        return self._rows.get(id(r))

    # -- the vectorized decode tick --------------------------------------------

    def mark_decode(self, pid: int) -> None:
        self._marked[pid] = True
        self._any_marked = True

    def decode_tick(self, now: int, groups: Sequence) -> None:
        """Apply every part's deferred decode for this wall tick.

        Equivalent to the object engine's per-part ``_tick_group`` calls:
        deferring them all behind the per-group ``step()`` control flow
        is safe because decode only touches the group's own rows and no
        same-tick consumer reads another group's post-decode state.
        """
        if self._admitted:
            self.live_idx = np.concatenate(
                [self.live_idx, np.asarray(self._admitted, np.int64)])
            self._admitted.clear()
        if not self._any_marked:
            return
        li = self.live_idx
        if li.size:
            mask = self._marked[self.part_flat[li]]
            dec = li[mask]
            if dec.size:
                self.remaining[dec] -= 1
                rem = self.remaining[dec]
                per_g = np.bincount(self.group_of[dec], minlength=self.G)
                self.live_load -= per_g
                for g in np.nonzero(per_g)[0]:
                    groups[g].stats.useful_tokens += int(per_g[g])
                fin = dec[rem == 0]
                for row in fin.tolist():
                    r = self.reqs[row]
                    r.generated = [0] * int(self.max_new[row])
                    r.finish = now
                    self.state[row] = DONE
                    self.part_live_n[self.part_flat[row]] -= 1
                self.live_idx = np.concatenate([li[~mask], dec[rem > 0]])
        self._marked[:] = False
        self._any_marked = False

    # -- lazy materialization ---------------------------------------------------

    def sync_generated(self) -> None:
        """Make ``len(r.generated)`` truthful for every live request.

        Called before control-plane consumers that price by sequence
        length (the migration planner) or read ``Request.remaining``
        directly (the fleet controller); queued requests have generated
        nothing and finished ones were materialized at completion.
        """
        for row in self.live_idx.tolist():
            r = self.reqs[row]
            tokens = int(self.max_new[row] - self.remaining[row])
            if len(r.generated) != tokens:
                r.generated = [0] * tokens

    # -- debug invariants -------------------------------------------------------

    def check(self, groups: Sequence) -> None:
        """Recompute every incremental total from scratch (tests only)."""
        for g in groups:
            assert g.queue.budget == sum(
                r.max_new_tokens for r in g.queue), g.gid
            live = 0
            for i, p in enumerate(g._parts):
                pid = g.gid * self.C + i
                n_live = 0 if p is None else int(
                    (self.remaining[np.asarray(p.idx, np.int64)] > 0).sum())
                assert self.part_live_n[pid] == n_live, (g.gid, i)
                if p is not None:
                    assert p.pid == pid, (g.gid, i, p.pid)
                    live += int(self.remaining[
                        np.asarray(p.idx, np.int64)].clip(min=0).sum())
            assert self.live_load[g.gid] == live, g.gid
            assert g.load() == live + g.queue.budget


class VecGroup(ReconfigurableGroup):
    """Array-backed group view: object control flow, vectorized data.

    Inherits ``step()``, the admission scan, submit/arrival tracking,
    controller wiring, and ``_reconfigure``'s partition bookkeeping from
    :class:`ReconfigurableGroup`; every hook that would touch jax state
    instead rewrites rows in the shared :class:`VecState`.
    """

    def __init__(self, model_cfg, params=None, *, vec_state: VecState,
                 **kw):
        kw.setdefault("decode_fn", _no_decode)
        super().__init__(model_cfg, params, **kw)
        self.vs = vec_state
        self.queue: TrackedQueue = TrackedQueue()

    # -- admission -------------------------------------------------------------

    def submit(self, requests: Sequence[Request], now: int = 0,
               part: Optional[int] = None) -> None:
        vs = self.vs
        for r in requests:
            row = vs.register(r)
            vs.state[row] = QUEUED
            vs.enqueue_tick[row] = now
            vs.group_of[row] = self.gid
            vs.part_flat[row] = -1
        super().submit(requests, now=now, part=part)

    def _prefill_wave(self, n_slots: int, now: int,
                      part_idx: Optional[int] = None) -> Optional[_VecPart]:
        wave = self._admission_scan(n_slots, part_idx)
        if not wave:
            return None
        by_len: Dict[int, List[Request]] = collections.defaultdict(list)
        for r in wave:
            by_len[len(r.prompt)].append(r)
        vs = self.vs
        pid = self.gid * vs.C + (part_idx or 0)
        ordered: List[Request] = []
        rows: List[int] = []
        n_live = 0
        for plen, reqs in sorted(by_len.items()):
            self.stats.prefill_tokens += plen * len(reqs)
            self.stats.useful_tokens += len(reqs)   # the prefill token each
            for r in reqs:
                row = vs.row(r)
                ordered.append(r)
                rows.append(row)
                vs.group_of[row] = self.gid
                vs.part_flat[row] = pid
                vs.remaining[row] = r.max_new_tokens - 1
                if vs.remaining[row] <= 0:          # done at prefill
                    r.generated = [0] * r.max_new_tokens
                    r.finish = now
                    vs.state[row] = DONE
                else:
                    vs.state[row] = LIVE
                    vs._admitted.append(row)
                    vs.live_load[self.gid] += vs.remaining[row]
                    n_live += 1
        vs.part_live_n[pid] += n_live
        return _VecPart(ordered, rows, vs, pid=pid)

    # -- decode (deferred to VecState.decode_tick) -----------------------------

    def _tick_group(self, g: _VecPart, slots: int, now: int,
                    part_idx: int = 0) -> None:
        pid = self.gid * self.vs.C + part_idx
        if self.vs.part_live_n[pid] <= 0:
            return                      # all-done part: no decode, no charge
        self.vs.mark_decode(pid)
        self.stats.slot_steps += slots

    def _part_done(self, g: Optional[_VecPart]) -> bool:
        return g is None or self.vs.part_live_n[g.pid] == 0

    # -- topology --------------------------------------------------------------

    def _merge_parts(self, live: List[_VecPart]) -> _VecPart:
        if len(live) == 1:
            return live[0]
        reqs: List[Request] = []
        rows: List[int] = []
        for p in live:
            reqs += p.requests
            rows += p.idx
        return _VecPart(reqs, rows, self.vs)

    def _make_part(self, merged: _VecPart,
                   ids: List[int]) -> Optional[_VecPart]:
        if not ids:
            return None
        return _VecPart([merged.requests[i] for i in ids],
                        [merged.idx[i] for i in ids], self.vs)

    def _reconfigure(self, target) -> None:
        super()._reconfigure(target)
        self._refresh_parts()

    def _refresh_parts(self) -> None:
        """Re-stamp flat part ids and live counts after a re-partition."""
        vs = self.vs
        base = self.gid * vs.C
        vs.part_live_n[base:base + vs.C] = 0
        for i, p in enumerate(self._parts):
            if p is None:
                continue
            pid = base + i
            p.pid = pid
            rows = np.asarray(p.idx, np.int64)
            vs.part_flat[rows] = pid
            vs.part_live_n[pid] = int((vs.remaining[rows] > 0).sum())

    # -- introspection ---------------------------------------------------------

    def live_requests(self) -> List[Request]:
        rem = self.vs.remaining
        out: List[Request] = []
        for g in self._parts:
            if g is not None:
                out.extend(r for r, row in zip(g.requests, g.idx)
                           if rem[row] > 0)
        return out

    def part_live(self, i: int) -> List[Request]:
        g = self._parts[i]
        if g is None:
            return []
        rem = self.vs.remaining
        return [r for r, row in zip(g.requests, g.idx) if rem[row] > 0]

    def _part_live_n(self, i: int) -> int:
        # O(1) from the per-part live counter — identical to the object
        # engine's len(part_live(i)), so lease slot charges stay bit-equal
        return int(self.vs.part_live_n[self.gid * self.vs.C + i])

    def live_count(self) -> int:
        # O(capacity) from the per-part live counters — identical to the
        # object engine's len(live_requests()), so per-tick metric
        # samples (repro.obs.metrics) match across engines
        base = self.gid * self.vs.C
        return int(self.vs.part_live_n[base:base + self.vs.C].sum())

    def load(self) -> int:
        return int(self.vs.live_load[self.gid]) + self.queue.budget

    # -- migration splices -----------------------------------------------------

    def extract_live(self, req: Request):
        vs = self.vs
        row = vs.row(req)
        if row is None:
            return None
        for i, g in enumerate(self._parts):
            if g is None:
                continue
            for j, r in enumerate(g.requests):
                if r is req and vs.remaining[row] > 0:
                    del g.requests[j]
                    del g.idx[j]
                    if not g.requests:
                        self._parts[i] = None
                    vs.part_live_n[self.gid * vs.C + i] -= 1
                    vs.live_load[self.gid] -= vs.remaining[row]
                    self.stats.migrations_out += 1
                    # opaque (state, last) handle — rows never move, so
                    # the row id is the whole decode state
                    return ("vecrow", row), ("vecrow", row)
        return None

    def insert_live(self, req: Request, state, last, part: int,
                    stall: int = 0) -> bool:
        if not self.can_insert(part):
            return False
        req.part_affinity = None
        vs = self.vs
        pid = self.gid * vs.C + part
        g = self._parts[part]
        if g is not None:
            # compact done-but-unretired members out (credit them), same
            # as the object engine's insert path
            keep_r, keep_i = [], []
            for r, row_ in zip(g.requests, g.idx):
                if vs.remaining[row_] > 0:
                    keep_r.append(r)
                    keep_i.append(row_)
                else:
                    self._credit(r)
            if keep_r:
                g.requests, g.idx = keep_r, keep_i
            else:
                g = None
                self._parts[part] = None
        row = vs.row(req)
        if g is None:
            self._parts[part] = _VecPart([req], [row], vs, pid=pid)
        else:
            g.requests.append(req)
            g.idx.append(row)
        vs.group_of[row] = self.gid
        vs.part_flat[row] = pid
        vs.state[row] = LIVE
        vs.part_live_n[pid] += 1
        vs.live_load[self.gid] += vs.remaining[row]
        self._stall[part] = max(self._stall[part], int(stall))
        self.stats.migrations_in += 1
        return True

    # -- drain -----------------------------------------------------------------

    def finalize(self) -> None:
        vs = self.vs
        for g in self._parts:
            if g is None:
                continue
            for r, row in zip(g.requests, g.idx):
                if vs.remaining[row] <= 0:
                    self._credit(r)
