"""Deterministic synthetic data pipeline.

Produces a reproducible Markov-ish token stream (so the LM loss actually
decreases — there is learnable structure) plus the per-family stub inputs:
precomputed audio-frame embeddings for whisper and patch embeddings for the
VLM.  The iterator state is one integer, so checkpoint/restore is exact:
restoring step k regenerates batch k bit-identically on any host count
(each host slices its own rows from the global batch by index — the
standard multi-host input sharding contract).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # Markov chain sparsity: each token has this many likely successors
    branching: int = 8
    enc_frames: int = 1500        # whisper stub frame count
    vision_tokens: int = 64       # vlm stub patch count


class SyntheticLM:
    """Deterministic, seekable synthetic LM batches."""

    def __init__(self, model: ModelConfig, shape: ShapeConfig,
                 cfg: DataConfig = DataConfig(),
                 host_index: int = 0, host_count: int = 1):
        self.model = model
        self.shape = shape
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        assert shape.global_batch % host_count == 0 or host_count == 1
        self.local_batch = max(shape.global_batch // host_count, 1)
        rng = np.random.default_rng(cfg.seed)
        v = model.vocab_size
        # sparse successor table: token t -> branching candidates
        self._succ = rng.integers(0, v, size=(v, cfg.branching),
                                  dtype=np.int64)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Global-step-indexed batch (deterministic, O(1) seek)."""
        B, S = self.local_batch, self.shape.seq_len
        seed = (self.cfg.seed * 1_000_003 + step) * 131 + self.host_index
        rng = np.random.default_rng(seed)
        toks = np.empty((B, S), dtype=np.int64)
        toks[:, 0] = rng.integers(0, self.model.vocab_size, size=B)
        choices = rng.integers(0, self.cfg.branching, size=(B, S))
        for t in range(1, S):
            toks[:, t] = self._succ[toks[:, t - 1], choices[:, t]]
        out: Dict[str, np.ndarray] = {"tokens": toks.astype(np.int32)}
        if self.model.encoder_layers:
            out["audio_embeds"] = rng.standard_normal(
                (B, self.cfg.enc_frames, self.model.d_model),
                dtype=np.float32)
        if self.model.vision_stub:
            n_vis = min(self.cfg.vision_tokens, S)
            out["vision_embeds"] = rng.standard_normal(
                (B, n_vis, self.model.d_model), dtype=np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(model: ModelConfig, shape: ShapeConfig,
                     cfg: DataConfig = DataConfig(),
                     dtype: str = "bfloat16") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run path)."""
    import jax.numpy as jnp
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if model.encoder_layers:
        specs["audio_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, model.d_model), jnp.dtype(dtype))
    if model.vision_stub:
        n_vis = min(model.max_vision_tokens, S)
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, n_vis, model.d_model), jnp.dtype(dtype))
    return specs
