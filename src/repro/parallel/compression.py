"""Gradient compression for the data-parallel all-reduce.

Int8 symmetric quantization with per-row scales (the Pallas kernel in
``repro.kernels.quantize``) cuts the DP gradient all-reduce payload ~4x —
the software-side attack on the same interconnect roofline term that the
paper's router-bypass fusion relieves in hardware.  Error feedback carries
the quantization residual into the next step so the compression is unbiased
over time (momentum-SGD/Adam tolerate it well).

Usage (inside a shard_map over the data axes)::

    g_mean = compressed_psum_mean(g, axis_name="data")

The all-reduce runs on the int32-accumulated quantized payload; scales are
reduced separately (max), so the wire format is ~1/4 of bf16.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def _quant(x2d: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x2d), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x2d / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_leaf(g: jnp.ndarray):
    """-> (q int8 (R, C), scale (R, 1), orig_shape)."""
    flat = g.astype(jnp.float32).reshape(-1)
    c = min(flat.size, 1024)
    r = -(-flat.size // c)
    pad = r * c - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    q, s = _quant(flat.reshape(r, c))
    return q, s, g.shape


def decompress_leaf(q: jnp.ndarray, scale: jnp.ndarray, shape,
                    dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum_mean(grads: Any, axis_name: str,
                         residuals: Optional[Any] = None):
    """Mean-all-reduce a gradient pytree with int8 payload + error feedback.

    Must be called inside shard_map with ``axis_name`` mapped.  Returns
    (mean_grads, new_residuals).
    """
    # jax.lax.axis_size is newer-jax only; psum(1) is the portable spelling
    # (statically folded under shard_map, no runtime collective)
    if hasattr(jax.lax, "axis_size"):
        n = jax.lax.axis_size(axis_name)
    else:
        n = jax.lax.psum(1, axis_name)

    def one(g, res):
        gf = g.astype(jnp.float32)
        if res is not None:
            gf = gf + res
        shape = gf.shape
        flat = gf.reshape(-1)
        c = min(flat.size, 1024)
        r = -(-flat.size // c)
        if r * c != flat.size:
            flat = jnp.pad(flat, (0, r * c - flat.size))
        rows = flat.reshape(r, c)
        # phase 1: agree on per-row scales (tiny collective), so every
        # shard's int8 payload shares the same quantization grid and the
        # int32 sum dequantizes exactly
        amax = jnp.max(jnp.abs(rows), axis=-1, keepdims=True)
        s_shared = jax.lax.pmax(jnp.maximum(amax, 1e-12) / 127.0, axis_name)
        q = jnp.clip(jnp.round(rows / s_shared), -127, 127).astype(jnp.int8)
        # phase 2: the actual payload — int8 accumulated in int32
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = decompress_leaf(acc, s_shared, shape) / n
        # error feedback: what this shard's wire format failed to carry
        sent = decompress_leaf(q, s_shared, shape)
        new_res = gf - sent
        return mean.astype(g.dtype), new_res

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = (treedef.flatten_up_to(residuals) if residuals is not None
              else [None] * len(flat_g))
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    means = treedef.unflatten([o[0] for o in outs])
    new_res = treedef.unflatten([o[1] for o in outs])
    return means, new_res


def init_residuals(grads_shape: Any):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)
