"""Resolve abstract PartitionSpecs against a concrete mesh.

Model code writes specs with the placeholder axis ``"batch"`` and logical
axes ``"data"`` / ``"model"`` / ``"pod"``.  The launcher resolves them:

* ``"batch"`` expands to the mesh's batch axes (``("pod", "data")`` on the
  multi-pod mesh) — or to no sharding when the actual batch dimension is
  not divisible by them (long-context decode with global_batch=1).
* axes missing from the mesh are dropped (a 1D mesh still runs TP specs).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axes_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def resolve_spec(spec: P, mesh: Mesh, batch_size: Optional[int] = None) -> P:
    out = []
    for entry in spec:
        if entry == "batch":
            ax = batch_axes(mesh)
            if not ax:
                out.append(None)
            elif batch_size is not None and batch_size % _axes_size(mesh, ax):
                out.append(None)          # unshardable batch: replicate
            else:
                out.append(ax if len(ax) > 1 else ax[0])
        elif entry is None:
            out.append(None)
        else:
            entries = entry if isinstance(entry, tuple) else (entry,)
            kept = tuple(a for a in entries if a in mesh.axis_names)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def resolve_spec_for(shape, spec: P, mesh: Mesh,
                     batch_size: Optional[int] = None) -> P:
    """Shape-aware resolution: drop mesh axes on non-divisible dims.

    (whisper's 51865 vocab does not divide by 16 — that dim replicates.)
    """
    base = resolve_spec(spec, mesh, batch_size)
    out = []
    for d, entry in enumerate(base):
        if entry is None or d >= len(shape):
            out.append(entry if d < len(shape) else None)
            continue
        if shape[d] % _axes_size(mesh, entry) != 0:
            out.append(None)
        else:
            out.append(entry)
    return P(*out)


def resolve_tree(pspecs, mesh: Mesh, batch_size: Optional[int] = None):
    """Pytree of PartitionSpec -> pytree of NamedSharding."""
    is_p = lambda x: isinstance(x, P)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, mesh, batch_size)),
        pspecs, is_leaf=is_p)


def resolve_tree_for(shapes, pspecs, mesh: Mesh,
                     batch_size: Optional[int] = None):
    """Shape-aware variant: shapes is a matching pytree of arrays or
    ShapeDtypeStructs; any sharded-but-indivisible dim falls back to
    replication instead of failing at lower time."""
    is_p = lambda x: isinstance(x, P)
    flat_s, treedef = jax.tree.flatten(shapes)
    flat_p = treedef.flatten_up_to(
        jax.tree.map(lambda x: x, pspecs, is_leaf=is_p))
    out = [NamedSharding(mesh, resolve_spec_for(
        getattr(s, "shape", ()), p, mesh, batch_size))
        for s, p in zip(flat_s, flat_p)]
    return treedef.unflatten(out)


def spec_tree(pspecs, mesh: Mesh, batch_size: Optional[int] = None):
    """Pytree of PartitionSpec -> resolved pytree of PartitionSpec."""
    is_p = lambda x: isinstance(x, P)
    return jax.tree.map(lambda s: resolve_spec(s, mesh, batch_size),
                        pspecs, is_leaf=is_p)
