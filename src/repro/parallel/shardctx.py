"""Mesh context plumbing.

Model code never imports a concrete mesh; it calls :func:`hint` /
:func:`current_mesh`.  Launchers install the active mesh with
:func:`use_mesh`.  On a bare CPU (tests, smoke runs) no mesh is installed and
every hint is a no-op, so the same model code runs everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def batch_axes() -> Tuple[str, ...]:
    """Mesh axes that carry the batch/data-parallel dimension."""
    mesh = current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes() -> Tuple[str, ...]:
    mesh = current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a == "model")


def _mesh_scope(mesh: Mesh):
    """Installed-mesh context across jax versions: ``jax.set_mesh`` (new),
    ``jax.sharding.use_mesh``/``set_mesh`` (transitional), or the mesh's own
    context manager (legacy pjit-style ``with mesh:``)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    for name in ("use_mesh", "set_mesh"):
        fn = getattr(jax.sharding, name, None)
        if fn is not None:
            return fn(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes it at top level with a ``check_vma`` kwarg; older
    releases only have ``jax.experimental.shard_map`` where the same knob
    is called ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with _mesh_scope(mesh):
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def hint(x, *spec):
    """``with_sharding_constraint`` when a mesh is active, else identity.

    ``spec`` entries are axis names (str), tuples of axis names, or None.
    The special entry ``"batch"`` expands to the active batch axes.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    resolved = []
    for s in spec:
        if s == "batch":
            ax = batch_axes()
            resolved.append(ax if ax else None)
        else:
            resolved.append(s)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def named_sharding(*spec) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    resolved = []
    for s in spec:
        if s == "batch":
            ax = batch_axes()
            resolved.append(ax if ax else None)
        else:
            resolved.append(s)
    return NamedSharding(mesh, P(*resolved))
