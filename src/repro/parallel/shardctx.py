"""Mesh context plumbing.

Model code never imports a concrete mesh; it calls :func:`hint` /
:func:`current_mesh`.  Launchers install the active mesh with
:func:`use_mesh`.  On a bare CPU (tests, smoke runs) no mesh is installed and
every hint is a no-op, so the same model code runs everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def batch_axes() -> Tuple[str, ...]:
    """Mesh axes that carry the batch/data-parallel dimension."""
    mesh = current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes() -> Tuple[str, ...]:
    mesh = current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a == "model")


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with jax.sharding.set_mesh(mesh):
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def hint(x, *spec):
    """``with_sharding_constraint`` when a mesh is active, else identity.

    ``spec`` entries are axis names (str), tuples of axis names, or None.
    The special entry ``"batch"`` expands to the active batch axes.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    resolved = []
    for s in spec:
        if s == "batch":
            ax = batch_axes()
            resolved.append(ax if ax else None)
        else:
            resolved.append(s)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def named_sharding(*spec) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    resolved = []
    for s in spec:
        if s == "batch":
            ax = batch_axes()
            resolved.append(ax if ax else None)
        else:
            resolved.append(s)
    return NamedSharding(mesh, P(*resolved))
