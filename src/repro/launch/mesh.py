"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — critical because the dry-run
process forces 512 host devices while every other process sees 1 CPU.

Axis semantics:
  pod    — pipeline/replica axis across pods (multi-pod only)
  data   — batch/FSDP axis (DP replicas = AMOEBA "number of SMs")
  model  — tensor/expert-parallel axis (per-group width = "SM size")

AMOEBA plans refactor (data x model) at a fixed chip count:
fused = model x2 / data /2 (scale-up), scale_out = the inverse.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.fusion import MeshPlan


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_plan_mesh(plan: MeshPlan):
    """Mesh for a named AMOEBA plan over the same chips."""
    return jax.make_mesh(plan.shape, plan.axes)


def single_pod_plan(name: str = "base") -> MeshPlan:
    base = MeshPlan("base", data=16, model=16)
    if name == "base":
        return base
    from repro.core.fusion import plan_family
    return plan_family(base)[name]


def multi_pod_plan() -> MeshPlan:
    return MeshPlan("multi", data=16, model=16, pod=2)
