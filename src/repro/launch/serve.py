"""Serving launcher: AMOEBA policy comparison on a real decode workload.

Runs the engine three times on the identical request trace — fused
baseline, direct_split, warp_regroup — and reports slot-efficiency,
makespan, and the split/fuse dynamics (paper Fig 12/19 at the mesh level).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b \
        --requests 24 --capacity 8
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import AmoebaConfig
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def make_requests(cfg, n: int, seed: int):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.choice([8, 16, 32]))
        mx = int(rng.choice([4, 8, 16, 64], p=[0.3, 0.3, 0.2, 0.2]))
        reqs.append(Request(i, list(map(int, rng.integers(
            0, cfg.vocab_size, plen))), mx))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)

    report = {}
    for name, dynamic, policy in [("fused_baseline", False, "warp_regroup"),
                                  ("direct_split", True, "direct_split"),
                                  ("warp_regroup", True, "warp_regroup")]:
        eng = ServeEngine(cfg, params, amoeba=AmoebaConfig(
            regroup_policy=policy, split_threshold=0.3,
            fuse_threshold=0.05, min_phase_steps=2),
            capacity=args.capacity)
        eng.submit(make_requests(cfg, args.requests, args.seed))
        st = eng.run(dynamic=dynamic)
        report[name] = {
            "ticks": st.ticks, "slot_steps": st.slot_steps,
            "useful_tokens": st.useful_tokens,
            "efficiency": round(st.efficiency, 4),
            "splits": st.splits, "fuses": st.fuses,
            "completed": st.completed,
        }
    base = report["fused_baseline"]["efficiency"]
    for k in report:
        report[k]["vs_fused"] = round(report[k]["efficiency"] / base, 3)
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
