"""Training launcher.

On real TPU fleets this runs under the production mesh; on this CPU
container it runs the reduced configs end-to-end (the full configs are
exercised via ``dryrun.py``).

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b \
        --steps 50 --reduced --batch 8 --seq 128
    PYTHONPATH=src python -m repro.launch.train --arch deepseek-moe-16b \
        --steps 30 --reduced --amoeba   # controller telemetry on
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import AmoebaConfig, ShapeConfig, TrainConfig
from repro.core.controller import AmoebaController
from repro.ckpt import CheckpointManager
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--amoeba", action="store_true",
                    help="attach the AMOEBA controller (divergence telemetry)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps, checkpoint_every=args.ckpt_every,
                       grad_compression=args.grad_compression, seed=args.seed)
    controller = AmoebaController(AmoebaConfig()) if args.amoeba else None
    trainer = Trainer(cfg, shape, tcfg, controller=controller)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    out = trainer.train(args.steps, ckpt=ckpt)
    hist = out["history"]
    print(json.dumps({
        "arch": args.arch,
        "steps": len(hist),
        "loss_first": hist[0].loss if hist else None,
        "loss_last": hist[-1].loss if hist else None,
        "mean_dt_s": float(np.mean([m.dt for m in hist[3:]])) if len(hist) > 3
        else None,
        "straggles": len(out["monitor"].events),
        "resumes": out["resumes"],
        "divergence_mean": float(np.mean([m.divergence for m in hist]))
        if hist else None,
    }, indent=1))


if __name__ == "__main__":
    main()
