import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization, and the dry-run needs 512 placeholder host
# devices to build the production meshes.  Only this entry point sets the
# flag — tests/benchmarks see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real program of the phase — the full
train_step (loss + grad + AdamW) for train shapes, ``prefill`` for
prefill shapes, one-token ``decode_step`` against the full-length KV/state
cache for decode shapes — with parameter/optimizer/cache shardings resolved
against the 16x16 single-pod mesh or the 2x16x16 multi-pod mesh, then:

    lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(*specs)
    compiled = lowered.compile()
    print(compiled.memory_analysis())     # proves the layout fits HBM
    print(compiled.cost_analysis())       # FLOPs/bytes for the roofline

Inputs are ShapeDtypeStructs (repro.data.make_batch_specs) — nothing is
allocated.  Collective payload bytes are parsed from the post-SPMD HLO and
the roofline terms (EXPERIMENTS.md) derive from the JSON artifact this
writes per cell.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k [--multi-pod] [--plan fused] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, LM_SHAPES, SHAPES, get_config,
                           shape_applicable)
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core.fusion import MeshPlan
from repro.core.metrics import profile_from_compiled
from repro.data.pipeline import DataConfig, make_batch_specs
from repro.launch import mesh as meshlib
from repro.models import transformer as T
from repro.parallel import resolve, shardctx
from repro.train.trainer import Trainer

ENC_FRAMES = 1500


def nonembed_params(cfg: ModelConfig, active: bool = True) -> int:
    n = cfg.active_param_count() if active else cfg.param_count()
    emb = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        emb *= 2
    return n - emb


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful FLOPs of the whole step: 6*N*D train, 2*N*D forward."""
    n = nonembed_params(cfg, active=True)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: 1 token/seq


def _rt(cfg: ModelConfig, shape: ShapeConfig,
        seq_shard: bool = True) -> T.Runtime:
    """Production runtime: SP on for full-sequence phases (see §Perf —
    sequence sharding is what fits the 340B residual stream in HBM)."""
    return T.Runtime(production=True, remat=True, use_kernels=False,
                     q_block=512, kv_block=1024, loss_chunk=512,
                     seq_shard=seq_shard and shape.kind != "decode")


def _micro_steps(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Grad-accumulation heuristic: cap the saved-residual footprint.

    est = L x B_loc x (S / TP) x D bytes; keep it under ~4 GB/device.
    """
    if shape.kind != "train":
        return 1
    if cfg.moe is not None:
        # the expert shard_map under a grad-accum scan trips the SPMD
        # partitioner (dynamic-slice of the FSDP gather); MoE residual
        # streams are narrow enough to train un-accumulated
        return 1
    if cfg.param_count() <= 5e10:
        # fp32 m/v states + grad-accum scan also trips the partitioner
        # (same dynamic-slice verifier failure); sub-50B residual streams
        # fit without accumulation anyway
        return 1
    b_loc = max(shape.global_batch // 16, 1)
    # budget for the saved residual stack (XLA may hoist an fp32 copy)
    est = cfg.num_layers * b_loc * (shape.seq_len / 16) * cfg.d_model * 4
    k = 1
    while est / k > 4e9 and k < 16 \
            and (shape.global_batch // 16) % (2 * k) == 0:
        k *= 2
    return k


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, plan_name: str):
    """Returns (jitted_fn, example_args (ShapeDtypeStructs))."""
    rt = _rt(cfg, shape)
    B = shape.global_batch
    batch_specs = make_batch_specs(cfg, shape)

    def batch_shardings(specs):
        return {k: NamedSharding(mesh, resolve.resolve_spec(
            P("batch"), mesh, v.shape[0])) for k, v in specs.items()}

    if shape.kind == "train":
        tcfg = TrainConfig(remat="full", micro_steps=_micro_steps(cfg, shape))
        trainer = Trainer(cfg, shape, tcfg, rt=rt, mesh=mesh,
                          state_dtype="bfloat16"
                          if cfg.param_count() > 5e10 else None)
        sp = trainer.state_pspecs()
        state_shapes = trainer._restore_template()
        state_sh = resolve.resolve_tree_for(state_shapes, sp, mesh)
        jitted = jax.jit(trainer.make_step_body(),
                         in_shardings=(state_sh, batch_shardings(batch_specs)),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        return jitted, (state_shapes, batch_specs)

    # serving paths need the parameter tree + decode state shapes
    params_shapes, pspecs = T.model_pspecs(cfg)
    params_sh = resolve.resolve_tree_for(params_shapes, pspecs, mesh)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return T.prefill(params, batch, cfg, rt)

        jitted = jax.jit(prefill_fn,
                         in_shardings=(params_sh,
                                       batch_shardings(batch_specs)),
                         out_shardings=None)
        return jitted, (params_shapes, batch_specs)

    # decode: one new token against a seq_len-deep cache
    enc_len = ENC_FRAMES if cfg.encoder_layers else 0
    state_shapes = jax.eval_shape(
        lambda: T.init_decode_state(cfg, B, shape.seq_len, enc_len))
    state_sp = T.decode_state_pspecs(cfg)
    state_sh = resolve.resolve_tree_for(state_shapes, state_sp, mesh,
                                        batch_size=B)
    tok_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, resolve.resolve_spec(P("batch"), mesh, B))

    def decode_fn(params, state, tokens):
        return T.decode_step(params, state, tokens, cfg, rt)

    jitted = jax.jit(decode_fn,
                     in_shardings=(params_sh, state_sh, tok_sh),
                     out_shardings=(None, state_sh),
                     donate_argnums=(1,))
    return jitted, (params_shapes, state_shapes, tok_spec)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             plan_name: str = "base", out_dir: str = "experiments/dryrun",
             verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "quadratic attention at 500k (DESIGN.md §4)"}
    if multi_pod:
        mesh = meshlib.make_production_mesh(multi_pod=True)
        mesh_name = "pod2x16x16"
    elif plan_name != "base":
        plan = meshlib.single_pod_plan(plan_name)
        mesh = meshlib.make_plan_mesh(plan)
        mesh_name = f"{plan.data}x{plan.model}_{plan_name}"
    else:
        mesh = meshlib.make_production_mesh(multi_pod=False)
        mesh_name = "16x16"

    t0 = time.time()
    with shardctx.use_mesh(mesh):
        jitted, args = build_cell(cfg, shape, mesh, plan_name)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = None
        try:
            mem = compiled.memory_analysis()
            if verbose:
                print(mem)
        except Exception as e:                       # CPU backend quirk
            print(f"memory_analysis unavailable: {e}")
        cost = compiled.cost_analysis()
        if verbose:
            print({k: cost[k] for k in sorted(cost)[:8]}
                  if hasattr(cost, "keys") else cost)

        chips = mesh.devices.size
        prof = profile_from_compiled(
            f"{arch}/{shape_name}/{mesh_name}", lowered, compiled,
            chips=chips, model_flops=model_flops(cfg, shape),
            per_chip_batch=shape.global_batch * shape.seq_len / chips
            if shape.kind != "decode" else shape.global_batch / chips)

    art = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "plan": plan_name, "kind": shape.kind, "chips": chips,
        "skipped": False,
        "flops_per_device": prof.flops,
        "hbm_bytes_per_device": prof.hbm_bytes,
        "collective_bytes_per_device": prof.coll_bytes,
        "collective_breakdown": prof.coll_breakdown,
        "model_flops": prof.model_flops,
        "per_chip_batch": prof.per_chip_batch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "roofline": prof.roofline(),
        "raw": prof.raw,
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                art[attr] = int(v)
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if plan_name == "base" else f"__{plan_name}"
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    if verbose:
        r = art["roofline"]
        print(f"[dryrun] {arch} {shape_name} {mesh_name}: "
              f"compute={r['compute_s']:.4g}s memory={r['memory_s']:.4g}s "
              f"coll={r['collective_s']:.4g}s -> {r['bottleneck']} "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    return art


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in LM_SHAPES] + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--plan", default="base",
                    choices=["base", "fused", "scale_out"])
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell on the chosen mesh")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for s in LM_SHAPES:
                cells.append((arch, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        try:
            run_cell(arch, shape_name, multi_pod=args.multi_pod,
                     plan_name=args.plan, out_dir=args.out)
        except Exception:
            traceback.print_exc()
            failures.append((arch, shape_name))
    if failures:
        print(f"FAILED cells: {failures}")
        raise SystemExit(1)
    print(f"dry-run OK: {len(cells)} cells")


if __name__ == "__main__":
    main()
