"""AdamW with sharded (ZeRO) optimizer state.

The m/v moments mirror the parameter PartitionSpecs, so whatever sharding
the parameters use (pure TP, or TP x FSDP over the 'data' axis), the
optimizer state is sharded identically — with FSDP-style param specs this
*is* ZeRO-3; with TP-only specs it degrades gracefully to ZeRO-1 semantics
on the model axis.  Moments can be kept in bf16 (``state_dtype``) for the
0.3T+ configs where fp32 m/v alone would not fit HBM.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # () int32
    m: Any                     # pytree like params
    v: Any


def adamw_init(params, state_dtype: Optional[str] = None) -> AdamWState:
    dt = jnp.dtype(state_dtype) if state_dtype else None

    def zero(p):
        return jnp.zeros(p.shape, dt or (p.dtype if jnp.issubdtype(
            p.dtype, jnp.floating) else jnp.float32))

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zero, params),
                      v=jax.tree.map(zero, params))


def adamw_pspecs(param_pspecs) -> AdamWState:
    """State PartitionSpecs mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_pspecs, v=param_pspecs)


def cosine_schedule(step: jnp.ndarray, *, base_lr: float, warmup: int,
                    total: int, min_frac: float = 0.1) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(s < warmup, warm, cos)


def global_norm(grads) -> jnp.ndarray:
    """Global L2 norm without materializing fp32 copies of stacked leaves
    (big leaves reduce layer-by-layer under lax.map)."""
    def leaf_sq(g):
        if g.ndim >= 3 and g.shape[0] >= 8:
            per = jax.lax.map(
                lambda s: jnp.sum(jnp.square(s.astype(jnp.float32))), g)
            return jnp.sum(per)
        return jnp.sum(jnp.square(g.astype(jnp.float32)))

    return jnp.sqrt(sum(leaf_sq(g) for g in jax.tree.leaves(grads)))


def global_norm_clip(grads, max_norm: float):
    """Returns (clipped grads, pre-clip global norm).

    Prefer passing ``grad_scale`` to :func:`adamw_update` instead — it folds
    the clip into the per-layer update and never materializes fp32 stacks.
    """
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_scale=1.0) -> Tuple[Any, AdamWState]:
    """One AdamW step; math in fp32, outputs cast back to storage dtypes.

    ``grad_scale`` applies gradient clipping inside the per-layer update
    (fused, no fp32 copy of the whole gradient tree).
    """
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd_math(p, g, m, v):
        gf = g.astype(jnp.float32) * grad_scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = mf / c1
        vhat = vf / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay \
            * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    def upd(p, g, m, v):
        # scan-stacked layer leaves: update one layer at a time so the fp32
        # temporaries are bounded by a single layer's slice, not L x it
        if p.ndim >= 3 and p.shape[0] >= 8:
            return jax.lax.map(lambda a: upd_math(*a), (p, g, m, v))
        return upd_math(p, g, m, v)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
