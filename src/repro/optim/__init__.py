from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               adamw_pspecs, cosine_schedule,
                               global_norm, global_norm_clip)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "adamw_pspecs",
           "cosine_schedule", "global_norm", "global_norm_clip"]
