"""RG-LRU recurrent block (recurrentgemma / Griffin).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * r_t),  r_t = sigmoid(x W_a + b_a),
i_t = sigmoid(x W_x).

The block is: in-proj (x branch + gate branch) -> causal conv on x branch ->
RG-LRU -> gate -> out-proj.  ``lru_width`` is sharded over 'model' (all the
recurrence math is elementwise over width).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers, scan_utils

_C = 8.0  # Griffin's fixed temperature on the recurrence gate


class RGLRUState(NamedTuple):
    conv: jnp.ndarray   # (B, K-1, W)
    h: jnp.ndarray      # (B, W) fp32


def init_rglru(key, cfg: ModelConfig):
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    std_d = 1.0 / math.sqrt(d)
    std_w = 1.0 / math.sqrt(w)
    # Lambda init so that a ~ uniform(0.9, 0.999) at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))
    params = {
        "in_x": layers.truncated_normal(ks[0], (d, w), std_d, dtype),
        "in_gate": layers.truncated_normal(ks[1], (d, w), std_d, dtype),
        "conv_w": layers.truncated_normal(ks[2], (r.conv_width, w), 0.1, dtype),
        "wa": layers.truncated_normal(ks[3], (w, w), std_w, dtype),
        "wx": layers.truncated_normal(ks[4], (w, w), std_w, dtype),
        "ba": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "out": layers.truncated_normal(ks[0], (w, d), std_w, dtype),
    }
    pspecs = {
        "in_x": P("data", "model"),
        "in_gate": P("data", "model"),
        "conv_w": P(None, "model"),
        "wa": P("data", "model"),
        "wx": P("data", "model"),
        "ba": P("model"),
        "lam": P("model"),
        "out": P("model", "data"),
    }
    return params, pspecs


def _gates(params, xc):
    """xc: (..., W) conv output -> (a, gated_input) in fp32."""
    r = jax.nn.sigmoid((xc @ params["wa"]).astype(jnp.float32) + params["ba"])
    i = jax.nn.sigmoid((xc @ params["wx"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = beta * i * xc.astype(jnp.float32)
    return a, b


def rglru_forward(params, x: jnp.ndarray, cfg: ModelConfig,
                  use_kernel: bool = False, return_state: bool = False):
    """x: (B,S,D) -> (B,S,D) (optionally also the final RGLRUState)."""
    xb = x @ params["in_x"]
    gate = x @ params["in_gate"]
    xc = scan_utils.causal_conv1d(xb, params["conv_w"])
    a, b = _gates(params, xc)
    h0 = jnp.zeros((x.shape[0], a.shape[-1]), jnp.float32)
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        h = kernel_ops.rglru_scan(a, b)
        h_last = h[:, -1]
    else:
        h, h_last = scan_utils.linear_scan(a, b, h0)
    y = h.astype(x.dtype) * jax.nn.gelu(gate)
    out = y @ params["out"]
    if not return_state:
        return out
    conv_state = scan_utils.conv_tail(xb, (cfg.rglru.conv_width
                                           if cfg.rglru else 4))
    return out, RGLRUState(conv=conv_state, h=h_last)


def init_rglru_state(cfg: ModelConfig, batch: int) -> RGLRUState:
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    return RGLRUState(
        conv=jnp.zeros((batch, r.conv_width - 1, w), jnp.dtype(cfg.dtype)),
        h=jnp.zeros((batch, w), jnp.float32),
    )


def rglru_state_pspec() -> RGLRUState:
    return RGLRUState(conv=P("batch", None, "model"),
                      h=P("batch", "model"))


def rglru_step(params, state: RGLRUState, x_new: jnp.ndarray,
               cfg: ModelConfig) -> Tuple[jnp.ndarray, RGLRUState]:
    """Decode step.  x_new: (B,1,D) -> (B,1,D)."""
    xb = x_new[:, 0] @ params["in_x"]
    gate = x_new[:, 0] @ params["in_gate"]
    xc, conv_state = scan_utils.causal_conv1d_step(
        xb, state.conv, params["conv_w"])
    a, b = _gates(params, xc)
    h = scan_utils.linear_scan_step(a, b, state.h)
    y = h.astype(x_new.dtype) * jax.nn.gelu(gate)
    return (y @ params["out"])[:, None], RGLRUState(conv=conv_state, h=h)
