"""Attention: GQA/MQA, causal / sliding-window / bidirectional, cross-attn,
and a sequence-parallel decode step.

Three execution paths:
  * ``chunked_attention`` — pure-jnp blockwise online-softmax (the oracle and
    the CPU/dry-run path; memory O(block²) so 32k+ prefill lowers safely).
  * ``repro.kernels.ops.flash_attention`` — the Pallas TPU kernel (selected
    with ``use_flash=True`` on TPU runtimes).
  * ``decode_step`` — one-token decode against a seq-sharded KV cache.  Under
    a mesh this runs as a ``shard_map`` flash-decode: each model-axis shard
    scores its local KV slice and the partial softmaxes are merged with a
    log-sum-exp ``psum`` — KV never leaves its shard (this is the memory-
    system analogue of AMOEBA's fused coalescing unit: one logical access
    serves the whole fused group).

KV caches are ring buffers: slot ``i`` holds absolute position
``p_i = pos - ((pos - i) mod W)`` (valid iff ``p_i >= 0``), which degenerates
to the identity layout when ``W >= seq``.  RoPE is applied at write time so
cached keys never need re-rotation.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.parallel import shardctx

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    q_dim, kv_dim = cfg.num_heads * hd, cfg.num_kv_heads * hd
    std = 1.0 / math.sqrt(d)
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    params = {
        "wq": layers.truncated_normal(ks[0], (d, q_dim), std, dtype),
        "wk": layers.truncated_normal(ks[1], (d, kv_dim), std, dtype),
        "wv": layers.truncated_normal(ks[2], (d, kv_dim), std, dtype),
        "wo": layers.truncated_normal(ks[3], (q_dim, d), 1.0 / math.sqrt(q_dim), dtype),
    }
    pspecs = {
        "wq": P("data", "model"),
        "wk": P("data", None) if cfg.num_kv_heads % 4 else P("data", "model"),
        "wv": P("data", None) if cfg.num_kv_heads % 4 else P("data", "model"),
        "wo": P("model", "data"),
    }
    # kv projections are sharded over "model" only when the kv-head count is
    # mesh-divisible; MQA/GQA-with-few-heads replicates them (cheap).
    if cfg.qk_norm and not cross:
        params["q_norm"] = jnp.ones((hd,), dtype)
        params["k_norm"] = jnp.ones((hd,), dtype)
        pspecs["q_norm"] = P(None)
        pspecs["k_norm"] = P(None)
    return params, pspecs


def _project_qkv(params, x, cfg: ModelConfig, positions, kv_source=None,
                 apply_positions=True):
    """Returns q (B,S,H,hd), k/v (B,Skv,KV,hd) with norm+rope applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    src = x if kv_source is None else kv_source
    q = (x @ params["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = (src @ params["wk"]).reshape(B, src.shape[1], cfg.num_kv_heads, hd)
    v = (src @ params["wv"]).reshape(B, src.shape[1], cfg.num_kv_heads, hd)
    if cfg.qk_norm and "q_norm" in params:
        q = layers.rmsnorm_headwise(params["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm_headwise(params["k_norm"], k, cfg.norm_eps)
    if apply_positions and positions is not None:
        if cfg.mrope:
            q = layers.apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = layers.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Blockwise online-softmax attention (full-sequence: train / prefill)
# ---------------------------------------------------------------------------

def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool, window: Optional[int] = None,
                      q_block: int = 512, kv_block: int = 512) -> jnp.ndarray:
    """q: (B,S,H,hd); k, v: (B,Skv,KV,hd) -> (B,S,H,hd).

    Double ``lax.scan`` over q- and kv-blocks with a running (m, l, o)
    accumulator.  Memory is O(q_block * kv_block) per head, so 500k-token
    sequences lower without materializing S² scores.
    """
    B, S, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    qb = min(q_block, S)
    kb = min(kv_block, Skv)
    nq = -(-S // qb)
    nk = -(-Skv // kb)
    pad_q = nq * qb - S
    pad_k = nk * kb - Skv

    # (nq, B, qb, KV, G, hd)
    qr = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    qr = qr.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 2, 3, 4, 5) * scale
    kr = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kr = kr.reshape(B, nk, kb, KV, hd).transpose(1, 0, 2, 3, 4)
    vr = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vr = vr.reshape(B, nk, kb, KV, hd).transpose(1, 0, 2, 3, 4)

    q_idx = jnp.arange(qb)
    k_idx = jnp.arange(kb)

    def kv_step(carry, inp):
        m, l, o, qi_blk, qpos = carry
        ki, kblk, vblk = inp
        kpos = ki * kb + k_idx
        s = jnp.einsum("bqkgh,bskh->bqkgs", qi_blk, kblk,
                       preferred_element_type=jnp.float32)
        mask = (kpos[None, :] < Skv) & jnp.ones((qb, 1), bool)
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgs,bskh->bqkgh", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        o = o * corr[..., None] + pv
        return (m_new, l, o, qi_blk, qpos), None

    def q_step(_, inp):
        qi, qblk = inp
        qpos = qi * qb + q_idx
        m0 = jnp.full((B, qb, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qb, KV, G), jnp.float32)
        o0 = jnp.zeros((B, qb, KV, G, hd), jnp.float32)
        (m, l, o, _, _), _ = jax.lax.scan(
            kv_step, (m0, l0, o0, qblk, qpos),
            (jnp.arange(nk), kr, vr))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qb, H, hd)
    return out[:, :S].astype(q.dtype)


def full_attention(params, x, positions, cfg: ModelConfig, *,
                   causal: bool = True, encoder_out=None,
                   use_flash: bool = False,
                   q_block: int = 512, kv_block: int = 512) -> jnp.ndarray:
    """Self- or cross-attention over a full sequence.  Returns (B,S,D)."""
    cross = encoder_out is not None
    q, k, v = _project_qkv(params, x, cfg, None if cross else positions,
                           kv_source=encoder_out)
    q = shardctx.hint(q, "batch", None, "model", None)
    window = None if cross else cfg.attn_window
    if use_flash:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.flash_attention(
            q, k, v, causal=causal and not cross, window=window)
    else:
        out = chunked_attention(q, k, v, causal=causal and not cross,
                                window=window, q_block=q_block,
                                kv_block=kv_block)
    out = out.reshape(x.shape[0], x.shape[1], -1)
    return out @ params["wo"]


# ---------------------------------------------------------------------------
# Decode: one token against a (possibly seq-sharded) ring-buffer KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray   # (B, W, KV, hd) — storage dtype (bf16 or int8)
    v: jnp.ndarray   # (B, W, KV, hd)
    k_scale: Any = None   # (B, W, KV, 1) f32 when int8-quantized
    v_scale: Any = None


def cache_pspec(quant: bool = False):
    sp = P("batch", "model", None, None)
    return KVCache(k=sp, v=sp,
                   k_scale=sp if quant else None,
                   v_scale=sp if quant else None)


def _quantize_kv(x: jnp.ndarray):
    """(.., hd) -> int8 payload + per-vector f32 scale (beyond-paper: the
    int8 KV cache halves decode HBM traffic; see EXPERIMENTS.md §Perf C2)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jnp.ndarray, scale, dtype=jnp.float32) -> jnp.ndarray:
    if scale is None:
        return q.astype(dtype)
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               num_layers: Optional[int] = None,
               quant: bool = False) -> KVCache:
    W = min(seq_len, cfg.attn_window) if cfg.attn_window else seq_len
    hd = cfg.resolved_head_dim
    shape = (batch, W, cfg.num_kv_heads, hd)
    if num_layers is not None:
        shape = (num_layers,) + shape
    if quant:
        z = jnp.zeros(shape, jnp.int8)
        s = jnp.ones(shape[:-1] + (1,), jnp.float32)
        return KVCache(k=z, v=z, k_scale=s, v_scale=s)
    z = jnp.zeros(shape, jnp.dtype(cfg.dtype))
    return KVCache(k=z, v=z)


def _ring_valid(pos: jnp.ndarray, W: int, slots: jnp.ndarray) -> jnp.ndarray:
    """Which ring slots hold a live position for each batch element.

    pos: (B,) current absolute position; slots: (S_loc,) global slot indices.
    """
    p = pos[:, None] - jnp.mod(pos[:, None] - slots[None, :], W)
    return p >= 0


def _write_slot_update(buf, new_val, bidx, clamped, in_range):
    cur = buf[bidx, clamped]
    val = jnp.where(jnp.reshape(in_range, (-1,) + (1,) * (cur.ndim - 1)),
                    new_val, cur)
    return buf.at[bidx, clamped].set(val)


def _decode_core(q, cache: KVCache, new_k, new_v, pos, *, W, offset,
                 s_loc, update, axis=None):
    """Scores one KV shard; LSE-combines across 'model' when mapped.

    q: (B,1,H,hd) -> internally (B,KV,G,hd); cache arrays: (B,s_loc,KV,*).
    Handles both bf16 and int8-quantized (k_scale/v_scale) caches.
    """
    B, _, H, hd = q.shape
    k_cache, v_cache = cache.k, cache.v
    ks, vs = cache.k_scale, cache.v_scale
    quant = ks is not None
    KV = k_cache.shape[2]
    G = H // KV
    slots = offset + jnp.arange(s_loc)

    if update:
        write_slot = jnp.mod(pos, W) - offset
        in_range = (write_slot >= 0) & (write_slot < s_loc)
        clamped = jnp.clip(write_slot, 0, s_loc - 1)
        bidx = jnp.arange(B)
        if quant:
            nk_q, nk_s = _quantize_kv(new_k[:, 0])
            nv_q, nv_s = _quantize_kv(new_v[:, 0])
            k_cache = _write_slot_update(k_cache, nk_q, bidx, clamped, in_range)
            v_cache = _write_slot_update(v_cache, nv_q, bidx, clamped, in_range)
            ks = _write_slot_update(ks, nk_s, bidx, clamped, in_range)
            vs = _write_slot_update(vs, nv_s, bidx, clamped, in_range)
        else:
            k_cache = _write_slot_update(k_cache, new_k[:, 0], bidx, clamped,
                                         in_range)
            v_cache = _write_slot_update(v_cache, new_v[:, 0], bidx, clamped,
                                         in_range)

    valid = _ring_valid(pos, W, slots)                       # (B, s_loc)
    kf = _dequantize_kv(k_cache, ks) if quant else k_cache
    vf = _dequantize_kv(v_cache, vs) if quant else v_cache
    qg = q.reshape(B, KV, G, hd) / math.sqrt(hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, kf,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B,KV,G)
    if axis is not None:
        m_g = jax.lax.pmax(m, axis)
    else:
        m_g = m
    p = jnp.exp(s - m_g[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(vf.dtype), vf,
                   preferred_element_type=jnp.float32)
    if axis is not None:
        l = jax.lax.psum(l, axis)
        o = jax.lax.psum(o, axis)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).reshape(B, 1, H, hd)
    return out.astype(q.dtype), KVCache(k=k_cache, v=v_cache,
                                        k_scale=ks, v_scale=vs)


def decode_attention(params, cache: KVCache, x_new: jnp.ndarray,
                     pos: jnp.ndarray, cfg: ModelConfig, *,
                     update: bool = True, cross: bool = False,
                     rope_pos: Optional[jnp.ndarray] = None
                     ) -> Tuple[jnp.ndarray, KVCache]:
    """One-token attention step.

    x_new: (B, 1, D); pos: (B,) absolute position of the new token (drives
    the ring-slot layout); rope_pos overrides the RoPE angle position when
    it differs from the ring position (M-RoPE vision offset).
    When a mesh is active the cache is seq-sharded over 'model' and the
    softmax is combined with psum; otherwise runs dense locally.
    """
    B = x_new.shape[0]
    W = cache.k.shape[1]
    rp = pos if rope_pos is None else rope_pos
    if cross or not cfg.uses_rope:
        positions = None
    elif cfg.mrope:
        # decode: all three M-RoPE components advance with the text position
        positions = jnp.broadcast_to(rp[:, None, None], (B, 3, 1))
    else:
        positions = rp[:, None]
    q, new_k, new_v = _project_qkv(params, x_new, cfg, positions)
    mesh = shardctx.current_mesh()

    shardable = (mesh is not None and "model" in mesh.axis_names
                 and W % mesh.shape["model"] == 0)
    if not shardable:
        out, new_cache = _decode_core(
            q, cache, new_k, new_v, pos,
            W=W, offset=0, s_loc=W, update=update)
    else:
        n_model = mesh.shape["model"]
        s_loc = W // n_model
        bat = shardctx.batch_axes() or None
        if bat:
            n_bat = 1
            for a in bat:
                n_bat *= mesh.shape[a]
            if B % n_bat:
                bat = None           # unshardable batch (e.g. B=1): replicate

        def shard_fn(q, c, nk, nv, pos):
            idx = jax.lax.axis_index("model")
            return _decode_core(q, c, nk, nv, pos,
                                W=W, offset=idx * s_loc, s_loc=s_loc,
                                update=update, axis="model")

        quant = cache.k_scale is not None
        cache_spec = KVCache(k=P(bat, "model"), v=P(bat, "model"),
                             k_scale=P(bat, "model") if quant else None,
                             v_scale=P(bat, "model") if quant else None)
        out, new_cache = shardctx.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(bat), cache_spec, P(bat), P(bat), P(bat)),
            out_specs=(P(bat), cache_spec),
        )(q, cache, new_k, new_v, pos)

    out = out.reshape(B, 1, -1) @ params["wo"]
    return out, new_cache


def build_cross_cache(params, encoder_out: jnp.ndarray,
                      cfg: ModelConfig) -> KVCache:
    """Static decode-time KV cache over the encoder output (no RoPE)."""
    _, k, v = _project_qkv(params, encoder_out, cfg, None,
                           apply_positions=False)
    k = shardctx.hint(k, "batch", "model", None, None)
    v = shardctx.hint(v, "batch", "model", None, None)
    return KVCache(k=k, v=v)


def prefill_cache(params, x, positions, cfg: ModelConfig,
                  window_override: Optional[int] = None,
                  quant: bool = False) -> KVCache:
    """Build the decode-layout cache from a full prefill pass."""
    _, k, v = _project_qkv(params, x, cfg, positions)
    W = window_override or (min(x.shape[1], cfg.attn_window)
                            if cfg.attn_window else x.shape[1])
    if cfg.attn_window:
        W = min(W, cfg.attn_window)
    S = x.shape[1]
    if S > W:
        k, v = k[:, -W:], v[:, -W:]
        # ring layout: slot = p mod W; the tail slice starts at position S-W,
        # which lands on slot (S-W) mod W — roll so slots line up.
        shift = (S - W) % W
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
    elif S < W:
        # identity layout; tail slots are unwritten (invalid until pos wraps)
        pad = ((0, 0), (0, W - S), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    k = shardctx.hint(k, "batch", "model", None, None)
    v = shardctx.hint(v, "batch", "model", None, None)
    if quant:
        kq, ksc = _quantize_kv(k)
        vq, vsc = _quantize_kv(v)
        return KVCache(k=kq, v=vq, k_scale=ksc, v_scale=vsc)
    return KVCache(k=k, v=v)
