"""Mamba-1 selective-SSM block (falcon-mamba-7b).

Tensor-parallel layout: ``d_inner`` is sharded over 'model' — the conv,
gating, scan and C-projection are all elementwise (or contract over
``d_state``/``dt_rank`` only), so the whole block runs collective-free until
``out_proj`` (one psum), mirroring Megatron MLP sharding.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers, scan_utils


class SSMState(NamedTuple):
    conv: jnp.ndarray   # (B, d_conv-1, d_inner)
    h: jnp.ndarray      # (B, d_inner, d_state)


def init_ssm(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dtr = s.resolved_dt_rank(d)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    std = 1.0 / math.sqrt(d)
    params = {
        "in_proj": layers.truncated_normal(ks[0], (d, 2 * di), std, dtype),
        "conv_w": layers.truncated_normal(ks[1], (s.d_conv, di), 0.1, dtype),
        "x_proj": layers.truncated_normal(
            ks[2], (di, dtr + 2 * s.d_state), 1.0 / math.sqrt(di), dtype),
        "dt_proj": layers.truncated_normal(ks[3], (dtr, di),
                                           1.0 / math.sqrt(dtr), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(
                ks[4], (di,), jnp.float32,
                math.log(1e-3), math.log(1e-1))))).astype(dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, s.d_state))
        ).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": layers.truncated_normal(ks[5], (di, d),
                                            1.0 / math.sqrt(di), dtype),
    }
    pspecs = {
        "in_proj": P("data", "model"),
        "conv_w": P(None, "model"),
        "x_proj": P("model", None),
        "dt_proj": P(None, "model"),
        "dt_bias": P("model"),
        "A_log": P("model", None),
        "D": P("model"),
        "out_proj": P("model", "data"),
    }
    return params, pspecs


def _ssm_inner(params, xc, cfg: ModelConfig):
    """Common post-conv math: returns (dt, A, Bmat, Cmat).

    xc: (B, S, di) conv+silu output.
    """
    s = cfg.ssm
    dtr = s.resolved_dt_rank(cfg.d_model)
    proj = xc @ params["x_proj"]                     # (B,S,dtr+2N)
    dt, Bm, Cm = jnp.split(proj, [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"]
                         + params["dt_bias"].astype(dt.dtype))  # (B,S,di)
    A = -jnp.exp(params["A_log"])                    # (di, N) fp32
    return dt, A, Bm, Cm


def ssm_forward(params, x: jnp.ndarray, cfg: ModelConfig,
                use_kernel: bool = False, return_state: bool = False):
    """x: (B,S,D) -> (B,S,D) (optionally also the final SSMState)."""
    s = cfg.ssm
    xz = x @ params["in_proj"]
    xp, z = jnp.split(xz, 2, axis=-1)                # (B,S,di) each
    xc = scan_utils.causal_conv1d(xp, params["conv_w"])
    xc = jax.nn.silu(xc)
    dt, A, Bm, Cm = _ssm_inner(params, xc, cfg)
    dtf = dt.astype(jnp.float32)
    # discretize: a = exp(dt*A) (B,S,di,N); b = dt*x*B
    a = jnp.exp(dtf[..., None] * A)                  # (B,S,di,N)
    bx = (dtf * xc.astype(jnp.float32))[..., None] * \
        Bm.astype(jnp.float32)[:, :, None, :]        # (B,S,di,N)
    h0 = jnp.zeros(a.shape[:1] + a.shape[2:], jnp.float32)
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        y, h_last = kernel_ops.ssm_scan(a, bx, Cm.astype(jnp.float32))
    else:
        y, h_last = scan_utils.linear_scan_contract(
            a, bx, Cm.astype(jnp.float32), h0)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if not return_state:
        return out
    conv_state = scan_utils.conv_tail(xp, s.d_conv)
    return out, SSMState(conv=conv_state, h=h_last)


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return SSMState(
        conv=jnp.zeros((batch, s.d_conv - 1, di), jnp.dtype(cfg.dtype)),
        h=jnp.zeros((batch, di, s.d_state), jnp.float32),
    )


def ssm_state_pspec() -> SSMState:
    return SSMState(conv=P("batch", None, "model"),
                    h=P("batch", "model", None))


def ssm_step(params, state: SSMState, x_new: jnp.ndarray,
             cfg: ModelConfig) -> Tuple[jnp.ndarray, SSMState]:
    """Decode step.  x_new: (B,1,D) -> (B,1,D)."""
    B = x_new.shape[0]
    xz = x_new[:, 0] @ params["in_proj"]
    xp, z = jnp.split(xz, 2, axis=-1)                 # (B,di)
    xc, conv_state = scan_utils.causal_conv1d_step(
        xp, state.conv, params["conv_w"])
    xc = jax.nn.silu(xc)
    dt, A, Bm, Cm = _ssm_inner(params, xc[:, None], cfg)
    dtf = dt[:, 0].astype(jnp.float32)               # (B,di)
    a = jnp.exp(dtf[..., None] * A)                  # (B,di,N)
    bx = (dtf * xc.astype(jnp.float32))[..., None] * \
        Bm[:, 0].astype(jnp.float32)[:, None, :]
    h = scan_utils.linear_scan_step(a, bx, state.h)
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y.astype(x_new.dtype) * jax.nn.silu(z)
    out = (y @ params["out_proj"])[:, None]
    return out, SSMState(conv=conv_state, h=h)
