"""Mixture-of-Experts FFN: fine-grained routed experts (+ shared experts,
+ optional arctic-style dense residual branch).

Two execution paths:

* ``moe_dense`` — capacity-free oracle: every expert runs on every token and
  results are combined by routing weight.  O(E·T·D·F): used for smoke-scale
  configs and as the ground truth in tests.

* ``moe_sharded`` — the production path.  Experts are sharded over the
  'model' axis (EP) and tokens over the batch axes; since tokens are
  *replicated* across 'model', each (data, model) device selects the subset
  of its local tokens routed to its local experts, packs them into a
  per-expert capacity buffer (scatter by intra-expert cumsum), runs the
  expert FFN as one static einsum, scatters back, and a single ``psum`` over
  'model' both combines expert contributions and restores replication.
  No all-to-all is needed in this layout — the AMOEBA analogy: a fused
  group shares one coalesced "memory port" instead of exchanging packets.

  Expert weights are additionally sharded over 'data' on D (FSDP) and
  all-gathered per layer inside the shard_map region; the transpose of that
  gather is the reduce-scatter that keeps gradient memory flat.

Returns routing telemetry (expert load fractions, dropped-token fraction)
— the **divergence signal** consumed by the AMOEBA controller.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.parallel import shardctx


class MoEAux(NamedTuple):
    aux_loss: jnp.ndarray       # scalar load-balance loss
    load: jnp.ndarray           # (E,) fraction of assignments per expert
    dropped: jnp.ndarray        # scalar fraction of dropped assignments


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(f)
    gated = cfg.activation == "swiglu"

    def expert_bank(key, n):
        kk = jax.random.split(key, 3)
        bank = {
            "wi_up": layers.truncated_normal(kk[0], (n, d, f), std_in, dtype),
            "wo": layers.truncated_normal(kk[1], (n, f, d), std_out, dtype),
        }
        if gated:
            bank["wi_gate"] = layers.truncated_normal(kk[2], (n, d, f), std_in, dtype)
        return bank

    params = {
        "router": layers.truncated_normal(ks[0], (d, m.num_experts), std_in,
                                          jnp.float32),
        "experts": expert_bank(ks[1], m.num_experts),
    }
    pspecs = {
        "router": P(None, None),
        "experts": {k: P("model", "data", None) if k != "wo"
                    else P("model", None, "data")
                    for k in params["experts"]},
    }
    if m.num_shared:
        params["shared"], pspecs["shared"] = layers.init_mlp(
            ks[2], d, m.num_shared * f, cfg.activation, dtype)
    if m.dense_residual:
        params["dense"], pspecs["dense"] = layers.init_mlp(
            ks[3], d, cfg.d_ff, cfg.activation, dtype)
    return params, pspecs


def _route(params, x2d: jnp.ndarray, cfg: ModelConfig):
    """x2d: (T, D) -> top-k ids/weights + aux loss terms (fp32)."""
    m = cfg.moe
    logits = (x2d.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    top_p, top_ids = jax.lax.top_k(probs, m.top_k)              # (T, k)
    top_w = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss
    assign = jnp.zeros_like(probs).at[
        jnp.arange(x2d.shape[0])[:, None], top_ids].add(1.0)
    frac_assign = jnp.mean(assign, axis=0) / m.top_k            # (E,)
    frac_prob = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac_assign * frac_prob)
    return top_ids, top_w, aux, frac_assign


def _expert_ffn(bank, x, cfg: ModelConfig, idx=None):
    """x: (E, C, D) (or (C, D) with idx) through the expert MLPs."""
    take = (lambda w: w[idx]) if idx is not None else (lambda w: w)
    up = jnp.einsum("...cd,...df->...cf", x, take(bank["wi_up"]))
    if cfg.activation == "swiglu":
        gate = jnp.einsum("...cd,...df->...cf", x, take(bank["wi_gate"]))
        h = jax.nn.silu(gate) * up
    elif cfg.activation == "relu2":
        h = jnp.square(jax.nn.relu(up))
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("...cf,...fd->...cd", h, take(bank["wo"]))


def _extras(params, x, cfg: ModelConfig):
    """Shared experts + dense residual (dense compute, model-sharded F)."""
    y = jnp.zeros_like(x)
    if "shared" in params:
        y = y + layers.mlp(params["shared"], x, cfg.activation)
    if "dense" in params:
        y = y + layers.mlp(params["dense"], x, cfg.activation)
    return y


# ---------------------------------------------------------------------------
# Oracle path
# ---------------------------------------------------------------------------

def moe_dense(params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, MoEAux]:
    """Capacity-free reference: all experts on all tokens."""
    B, S, D = x.shape
    m = cfg.moe
    x2d = x.reshape(-1, D)
    top_ids, top_w, aux, load = _route(params, x2d, cfg)
    all_out = _expert_ffn(params["experts"], x2d[None].repeat(m.num_experts, 0),
                          cfg)                                   # (E, T, D)
    gathered = all_out[top_ids.T, jnp.arange(x2d.shape[0])[None]]  # (k, T, D)
    y = jnp.einsum("ktd,tk->td", gathered, top_w.astype(x.dtype))
    y = y.reshape(B, S, D) + _extras(params, x, cfg)
    return y, MoEAux(aux_loss=aux, load=load, dropped=jnp.zeros(()))


# ---------------------------------------------------------------------------
# Production path
# ---------------------------------------------------------------------------

def _moe_local(params_local, x_loc, cfg: ModelConfig, e_start: int,
               e_local: int, capacity: int, model_axis, fsdp_axis):
    """Per-device body (runs under shard_map, or standalone when unsharded).

    x_loc: (T, D) local tokens (replicated over 'model').
    params_local: expert bank local to this model rank; if ``fsdp_axis``,
    weights arrive D-sharded and are all-gathered here.
    """
    m = cfg.moe
    T, D = x_loc.shape
    bank = params_local["experts"]
    if fsdp_axis is not None:
        bank = {k: jax.lax.all_gather(
            w, fsdp_axis, axis=(2 if k == "wo" else 1), tiled=True)
            for k, w in bank.items()}

    top_ids, top_w, aux, load = _route(params_local, x_loc, cfg)
    flat_ids = top_ids.reshape(-1)                       # (T*k,)
    flat_w = top_w.reshape(-1)
    mine = (flat_ids >= e_start) & (flat_ids < e_start + e_local)
    le = jnp.clip(flat_ids - e_start, 0, e_local - 1)    # local expert id
    # intra-expert slot via masked cumsum
    onehot = (jax.nn.one_hot(le, e_local, dtype=jnp.int32)
              * mine[:, None].astype(jnp.int32))         # (T*k, E_loc)
    slot = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.sum(slot * onehot, axis=-1)               # (T*k,)
    keep = mine & (slot < capacity)
    dropped_here = jnp.sum(mine & ~keep).astype(jnp.float32)

    tok_idx = jnp.arange(T).repeat(m.top_k)
    slot_c = jnp.where(keep, slot, capacity)             # overflow row
    buf = jnp.zeros((e_local, capacity + 1, D), x_loc.dtype)
    buf = buf.at[le, slot_c].set(
        jnp.where(keep[:, None], x_loc[tok_idx], 0.0))
    out_buf = _expert_ffn(bank, buf[:, :capacity], cfg)  # (E_loc, C, D)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((e_local, 1, D), out_buf.dtype)], axis=1)
    y_tok = out_buf[le, slot_c] * jnp.where(keep, flat_w, 0.0)[:, None].astype(x_loc.dtype)
    y = jnp.zeros_like(x_loc).at[tok_idx].add(y_tok)

    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
        dropped_here = jax.lax.psum(dropped_here, model_axis)
    dropped = dropped_here / (T * m.top_k)
    return y, MoEAux(aux_loss=aux, load=load, dropped=dropped)


def _moe_local_mapped(params_local, x_loc, cfg, e_start, e_local, capacity,
                      model_axis, fsdp_axis):
    """shard_map body wrapper: aux terms get a leading mapped batch dim of 1
    (per-data-shard values are NOT replicated, so they must be mapped)."""
    y, aux = _moe_local(params_local, x_loc, cfg, e_start, e_local, capacity,
                        model_axis, fsdp_axis)
    return y, MoEAux(aux_loss=aux.aux_loss[None], load=aux.load[None],
                     dropped=aux.dropped[None])


def moe_sharded(params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, MoEAux]:
    """EP over 'model', token-parallel over batch axes, FSDP over 'data'."""
    B, S, D = x.shape
    m = cfg.moe
    mesh = shardctx.current_mesh()
    x2d = x.reshape(-1, D)

    if mesh is None or "model" not in mesh.axis_names:
        cap = int(math.ceil(x2d.shape[0] * m.top_k / m.num_experts
                            * m.capacity_factor))
        y, aux = _moe_local(params, x2d, cfg, 0, m.num_experts, cap,
                            None, None)
        y = y + _extras(params, x2d, cfg)
        return y.reshape(B, S, D), aux

    n_model = mesh.shape["model"]
    bat = shardctx.batch_axes() or None
    n_bat = 1
    for a in (bat or ()):
        n_bat *= mesh.shape[a]
    e_local = m.num_experts // n_model
    t_local = (B * S) // n_bat
    capacity = int(math.ceil(t_local * m.top_k / m.num_experts
                             * m.capacity_factor))
    has_fsdp = "data" in mesh.axis_names and mesh.shape["data"] > 1

    expert_specs = {k: P("model", "data", None) if k != "wo"
                    else P("model", None, "data")
                    for k in params["experts"]}
    if not has_fsdp:
        expert_specs = {k: P("model", None, None) for k in params["experts"]}
    pspec_in = {
        "router": P(None, None),
        "experts": expert_specs,
    }
    routed = {"router": params["router"], "experts": params["experts"]}

    def body(params_l, x_l):
        e_start = jax.lax.axis_index("model") * e_local
        return _moe_local_mapped(params_l, x_l, cfg, e_start, e_local,
                                 capacity, "model",
                                 "data" if has_fsdp else None)

    aux_spec = MoEAux(aux_loss=P(bat), load=P(bat, None), dropped=P(bat))
    y, aux = shardctx.shard_map(
        body, mesh=mesh,
        in_specs=(pspec_in, P(bat, None)),
        out_specs=(P(bat, None), aux_spec),
        check_vma=False,
    )(routed, x2d)
    # always-on branches (shared experts / arctic dense residual) run as
    # plain GSPMD matmuls outside the expert shard_map — they are dense
    # compute, and XLA can overlap them with the routed path
    y = y + _extras(params, x2d, cfg)
    aux = MoEAux(aux_loss=jnp.mean(aux.aux_loss),
                 load=jnp.mean(aux.load, axis=0),
                 dropped=jnp.mean(aux.dropped))
    return y.reshape(B, S, D), aux


def moe_forward(params, x, cfg: ModelConfig,
                production: bool = True) -> Tuple[jnp.ndarray, MoEAux]:
    if production and shardctx.current_mesh() is not None:
        return moe_sharded(params, x, cfg)
    return moe_dense(params, x, cfg)
