"""Model assembly: every assigned architecture as one composable stack.

The layer sequence is factored into ``R`` repetitions of the arch's block
pattern (``('attn',)`` for dense, ``('ssm',)`` for mamba, ``('rglru',
'rglru', 'attn')`` for recurrentgemma, ...) plus an unrolled remainder.
Repetitions run under one ``jax.lax.scan`` with parameters stacked on a
leading ``R`` axis, so the lowered HLO (and compile time) is O(1) in depth —
mandatory for the 96-layer dry-run configs.

Three entry points, one per program phase (the per-phase granularity at
which the AMOEBA controller reconfigures the mesh):

* :func:`loss_fn`       — full-sequence teacher-forced LM loss (train_4k)
* :func:`prefill`       — full-sequence forward that builds decode state
                          (prefill_32k)
* :func:`decode_step`   — one new token against the cached state
                          (decode_32k / long_500k)

The LM loss streams over sequence chunks (``lax.scan`` + ``jax.checkpoint``)
so the fp32 (B, S, V) logits tensor is never materialized — for the
256k-vocab configs that is the difference between fitting HBM and not.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, layers, moe, rglru, ssm
from repro.models.attention import KVCache
from repro.models.moe import MoEAux
from repro.models.rglru import RGLRUState
from repro.models.ssm import SSMState
from repro.parallel import shardctx


# ---------------------------------------------------------------------------
# Runtime options (static over a jit)
# ---------------------------------------------------------------------------

class Runtime(NamedTuple):
    """Static execution knobs threaded through the stack."""
    use_kernels: bool = False     # Pallas kernels (TPU) vs pure-jnp oracles
    production: bool = True       # sharded MoE vs dense oracle
    remat: bool = True            # per-block activation checkpointing
    q_block: int = 512            # attention q/kv block sizes
    kv_block: int = 1024
    loss_chunk: int = 512         # vocab-loss sequence chunk
    # Megatron-SP: residual stream sharded over 'model' on the sequence dim
    # between blocks — saved remat residuals shrink by the TP width (the
    # difference between 340B fitting v5e HBM and not).
    seq_shard: bool = False
    # int8 KV cache (+ per-vector scales): ~2x less decode HBM traffic
    # (beyond-paper optimization, EXPERIMENTS.md §Perf C2)
    kv_quant: bool = False


DEFAULT_RT = Runtime()


def _pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.block_pattern is not None:
        return tuple(cfg.block_pattern)
    return ("ssm",) if cfg.family == "ssm" else ("attn",)


def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    return kind != "ssm" and (cfg.moe is not None or cfg.d_ff > 0)


def _zero_aux(cfg: ModelConfig) -> MoEAux:
    e = cfg.moe.num_experts if cfg.moe is not None else 1
    return MoEAux(aux_loss=jnp.zeros(()), load=jnp.zeros((e,)),
                  dropped=jnp.zeros(()))


def _add_aux(a: MoEAux, b: MoEAux) -> MoEAux:
    return MoEAux(aux_loss=a.aux_loss + b.aux_loss,
                  load=a.load + b.load, dropped=a.dropped + b.dropped)


# ---------------------------------------------------------------------------
# One block: norm -> mixer -> (cross-attn) -> norm -> ffn, pre-norm residual
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str, cross: bool = False):
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    params: Dict[str, Any] = {}
    pspecs: Dict[str, Any] = {}
    params["norm1"], pspecs["norm1"] = layers.init_rmsnorm(cfg.d_model, dtype)
    if kind == "attn":
        params["mixer"], pspecs["mixer"] = attention.init_attention(ks[0], cfg)
    elif kind == "ssm":
        params["mixer"], pspecs["mixer"] = ssm.init_ssm(ks[0], cfg)
    elif kind == "rglru":
        params["mixer"], pspecs["mixer"] = rglru.init_rglru(ks[0], cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cross and kind == "attn":
        params["cross_norm"], pspecs["cross_norm"] = \
            layers.init_rmsnorm(cfg.d_model, dtype)
        params["cross_attn"], pspecs["cross_attn"] = \
            attention.init_attention(ks[1], cfg, cross=True)
    if _has_ffn(cfg, kind):
        params["norm2"], pspecs["norm2"] = layers.init_rmsnorm(cfg.d_model, dtype)
        if cfg.moe is not None:
            params["ffn"], pspecs["ffn"] = moe.init_moe(ks[2], cfg)
        else:
            params["ffn"], pspecs["ffn"] = layers.init_mlp(
                ks[2], cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return params, pspecs


def _pin_block_params(params: Dict[str, Any], kind: str,
                      cfg: Optional[ModelConfig] = None) -> Dict[str, Any]:
    """Re-assert the FSDP sharding of the big per-layer weights.

    Inside a scan-over-layers XLA is free to hoist the 'data'-axis
    all-gather of the whole stacked weight out of the loop — materializing
    an unsharded copy of every layer at once (tens of GB at 340B scale).
    Pinning each slice to its stored sharding keeps the gather inside the
    (rematted) block, so only one layer's weights are ever live.
    """
    kv_spec = ("data", "model") if (cfg is not None
                                    and cfg.num_kv_heads % 4 == 0) \
        else ("data", None)
    pins = {"wq": ("data", "model"), "wk": kv_spec,
            "wv": kv_spec, "wo": ("model", "data"),
            "wi_gate": ("data", "model"), "wi_up": ("data", "model"),
            "in_proj": ("data", "model"), "out_proj": ("model", "data"),
            "in_x": ("data", "model"), "in_gate": ("data", "model"),
            "wa": ("data", "model"), "wx": ("data", "model"),
            "out": ("model", "data")}

    def pin(tree):
        out = dict(tree)
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = pin(v)
            elif k in pins and hasattr(v, "ndim") and v.ndim == 2:
                out[k] = shardctx.hint(v, *pins[k])
            elif k in ("wi_gate", "wi_up", "wo") and hasattr(v, "ndim") \
                    and v.ndim == 3:   # expert banks (E, D, F)
                spec = ("model", "data", None) if k != "wo" \
                    else ("model", None, "data")
                out[k] = shardctx.hint(v, *spec)
        return out

    return pin(params)


def block_forward(params, x, positions, encoder_out, cfg: ModelConfig,
                  kind: str, rt: Runtime, *, causal: bool = True,
                  build_cache: bool = False, cache_window: Optional[int] = None):
    """Full-sequence block. Returns (x, aux, cache_or_None)."""
    if rt.production and shardctx.current_mesh() is not None:
        params = _pin_block_params(params, kind, cfg)

    def gather_seq(h):
        # Megatron-SP transition: residual/norms live S-sharded over
        # 'model'; compute regions run on the gathered sequence (otherwise
        # the partitioner replicates the weights instead — fatal at 340B).
        # Double constraint asks the partitioner to materialize the bf16
        # norm output S-sharded before gathering (so the SP all-gather
        # moves bf16, not the fp32 intermediate).  §Perf iteration A1:
        # XLA-CPU's partitioner ignores the ordering and gathers fp32
        # anyway (hypothesis refuted there); kept because the constraint is
        # free and the TPU partitioner honors operand-dtype boundaries.
        if rt.seq_shard:
            h = shardctx.hint(h, "batch", "model", None)
            return shardctx.hint(h, "batch", None, None)
        return h

    def scatter_seq(y):
        # inverse transition: sublayer outputs return to the S-sharded
        # residual stream.  Intended to lower the TP combine as a
        # reduce-scatter; XLA-CPU still emits all-reduce + slice (§Perf A1,
        # refuted on this backend), but the constraint is what the TPU
        # partitioner needs to pick reduce-scatter.
        if rt.seq_shard:
            return shardctx.hint(y, "batch", "model", None)
        return y

    h = gather_seq(layers.rmsnorm(params["norm1"], x, cfg.norm_eps))
    cache = None
    if kind == "attn":
        mix = attention.full_attention(
            params["mixer"], h, positions, cfg, causal=causal,
            use_flash=rt.use_kernels, q_block=rt.q_block, kv_block=rt.kv_block)
        if build_cache:
            cache = {"self": attention.prefill_cache(
                params["mixer"], h, positions, cfg,
                window_override=cache_window, quant=rt.kv_quant)}
    elif kind == "ssm":
        out = ssm.ssm_forward(params["mixer"], h, cfg,
                              use_kernel=rt.use_kernels,
                              return_state=build_cache)
        if build_cache:
            mix, st = out
            cache = {"self": st}
        else:
            mix = out
    else:  # rglru
        out = rglru.rglru_forward(params["mixer"], h, cfg,
                                  use_kernel=rt.use_kernels,
                                  return_state=build_cache)
        if build_cache:
            mix, st = out
            cache = {"self": st}
        else:
            mix = out
    x = x + scatter_seq(mix)
    if "cross_attn" in params and encoder_out is not None:
        h = gather_seq(layers.rmsnorm(params["cross_norm"], x, cfg.norm_eps))
        x = x + attention.full_attention(
            params["cross_attn"], h, None, cfg, causal=False,
            encoder_out=encoder_out, q_block=rt.q_block, kv_block=rt.kv_block)
        if build_cache:
            cache["cross"] = attention.build_cross_cache(
                params["cross_attn"], encoder_out, cfg)
    aux = _zero_aux(cfg)
    if "ffn" in params:
        h = gather_seq(layers.rmsnorm(params["norm2"], x, cfg.norm_eps))
        if cfg.moe is not None:
            y, aux = moe.moe_forward(params["ffn"], h, cfg,
                                     production=rt.production)
        else:
            y = layers.mlp(params["ffn"], h, cfg.activation)
        x = x + scatter_seq(y)
    x = shardctx.hint(x, "batch", "model" if rt.seq_shard else None, None)
    return x, aux, cache


def block_decode(params, state, x_new, pos, cfg: ModelConfig, kind: str,
                 rt: Runtime, rope_pos=None):
    """One-token block step. x_new: (B,1,D). Returns (x, new_state)."""
    h = layers.rmsnorm(params["norm1"], x_new, cfg.norm_eps)
    new_state = dict(state)
    if kind == "attn":
        mix, new_state["self"] = attention.decode_attention(
            params["mixer"], state["self"], h, pos, cfg, rope_pos=rope_pos)
    elif kind == "ssm":
        mix, new_state["self"] = ssm.ssm_step(
            params["mixer"], state["self"], h, cfg)
    else:
        mix, new_state["self"] = rglru.rglru_step(
            params["mixer"], state["self"], h, cfg)
    x = x_new + mix
    if "cross" in state:
        h = layers.rmsnorm(params["cross_norm"], x, cfg.norm_eps)
        enc_len = state["cross"].k.shape[1]
        enc_pos = jnp.full((x.shape[0],), enc_len, jnp.int32)
        out, _ = attention.decode_attention(
            params["cross_attn"], state["cross"], h, enc_pos, cfg,
            update=False, cross=True)
        x = x + out
    if "ffn" in params:
        h = layers.rmsnorm(params["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe.moe_forward(params["ffn"], h, cfg,
                                   production=rt.production)
        else:
            y = layers.mlp(params["ffn"], h, cfg.activation)
        x = x + y
    return x, new_state


# ---------------------------------------------------------------------------
# Whole-model parameters
# ---------------------------------------------------------------------------

def _stack_blocks(pairs):
    """[(params, pspecs)] with identical structure -> (stacked, pspecs+lead)."""
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *[p for p, _ in pairs])
    is_p = lambda x: isinstance(x, P)
    pspecs = jax.tree.map(lambda s: P(*((None,) + tuple(s))),
                          pairs[0][1], is_leaf=is_p)
    return params, pspecs


def init_model(key, cfg: ModelConfig):
    """Returns (params, pspecs). Run under jax.eval_shape for the dry-run."""
    pattern = _pattern(cfg)
    L, PL = cfg.num_layers, len(pattern)
    R, rem = divmod(L, PL)
    keys = jax.random.split(key, 3 + L + cfg.encoder_layers)
    dtype = jnp.dtype(cfg.dtype)

    params: Dict[str, Any] = {}
    pspecs: Dict[str, Any] = {}
    params["embed"], pspecs["embed"] = layers.init_embedding(
        keys[0], cfg.vocab_size, cfg.d_model, dtype, cfg.tie_embeddings)
    params["final_norm"], pspecs["final_norm"] = \
        layers.init_rmsnorm(cfg.d_model, dtype)

    cross = cfg.cross_attention
    kidx = 3
    if R > 0:
        reps_p, reps_s = [], []
        for i, kind in enumerate(pattern):
            pairs = []
            for r in range(R):
                pairs.append(init_block(keys[kidx + r * PL + i], cfg, kind,
                                        cross=cross))
            sp, ss = _stack_blocks(pairs)
            reps_p.append(sp)
            reps_s.append(ss)
        params["reps"] = tuple(reps_p)
        pspecs["reps"] = tuple(reps_s)
    kidx += R * PL
    if rem:
        rest_p, rest_s = [], []
        for j in range(rem):
            p, s = init_block(keys[kidx + j], cfg, pattern[j % PL], cross=cross)
            rest_p.append(p)
            rest_s.append(s)
        params["rest"] = tuple(rest_p)
        pspecs["rest"] = tuple(rest_s)

    if cfg.encoder_layers:
        pairs = [init_block(keys[3 + L + e], cfg, "attn", cross=False)
                 for e in range(cfg.encoder_layers)]
        params["encoder"], pspecs["encoder"] = _stack_blocks(pairs)
        params["enc_norm"], pspecs["enc_norm"] = \
            layers.init_rmsnorm(cfg.d_model, dtype)
    return params, pspecs


def model_pspecs(cfg: ModelConfig):
    """Parameter PartitionSpec tree without allocating any parameters."""
    holder = {}

    def f(key):
        p, s = init_model(key, cfg)
        holder["pspecs"] = s     # static python objects captured at trace time
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, holder["pspecs"]


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Input embedding / positions per family
# ---------------------------------------------------------------------------

def _mrope_positions(B: int, S: int, n_vision: int) -> jnp.ndarray:
    """(B, 3, S) (temporal, h, w) M-RoPE indices: a vision-patch grid prefix
    followed by text positions (all three components advance together)."""
    idx = jnp.arange(S)
    side = max(1, int(math.ceil(math.sqrt(max(n_vision, 1)))))
    is_vis = idx < n_vision
    t = jnp.where(is_vis, 0, idx - n_vision + side)
    h = jnp.where(is_vis, idx // side, idx - n_vision + side)
    w = jnp.where(is_vis, idx % side, idx - n_vision + side)
    pos = jnp.stack([t, h, w], axis=0)                       # (3, S)
    return jnp.broadcast_to(pos[None], (B, 3, S)).astype(jnp.int32)


def embed_inputs(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    """-> (x (B,S,D), positions, encoder_out_or_None)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = layers.embed(params["embed"], tokens)
    encoder_out = None
    if cfg.encoder_layers:
        # whisper: conv frontend is a stub — precomputed frame embeddings.
        enc = batch["audio_embeds"]
        enc = enc + layers.sinusoidal_positions(
            enc.shape[1], cfg.d_model).astype(enc.dtype)
        encoder_out = encode(params, enc, cfg)
        x = x + layers.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
        positions = None                      # sinusoidal, no RoPE
    elif cfg.vision_stub and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(x.dtype)         # (B, V, D)
        V = vis.shape[1]
        x = jnp.concatenate([vis, x[:, V:]], axis=1)
        positions = _mrope_positions(B, S, V)
    elif cfg.mrope:
        positions = _mrope_positions(B, S, 0)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    x = shardctx.hint(x, "batch", None, None)
    return x, positions, encoder_out


def encode(params, enc_in: jnp.ndarray, cfg: ModelConfig,
           rt: Runtime = DEFAULT_RT) -> jnp.ndarray:
    """Whisper encoder: bidirectional attention over frame embeddings."""
    def body(x, blk_params):
        def one(p, x):
            y, _, _ = block_forward(p, x, None, None, cfg, "attn", rt,
                                    causal=False)
            return y
        f = jax.checkpoint(one) if rt.remat else one
        return f(blk_params, x), None

    x, _ = jax.lax.scan(body, enc_in, params["encoder"])
    return layers.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Full-sequence forward (shared by loss / logits / prefill)
# ---------------------------------------------------------------------------

def forward_hidden(params, x, positions, encoder_out, cfg: ModelConfig,
                   rt: Runtime, build_cache: bool = False,
                   cache_window: Optional[int] = None):
    """Runs the decoder stack. Returns (hidden, aux, caches)."""
    pattern = _pattern(cfg)
    PL = len(pattern)
    aux = _zero_aux(cfg)
    caches_rep, caches_rest = None, None

    def one_block(p, x, positions, encoder_out, kind):
        return block_forward(p, x, positions, encoder_out, cfg, kind, rt,
                             causal=True, build_cache=build_cache,
                             cache_window=cache_window)

    if "reps" in params:
        def rep_body(carry, rep_params):
            x, aux = carry
            caches = []
            for i, kind in enumerate(pattern):
                f = partial(one_block, kind=kind)
                if rt.remat and not build_cache:
                    f = jax.checkpoint(f)
                x, a, c = f(rep_params[i], x, positions, encoder_out)
                aux = _add_aux(aux, a)
                caches.append(c)
            ys = tuple(caches) if build_cache else None
            return (x, aux), ys

        (x, aux), caches_rep = jax.lax.scan(rep_body, (x, aux), params["reps"])

    if "rest" in params:
        caches = []
        for j, p in enumerate(params["rest"]):
            kind = pattern[j % PL]
            f = partial(one_block, kind=kind)
            if rt.remat and not build_cache:
                f = jax.checkpoint(f)
            x, a, c = f(p, x, positions, encoder_out)
            aux = _add_aux(aux, a)
            caches.append(c)
        caches_rest = tuple(caches) if build_cache else None

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, (caches_rep, caches_rest)


def logits_fn(params, batch, cfg: ModelConfig, rt: Runtime = DEFAULT_RT):
    """Full (B,S,V) logits — smoke-test scale only."""
    x, positions, enc = embed_inputs(params, batch, cfg)
    x, aux, _ = forward_hidden(params, x, positions, enc, cfg, rt)
    return layers.unembed(params["embed"], x, cfg.tie_embeddings), aux


# ---------------------------------------------------------------------------
# Training loss (chunked over sequence, vocab sharded over 'model')
# ---------------------------------------------------------------------------

def _chunked_lm_loss(params, x, tokens, cfg: ModelConfig, chunk: int):
    """Mean NLL of tokens[:,1:] given hidden x[:,:-1]; O(chunk·V) memory."""
    B, S, D = x.shape
    n = S - 1
    xs, tg = x[:, :-1], tokens[:, 1:]
    c = min(chunk, n)
    nc = -(-n // c)
    pad = nc * c - n
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        tg = jnp.pad(tg, ((0, 0), (0, pad)))
    valid = (jnp.arange(nc * c) < n).astype(jnp.float32)     # (nc*c,)
    xs = xs.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    tg = tg.reshape(B, nc, c).transpose(1, 0, 2)
    vd = valid.reshape(nc, c)

    def chunk_nll(xc, tc, vc):
        logits = layers.unembed(params["embed"], xc, cfg.tie_embeddings)
        logits = shardctx.hint(logits, "batch", None, "model")
        lg = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(lg, axis=-1)       # (B, c)
        picked = jnp.take_along_axis(lg, tc[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - picked) * vc[None, :])

    body_fn = jax.checkpoint(chunk_nll)

    def body(acc, inp):
        xc, tc, vc = inp
        return acc + body_fn(xc, tc, vc), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, tg, vd))
    return total / (B * n)


def loss_fn(params, batch, cfg: ModelConfig, rt: Runtime = DEFAULT_RT):
    """-> (loss, metrics). metrics carries the AMOEBA divergence signals."""
    x, positions, enc = embed_inputs(params, batch, cfg)
    x, aux, _ = forward_hidden(params, x, positions, enc, cfg, rt)
    lm = _chunked_lm_loss(params, x, batch["tokens"], cfg, rt.loss_chunk)
    loss = lm
    n_moe = sum(1 for k in cfg.layer_kinds if k != "ssm") or 1
    metrics = {"lm_loss": lm}
    if cfg.moe is not None:
        aux_mean = aux.aux_loss / n_moe
        loss = loss + cfg.moe.router_aux_loss * aux_mean
        metrics.update(moe_aux=aux_mean, expert_load=aux.load / n_moe,
                       dropped_frac=aux.dropped / n_moe)
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode state: prefill + one-token step
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    pos: jnp.ndarray                       # (B,) next absolute position
    rope_offset: jnp.ndarray               # (B,) rope_pos = pos + offset (M-RoPE)
    reps: Any                              # tuple per pattern position, stacked (R, ...)
    rest: Any                              # tuple per remainder layer


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int,
                      enc_len: int = 0, kv_quant: bool = False) -> DecodeState:
    """Zero-initialized state sized for a seq_len-token context window."""
    pattern = _pattern(cfg)
    L, PL = cfg.num_layers, len(pattern)
    R, rem = divmod(L, PL)

    def one(kind):
        if kind == "attn":
            st = {"self": attention.init_cache(cfg, batch, seq_len,
                                               quant=kv_quant)}
            if cfg.cross_attention:
                hd = cfg.resolved_head_dim
                z = jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd),
                              jnp.dtype(cfg.dtype))
                st["cross"] = KVCache(k=z, v=z)
            return st
        if kind == "ssm":
            return {"self": ssm.init_ssm_state(cfg, batch)}
        return {"self": rglru.init_rglru_state(cfg, batch)}

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape),
                            tree)

    reps = tuple(stack(one(k), R) for k in pattern) if R else ()
    rest = tuple(one(pattern[j % PL]) for j in range(rem))
    return DecodeState(pos=jnp.zeros((batch,), jnp.int32),
                       rope_offset=jnp.zeros((batch,), jnp.int32),
                       reps=reps, rest=rest)


def decode_state_pspecs(cfg: ModelConfig, kv_quant: bool = False):
    """PartitionSpec tree matching init_decode_state (leading scan dim on reps).

    Uses the 'batch' placeholder resolved by repro.parallel.resolve.
    """
    pattern = _pattern(cfg)
    L, PL = cfg.num_layers, len(pattern)
    R, rem = divmod(L, PL)

    def one(kind):
        if kind == "attn":
            st = {"self": attention.cache_pspec(quant=kv_quant)}
            if cfg.cross_attention:
                st["cross"] = KVCache(k=P("batch", None, None, None),
                                      v=P("batch", None, None, None))
            return st
        if kind == "ssm":
            return {"self": ssm.ssm_state_pspec()}
        return {"self": rglru.rglru_state_pspec()}

    is_p = lambda x: isinstance(x, P)
    lead = lambda t: jax.tree.map(lambda s: P(*((None,) + tuple(s))), t,
                                  is_leaf=is_p)
    reps = tuple(lead(one(k)) for k in pattern) if R else ()
    rest = tuple(one(pattern[j % PL]) for j in range(rem))
    return DecodeState(pos=P("batch"), rope_offset=P("batch"),
                       reps=reps, rest=rest)


def prefill(params, batch, cfg: ModelConfig, rt: Runtime = DEFAULT_RT,
            window: Optional[int] = None):
    """Full-sequence forward that also builds the decode state.

    Returns (last_logits (B, V), DecodeState).  ``window`` sets the decode
    horizon (cache length); defaults to the prompt length — pass the full
    generation horizon when decoding past the prompt with dense attention.
    """
    x, positions, enc = embed_inputs(params, batch, cfg)
    x, _, (caches_rep, caches_rest) = forward_hidden(
        params, x, positions, enc, cfg, rt, build_cache=True,
        cache_window=window)
    last = x[:, -1]
    logits = layers.unembed(params["embed"], last[:, None],
                            cfg.tie_embeddings)[:, 0]
    B, S = batch["tokens"].shape
    pos = jnp.full((B,), S, jnp.int32)
    # M-RoPE: text positions run (i - V + side); carry the offset for decode
    offset = jnp.zeros((B,), jnp.int32)
    if cfg.vision_stub and "vision_embeds" in batch:
        V = batch["vision_embeds"].shape[1]
        side = max(1, int(math.ceil(math.sqrt(max(V, 1)))))
        offset = jnp.full((B,), side - V, jnp.int32)
    return logits, DecodeState(pos=pos, rope_offset=offset,
                               reps=caches_rep or (),
                               rest=caches_rest or ())


def decode_step(params, state: DecodeState, new_tokens: jnp.ndarray,
                cfg: ModelConfig, rt: Runtime = DEFAULT_RT):
    """new_tokens: (B, 1) int32 -> (logits (B, V), new DecodeState)."""
    pattern = _pattern(cfg)
    PL = len(pattern)
    pos = state.pos
    rope_pos = pos + state.rope_offset
    x = layers.embed(params["embed"], new_tokens)            # (B,1,D)
    if cfg.encoder_layers:
        # sinusoidal position of the new token
        d = cfg.d_model
        half = d // 2
        freq = jnp.exp(-math.log(10_000.0)
                       * jnp.arange(half, dtype=jnp.float32) / (half - 1))
        ang = pos.astype(jnp.float32)[:, None] * freq[None, :]
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(x.dtype)
        x = x + pe[:, None, :]
    x = shardctx.hint(x, "batch", None, None)

    new_reps = ()
    if state.reps:
        def rep_body(x, inp):
            rep_params, rep_states = inp
            new_states = []
            for i, kind in enumerate(pattern):
                x, ns = block_decode(rep_params[i], rep_states[i], x, pos,
                                     cfg, kind, rt, rope_pos=rope_pos)
                new_states.append(ns)
            return x, tuple(new_states)

        x, new_reps = jax.lax.scan(rep_body, x, (params["reps"], state.reps))

    new_rest = []
    for j, p in enumerate(params.get("rest", ())):
        x, ns = block_decode(p, state.rest[j], x, pos, cfg, pattern[j % PL],
                             rt, rope_pos=rope_pos)
        new_rest.append(ns)

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = layers.unembed(params["embed"], x, cfg.tie_embeddings)[:, 0]
    return logits, DecodeState(pos=pos + 1, rope_offset=state.rope_offset,
                               reps=new_reps, rest=tuple(new_rest))
