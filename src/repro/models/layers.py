"""Shared layer primitives: norms, MLPs, RoPE / M-RoPE, embeddings.

All parameters are plain pytrees (nested dicts of ``jnp.ndarray``).  Every
``init_*`` returns ``(params, pspecs)`` where ``pspecs`` mirrors the param
tree with ``jax.sharding.PartitionSpec`` leaves — the distribution layer
turns those into ``NamedSharding`` for the production mesh.

Sharding vocabulary (logical axes):
  * ``"model"``  — tensor-parallel axis (heads / d_ff / experts / vocab-out)
  * ``"data"``   — FSDP axis: weights additionally sharded on a non-model
    dimension and all-gathered per layer inside the scan (ZeRO-3 semantics
    under GSPMD).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def truncated_normal(key, shape, stddev, dtype):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype) -> Tuple[dict, dict]:
    return {"scale": jnp.ones((dim,), dtype)}, {"scale": P(None)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)).astype(dtype)


def rmsnorm_headwise(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6):
    """QK-norm: normalize the trailing head_dim of (..., H, hd)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype):
    """Gated (swiglu) or 2-matrix (relu2 / gelu) MLP."""
    std_in = 1.0 / math.sqrt(d_model)
    std_out = 1.0 / math.sqrt(d_ff)
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        params = {
            "wi_gate": truncated_normal(ks[0], (d_model, d_ff), std_in, dtype),
            "wi_up": truncated_normal(ks[1], (d_model, d_ff), std_in, dtype),
            "wo": truncated_normal(ks[2], (d_ff, d_model), std_out, dtype),
        }
        pspecs = {
            "wi_gate": P("data", "model"),
            "wi_up": P("data", "model"),
            "wo": P("model", "data"),
        }
    else:
        params = {
            "wi_up": truncated_normal(ks[1], (d_model, d_ff), std_in, dtype),
            "wo": truncated_normal(ks[2], (d_ff, d_model), std_out, dtype),
        }
        pspecs = {
            "wi_up": P("data", "model"),
            "wo": P("model", "data"),
        }
    return params, pspecs


def mlp(params: dict, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    up = x @ params["wi_up"]
    if activation == "swiglu":
        gate = x @ params["wi_gate"]
        h = jax.nn.silu(gate) * up
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(up))
    elif activation == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)               # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Sequence[int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions: (B, 3, S) — (temporal, height, width) index
    per token.  The hd/2 frequency bins are partitioned into ``sections``
    (e.g. 16+24+24 = 64); each partition takes its angle from the matching
    position component.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)                 # (half,)
    ang_all = positions.astype(jnp.float32)[..., None] * freqs   # (B, 3, S, half)
    parts = []
    start = 0
    for comp, sec in enumerate(sections):
        parts.append(ang_all[:, comp, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                        # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal position embedding, (S, D)."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype, tie: bool):
    ks = jax.random.split(key, 2)
    params = {"table": truncated_normal(ks[0], (vocab, d_model), 1.0, dtype)}
    pspecs = {"table": P("data", "model")}
    if not tie:
        params["out"] = truncated_normal(
            ks[1], (d_model, vocab), 1.0 / math.sqrt(d_model), dtype)
        pspecs["out"] = P("data", "model")
    return params, pspecs


def embed(params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: dict, x: jnp.ndarray, tie: bool) -> jnp.ndarray:
    if tie:
        return x @ params["table"].T.astype(x.dtype)
    return x @ params["out"]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def next_token_loss(logits: jnp.ndarray, tokens: jnp.ndarray,
                    mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Causal LM cross-entropy, fp32 accumulation over a 'model'-sharded vocab."""
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = logz - picked
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
