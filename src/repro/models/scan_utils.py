"""Linear-recurrence scan shared by the SSM and RG-LRU blocks.

``h_t = a_t * h_{t-1} + b_t`` evaluated as a chunked associative scan:
an outer ``lax.scan`` carries the state across fixed-size chunks (bounding
peak memory to O(chunk)) while ``lax.associative_scan`` parallelizes inside
each chunk.  This is the pure-JAX oracle; ``repro.kernels`` carries the
Pallas TPU version that keeps the running state in VMEM.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _combine(left, right):
    a1, b1 = left
    a2, b2 = right
    return a2 * a1, a2 * b1 + b2


def linear_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                chunk: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Evaluate h_t = a_t h_{t-1} + b_t along axis 1.

    a, b: (B, S, ...); h0: (B, ...).  Returns (h_all (B,S,...), h_last).
    """
    B, S = a.shape[0], a.shape[1]
    c = min(chunk, S)
    n = -(-S // c)
    pad = n * c - S
    if pad:
        # identity elements: a=1, b=0 leave the state untouched
        a = jnp.concatenate([a, jnp.ones((B, pad) + a.shape[2:], a.dtype)], 1)
        b = jnp.concatenate([b, jnp.zeros((B, pad) + b.shape[2:], b.dtype)], 1)
    ar = a.reshape((B, n, c) + a.shape[2:]).swapaxes(0, 1)
    br = b.reshape((B, n, c) + b.shape[2:]).swapaxes(0, 1)

    def step(h, inp):
        ac, bc = inp                                  # (B, c, ...)
        bc = bc.at[:, 0].add(ac[:, 0] * h)            # fold carry into chunk
        _, hs = jax.lax.associative_scan(_combine, (ac, bc), axis=1)
        return hs[:, -1], hs

    h_last, chunks = jax.lax.scan(step, h0, (ar, br))
    out = chunks.swapaxes(0, 1).reshape((B, n * c) + a.shape[2:])
    return out[:, :S], h_last


def linear_scan_contract(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
                         h0: jnp.ndarray, chunk: int = 64
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused scan + state contraction for the selective SSM.

    h_t = a_t * h_{t-1} + b_t  with  a, b: (B, S, D, N);  then
    y_t = sum_n h_t[.., n] * c_t[.., n]  with  c: (B, S, N).

    Returns (y (B, S, D), h_last (B, D, N)).  The (B, S, D, N) state history
    is only ever materialized one chunk at a time — this is the pure-JAX
    mirror of what the Pallas kernel does in VMEM.
    """
    B, S, D, N = a.shape
    ck = min(chunk, S)
    n = -(-S // ck)
    pad = n * ck - S
    if pad:
        a = jnp.concatenate([a, jnp.ones((B, pad, D, N), a.dtype)], 1)
        b = jnp.concatenate([b, jnp.zeros((B, pad, D, N), b.dtype)], 1)
        c = jnp.concatenate([c, jnp.zeros((B, pad, N), c.dtype)], 1)
    ar = a.reshape(B, n, ck, D, N).swapaxes(0, 1)
    br = b.reshape(B, n, ck, D, N).swapaxes(0, 1)
    cr = c.reshape(B, n, ck, N).swapaxes(0, 1)

    def step(h, inp):
        ac, bc, cc = inp                              # (B, ck, D, N), (B, ck, N)
        bc = bc.at[:, 0].add(ac[:, 0] * h)
        _, hs = jax.lax.associative_scan(_combine, (ac, bc), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", hs, cc)
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(step, h0, (ar, br, cr))
    y = ys.swapaxes(0, 1).reshape(B, n * ck, D)
    return y[:, :S], h_last


def linear_scan_step(a: jnp.ndarray, b: jnp.ndarray,
                     h: jnp.ndarray) -> jnp.ndarray:
    """Single decode step of the same recurrence."""
    return a * h + b


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along seq.  x: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is 4 — unrolled adds fuse into one kernel
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out


def causal_conv1d_step(x_new: jnp.ndarray, conv_state: jnp.ndarray,
                       w: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decode-step conv.  x_new: (B,C); conv_state: (B,K-1,C); w: (K,C)."""
    window = jnp.concatenate([conv_state, x_new[:, None]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, w)
    return out, window[:, 1:]


def conv_tail(x: jnp.ndarray, kernel_width: int) -> jnp.ndarray:
    """Last K-1 steps of the conv input (front-padded when S < K-1).

    x: (B, S, C) -> (B, K-1, C): the decode-time conv state after a prefill.
    """
    K1 = kernel_width - 1
    B, S, C = x.shape
    if S >= K1:
        return x[:, S - K1:]
    return jnp.pad(x, ((0, 0), (K1 - S, 0), (0, 0)))
