"""Faithful reproduction of the paper's evaluation system.

The paper evaluates AMOEBA in GPGPU-Sim (Table 1 config) on 12 benchmarks,
purely on throughput.  CUDA traces cannot run here, so the reproduction is a
cycle-approximate behavioral model of exactly the machine the paper
describes — scale-out SMs, pairwise fusion, shared L1/coalescer, mesh NoC
with router bypass, divergence-driven dynamic splitting — driven by
workload profiles parameterized to the characteristics the paper reports
per benchmark.  Every figure of §5 has a corresponding harness in
``benchmarks/``.
"""
from repro.core.gpusim.sim import (
    EXTENDED_SCHEMES,
    SCHEMES,
    SimResult,
    profile_features,
    rank_chip_mixes,
    run_benchmark,
    run_all,
    FEATURE_NAMES,
)
from repro.core.gpusim.workloads import WORKLOADS, Workload, workload_variants

__all__ = [
    "EXTENDED_SCHEMES", "SCHEMES", "SimResult", "profile_features",
    "rank_chip_mixes", "run_benchmark", "run_all",
    "FEATURE_NAMES", "WORKLOADS", "Workload", "workload_variants",
]
