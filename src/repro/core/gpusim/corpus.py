"""Offline training corpus for the scalability predictor (paper §4.1.3).

"We train this binary logistic model using a large amount of offline
experimental data": for every benchmark profile and randomized variants of
it, run the simulator under both static configurations, label with the
winner, and pair the label with the §4.1.2 metrics sampled from a short
scale-out profiling window.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import predictor as P
from repro.core.gpusim.sim import (FEATURE_NAMES, profile_features,
                                   run_benchmark)
from repro.core.gpusim.workloads import WORKLOADS, workload_variants


def build_corpus(variants_per_workload: int = 24, seed: int = 0,
                 epochs: int = 48) -> Tuple[np.ndarray, np.ndarray, list]:
    """Returns (X (N, F), y (N,), names)."""
    X, y, names = [], [], []
    for base_name, base in WORKLOADS.items():
        pool = (base,) + workload_variants(base, variants_per_workload, seed)
        seed += 1
        for w in pool:
            feats = profile_features(w)
            a = run_benchmark(w, "baseline", epochs=epochs)
            b = run_benchmark(w, "scale_up", epochs=epochs)
            X.append(feats)
            y.append(1.0 if b.ipc > a.ipc else 0.0)
            names.append(w.name)
    return np.stack(X), np.asarray(y), names


def train_sim_predictor(variants_per_workload: int = 24, seed: int = 0,
                        epochs: int = 48):
    """Builds the corpus, trains, and cross-checks on the 12 base profiles.

    Returns (model, info) where info adds base-profile accuracy.
    """
    X, y, names = build_corpus(variants_per_workload, seed, epochs)
    model, info = P.train_logistic(X, y, feature_names=FEATURE_NAMES)
    correct = 0
    for name, w in WORKLOADS.items():
        feats = profile_features(w)
        pred = bool(P.predict_fuse(model, feats))
        a = run_benchmark(w, "baseline", epochs=epochs)
        b = run_benchmark(w, "scale_up", epochs=epochs)
        truth = b.ipc > a.ipc
        correct += pred == truth
    info["base_profile_accuracy"] = correct / len(WORKLOADS)
    return model, info
