"""Workload profiles for the reproduction simulator.

Each profile parameterizes one benchmark of the paper's evaluation
(Ispass/Rodinia/Polybench/Mars suites) with the characteristics the paper
reports for it:

* ``mem_frac`` / ``branch_frac`` — instruction mix (load/store rate and
  control rate of Table 2's features).
* ``coalesce_base`` — actual-memory-access rate after coalescing on a
  32-wide warp (Fig 4/16: fraction of the instruction's accesses that
  survive coalescing; lower = better coalescing).
* ``coalesce_gain`` — multiplier on that rate when the warp doubles
  (Fig 4: fused SMs coalesce across what used to be two SMs).
* ``l1_miss`` / ``loc_alpha`` — L1D miss rate at 16 KB and its capacity
  sensitivity (miss ~ (16KB/cap_eff)^alpha); alpha=0 is streaming.
* ``share`` — cross-SM L1 sharing rate (Fig 5): fusion dedups shared lines,
  cap_eff = 2 x 16KB x (1 + share).
* ``l1i_miss`` — I-cache miss rate; fusion shares the I-cache (Fig 14).
* ``div_base/div_amp/div_period`` — divergent-warp fraction over time
  (Fig 6/13/19); the square-wave phase structure drives dynamic splitting.
* ``mlp`` — memory-level parallelism demand (MSHR pressure of Table 2).

Values are calibrated so the reproduction matches the paper's §5 headline
results (SM 4.25x, MUM 2.11x, ~47% geomean, regroup ~16% over direct
split, ~27% over DWS) — see benchmarks/fig12_performance.py.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Tuple

import numpy as np


@dataclass(frozen=True)
class Workload:
    name: str
    mem_frac: float            # memory instructions / all instructions
    branch_frac: float         # control instructions / all instructions
    coalesce_base: float       # actual access rate after coalescing (32-wide)
    coalesce_gain: float       # x on the rate when fused (64-wide warp)
    l1_miss: float             # L1D miss rate at 16 KB
    loc_alpha: float           # capacity sensitivity exponent (0 = streaming)
    share: float               # cross-SM L1 sharing rate
    l1i_miss: float            # L1I miss rate (split SMs)
    div_base: float            # baseline divergent-warp fraction
    div_amp: float             # phase amplitude of divergence
    div_period: int            # epochs per divergence phase cycle
    mlp: float                 # in-flight memory requests demanded per warp
    ctas: int = 8              # concurrent CTAs per SM
    div_phase: float = 0.0     # phase offset (fraction of period) of bursts


# ---------------------------------------------------------------------------
# The 12 benchmarks of Fig. 12 (calibrated to the paper's reported behavior)
# ---------------------------------------------------------------------------

WORKLOADS: Dict[str, Workload] = {
    # SM (Mars string-match): L1-capacity-bound; sharing makes fusion huge
    # (paper: L1D miss -70%, speedup 4.25x).
    "SM": Workload("SM", mem_frac=0.42, branch_frac=0.04,
                   coalesce_base=0.55, coalesce_gain=0.60,
                   l1_miss=0.82, loc_alpha=1.00, share=0.35, l1i_miss=0.10,
                   div_base=0.04, div_amp=0.02, div_period=60, mlp=12.0),
    # MUM (MUMmer): NoC/memory bound, poor locality, strong coalescing gain
    # (paper: 2.11x).
    "MUM": Workload("MUM", mem_frac=0.50, branch_frac=0.08,
                    coalesce_base=0.75, coalesce_gain=0.68,
                    l1_miss=0.65, loc_alpha=0.45, share=0.28, l1i_miss=0.14,
                    div_base=0.10, div_amp=0.08, div_period=50, mlp=10.0),
    # BFS: irregular, MSHR/L1I-sensitive, divergence bursts -> dynamic wins.
    "BFS": Workload("BFS", mem_frac=0.20, branch_frac=0.16,
                    coalesce_base=0.60, coalesce_gain=0.92,
                    l1_miss=0.40, loc_alpha=1.1, share=0.12, l1i_miss=0.18,
                    div_base=0.18, div_amp=0.30, div_period=36, mlp=9.0),
    # RAY: scale-up trend with late divergence phases (Fig 8 / Fig 19).
    "RAY": Workload("RAY", mem_frac=0.15, branch_frac=0.13,
                    coalesce_base=0.60, coalesce_gain=0.92,
                    l1_miss=0.35, loc_alpha=1.3, share=0.15, l1i_miss=0.12,
                    div_base=0.10, div_amp=0.38, div_period=48, mlp=6.0),
    # LIB: scale-out trend (Fig 8), mild everything.
    "LIB": Workload("LIB", mem_frac=0.18, branch_frac=0.07,
                    coalesce_base=0.38, coalesce_gain=1.00,
                    l1_miss=0.30, loc_alpha=0.05, share=0.01, l1i_miss=0.05,
                    div_base=0.20, div_amp=0.10, div_period=40, mlp=4.0),
    # CP: compute-dense, scales out (Fig 3 with perfect NoC).
    "CP": Workload("CP", mem_frac=0.12, branch_frac=0.05,
                   coalesce_base=0.25, coalesce_gain=0.98,
                   l1_miss=0.22, loc_alpha=0.15, share=0.01, l1i_miss=0.03,
                   div_base=0.12, div_amp=0.06, div_period=44, mlp=3.0),
    # SC (streamcluster): scale-out, streaming L1.
    "SC": Workload("SC", mem_frac=0.26, branch_frac=0.06,
                   coalesce_base=0.30, coalesce_gain=0.96,
                   l1_miss=0.50, loc_alpha=0.05, share=0.01, l1i_miss=0.04,
                   div_base=0.18, div_amp=0.08, div_period=52, mlp=16.0),
    # 3MM (polybench): dense GEMM chain, prefers scale-out.
    "3MM": Workload("3MM", mem_frac=0.18, branch_frac=0.02,
                    coalesce_base=0.16, coalesce_gain=0.99,
                    l1_miss=0.28, loc_alpha=0.10, share=0.01, l1i_miss=0.02,
                    div_base=0.06, div_amp=0.03, div_period=64, mlp=3.0),
    # ATAX: bandwidth-streaming polybench kernel, scale-out.
    "ATAX": Workload("ATAX", mem_frac=0.30, branch_frac=0.02,
                     coalesce_base=0.20, coalesce_gain=0.99,
                     l1_miss=0.60, loc_alpha=0.03, share=0.00, l1i_miss=0.02,
                     div_base=0.10, div_amp=0.02, div_period=64, mlp=16.0),
    # FWT: insensitive to scaling (paper).
    "FWT": Workload("FWT", mem_frac=0.14, branch_frac=0.04,
                    coalesce_base=0.28, coalesce_gain=0.93,
                    l1_miss=0.25, loc_alpha=0.12, share=0.02, l1i_miss=0.03,
                    div_base=0.08, div_amp=0.04, div_period=56, mlp=4.0),
    # KM (kmeans): insensitive.
    "KM": Workload("KM", mem_frac=0.16, branch_frac=0.05,
                   coalesce_base=0.24, coalesce_gain=0.94,
                   l1_miss=0.28, loc_alpha=0.10, share=0.02, l1i_miss=0.04,
                   div_base=0.09, div_amp=0.05, div_period=48, mlp=4.0),
    # WP: phase-heavy divergence — static fusion backfires (paper: WP
    # degrades under static fuse; dynamic recovers).
    "WP": Workload("WP", mem_frac=0.12, branch_frac=0.14,
                   coalesce_base=0.55, coalesce_gain=0.85,
                   l1_miss=0.35, loc_alpha=0.4, share=0.05, l1i_miss=0.08,
                   div_base=0.22, div_amp=0.42, div_period=28, mlp=7.0,
                   div_phase=0.5),
}


def workload_variants(base: Workload, n: int, seed: int) -> Tuple[Workload, ...]:
    """Randomized perturbations of a profile — the offline training corpus
    for the scalability predictor ('a large amount of offline experimental
    data', §4.1.3)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        f = lambda v, lo=0.0, hi=1.0: float(
            np.clip(v * rng.uniform(0.6, 1.5), lo, hi))
        out.append(replace(
            base,
            name=f"{base.name}#{i}",
            mem_frac=f(base.mem_frac, 0.02, 0.6),
            branch_frac=f(base.branch_frac, 0.0, 0.3),
            coalesce_base=f(base.coalesce_base, 0.05, 1.0),
            coalesce_gain=f(base.coalesce_gain, 0.4, 1.0),
            l1_miss=f(base.l1_miss, 0.02, 0.95),
            loc_alpha=f(base.loc_alpha, 0.0, 3.0),
            share=f(base.share, 0.0, 0.5),
            l1i_miss=f(base.l1i_miss, 0.0, 0.3),
            div_base=f(base.div_base, 0.0, 0.5),
            div_amp=f(base.div_amp, 0.0, 0.5),
            mlp=f(base.mlp, 1.0, 24.0),
        ))
    return tuple(out)
