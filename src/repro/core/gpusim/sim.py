"""Cycle-approximate AMOEBA simulator (Table 1 machine, §4-§5 mechanisms).

The machine is the paper's baseline: 48 scale-out SMs (24 neighbor pairs),
SIMD width 8, warp 32, 64 MSHRs/SM, 16 KB L1/SM, 8 MCs behind a 2-stage
mesh NoC with two subnets.  Fusing a pair (paper Fig 9) merges L1s
(capacity doubles, +1 cycle), merges coalescing units (the 64-wide warp
coalesces across the former SM boundary), bypasses one NoC router (network
shrinks), and couples both datapaths behind one scheduler (divergence now
stalls a 64-wide pipe).

Dynamic splitting (Fig 10/11) decouples only the *issue* paths: "we do not
split the shared resources, such as L1 cache, register files, and NoC
interface" — so a pair has three states:

  SPLIT_BASE  — never fused: 2 narrow SMs, private L1s, 2 NoC ports.
  FUSED       — 1 wide SM: shared L1 (+1 cycle), merged coalescer, 1 port.
  QSPLIT      — split *from* fused: 2 narrow issue paths (divergent warps
                quarantined on one), but L1/MSHR/NoC stay merged; the
                64-wide coalescing gain is lost (warps are 32-wide again).

``direct_split`` cuts divergent warps in the middle (imperfect segregation);
``warp_regroup`` sorts threads into an all-slow warp and backfills idle
slots on the slow half with fast warps.

Per epoch each pair's throughput is the min of three bounds — issue
(divergence/fetch-limited), memory (MSHR Little's-law), and NoC (MC
bandwidth + interface caps) — solved to a fixed point since NoC latency
depends on injected traffic.  This three-bound structure is the same
compute/memory/collective roofline the mesh-level controller uses; the
simulator is the paper's world, the mesh is ours.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np

from repro.configs.paper_gpu import PAPER_GPU
from repro.control import (ConfigSpace, OraclePolicy, PredictorPolicy,
                           hysteresis_toggle, n_parts)
from repro.core.gpusim.workloads import WORKLOADS, Workload

# -- machine constants (derived from Table 1) -------------------------------
N_PAIRS = PAPER_GPU.num_sms // 2           # 24
ISSUE_PER_PAIR = 2 * PAPER_GPU.simd_width  # 16 issue slots/cycle/pair
LINE_BYTES = 64.0
CHAN_BYTES = PAPER_GPU.noc_channel_bits / 8          # 16 B/cycle/port
# 2 subnets (request/reply), MC side clocked at 924/700 of the core clock
NOC_CAPACITY = PAPER_GPU.num_memory_controllers * CHAN_BYTES * 2 \
    * (PAPER_GPU.mem_clock_mhz / PAPER_GPU.core_clock_mhz)
L2_LAT = PAPER_GPU.l2_latency_cycles
DRAM_LAT = PAPER_GPU.dram_latency_cycles
L2_MISS = 0.45                              # fraction of L1 misses hitting DRAM

# -- pair states -------------------------------------------------------------
SPLIT_BASE, FUSED, QSPLIT = 0, 1, 2

# -- divergence penalties (§3.1(3): wide pipes stall ~2x) --------------------
P_NARROW = 0.55
P_WIDE = 1.15
I_PEN = 0.55                                # fetch-stall weight of L1I misses
DWS_FACTOR = 0.45                           # intra-SM subdivision residual
REGROUP_Q = 0.92                            # warp_regroup segregation quality
DIRECT_Q = 0.50                             # direct mid-split segregation
BACKFILL = 0.40                             # fast-warp backfill into slow SM
SWITCH_COST = 0.06                          # epoch fraction lost per toggle
MSHR_IMBALANCE = 0.93                       # split MSHR domains pack worse

EPOCHS = 160
FEATURE_NAMES = (
    "noc_throughput", "noc_latency", "coalesce_rate", "l1d_miss",
    "l1i_miss", "l1c_miss", "mshr_rate", "inactive_rate",
    "load_insn_rate", "store_insn_rate", "concurrent_cta",
)


def _divergence(w: Workload, t: np.ndarray, jitter: np.ndarray) -> np.ndarray:
    """Divergent-warp fraction per (epoch, pair): square-wave phases."""
    off = getattr(w, "div_phase", 0.0) * w.div_period
    phase = ((t[:, None] + jitter[None, :] + off) % w.div_period) / w.div_period
    wave = (phase < 0.45).astype(np.float64)
    return np.clip(w.div_base + w.div_amp * wave, 0.0, 0.95)


def _issue_eff(w: Workload, d: np.ndarray, st: np.ndarray,
               quarantine: float, dws: bool) -> np.ndarray:
    """Issue efficiency in [0,1] per pair given divergence and state."""
    l1i = w.l1i_miss * np.where(st >= FUSED, 0.5, 1.0)
    e_fetch = 1.0 - l1i * I_PEN
    if dws:
        e_div = 1.0 - np.minimum(d * P_NARROW * DWS_FACTOR, 1.0)
    else:
        e_narrow = 1.0 - np.minimum(d * P_NARROW, 1.0)
        e_wide = 1.0 - np.minimum(d * P_WIDE, 1.0)
        q = quarantine
        d_fast = d * (1.0 - q)
        d_slow = np.minimum(2.0 * d * q, 1.0)
        e_fast = 1.0 - np.minimum(d_fast * P_NARROW, 1.0)
        e_slow = 1.0 - np.minimum(d_slow * P_NARROW, 1.0)
        if q >= REGROUP_Q:
            # regrouped slow warps are all-slow; idle slots backfilled with
            # fast warps (paper: "periodically move some fast warps")
            e_q = 0.5 * e_fast + 0.5 * (e_slow + BACKFILL * (1.0 - e_slow))
        else:
            # direct mid-cut traps fast threads inside half-slow warps on the
            # slow SM: roughly half its issue slots do no useful work
            e_q = 0.5 * e_fast + 0.5 * (0.55 * e_slow + 0.45 * e_slow * 0.5)
        e_div = np.select([st == SPLIT_BASE, st == FUSED], [e_narrow, e_wide],
                          default=e_q)
    return np.maximum(e_fetch * e_div, 0.02)


def _memory_terms(w: Workload, st: np.ndarray):
    """(miss-per-instruction, coalesce rate, l1d miss) per pair."""
    # 64-wide coalescing only while actually fused; merged L1 also in QSPLIT
    c_eff = w.coalesce_base * np.where(st == FUSED, w.coalesce_gain, 1.0)
    cap_mult = np.where(st >= FUSED, 2.0 * (1.0 + w.share), 1.0)
    mu = np.minimum(w.l1_miss * cap_mult ** (-w.loc_alpha), 0.98)
    mpi = w.mem_frac * c_eff * mu
    return mpi, c_eff, mu


def _usable_mshr(w: Workload, st: np.ndarray, dws: bool = False) -> np.ndarray:
    """Merged MSHRs (FUSED/QSPLIT) pool perfectly; split domains pack worse.

    DWS (Fig 21) subdivides warps on memory divergence so hit-threads keep
    issuing — modeled as better MSHR utilization, its intra-SM-only benefit.
    """
    split = MSHR_IMBALANCE * 2.0 * np.minimum(PAPER_GPU.mshr_per_core,
                                              w.mlp * 8.0)
    merged = np.minimum(2.0 * PAPER_GPU.mshr_per_core, w.mlp * 16.0)
    out = np.where(st >= FUSED, merged, split)
    return out * 1.35 if dws else out


@dataclass
class SimResult:
    ipc: float
    trace: np.ndarray                 # (E, N_PAIRS) int states
    control_stall: float              # Fig 13
    l1i_miss: float                   # Fig 14
    l1d_miss: float                   # Fig 15
    actual_mem_rate: float            # Fig 16
    noc_stall: float                  # Fig 17
    injection_rate: float             # Fig 18 (bytes/cycle/router)
    switches: int = 0


def _epoch_throughput(w: Workload, st: np.ndarray, d: np.ndarray,
                      quarantine: float, dws: bool):
    """Fixed-point solve of the three bounds for one epoch.

    Returns (ipc_per_pair, stats dict).
    """
    e = _issue_eff(w, d, st, quarantine, dws)
    ipc_compute = ISSUE_PER_PAIR * e
    mpi, c_eff, mu = _memory_terms(w, st)
    mshr = _usable_mshr(w, st, dws)

    n_routers = int(PAPER_GPU.num_sms - (st >= FUSED).sum()) \
        + PAPER_GPU.num_memory_controllers
    side = math.sqrt(n_routers)
    hops = (2.0 / 3.0) * side
    base_rtt = 2.0 * hops * (PAPER_GPU.noc_router_stages + 1)

    iface_cap = np.where(st >= FUSED, CHAN_BYTES, 2 * CHAN_BYTES)

    ipc = ipc_compute.copy()
    rho = 0.0
    for _ in range(8):
        traffic = ipc * mpi * LINE_BYTES                  # B/cycle/pair
        total = traffic.sum()
        rho = min(total / NOC_CAPACITY, 0.995)
        congestion = 1.0 / (1.0 - min(rho, 0.90))
        rtt = base_rtt * congestion
        lat = L2_LAT + rtt + L2_MISS * DRAM_LAT + np.where(st >= FUSED, 1., 0.)
        ipc_mem = mshr / np.maximum(mpi * lat, 1e-9)
        ipc_iface = iface_cap / np.maximum(mpi * LINE_BYTES, 1e-9)
        ipc_new = np.minimum.reduce([ipc_compute, ipc_mem, ipc_iface])
        # hard MC-bandwidth constraint: aggregate traffic <= NoC capacity
        total_new = (ipc_new * mpi * LINE_BYTES).sum()
        if total_new > NOC_CAPACITY:
            ipc_new = ipc_new * (NOC_CAPACITY / total_new)
        ipc = 0.5 * ipc + 0.5 * ipc_new

    e_fetch = 1.0 - (w.l1i_miss * np.where(st >= FUSED, .5, 1.)) * I_PEN
    stats = {
        "rho": rho,
        "control_stall": float(np.mean(1.0 - e / np.maximum(e_fetch, 1e-9))),
        "l1i_miss": float(np.mean(w.l1i_miss * np.where(st >= FUSED, .5, 1.))),
        "l1d_miss": float(np.mean(mu)),
        "actual_mem_rate": float(np.mean(c_eff)),
        "noc_stall": float(max(0.0, rho - 0.85) / 0.15),
        "injection_rate": float((ipc * mpi * LINE_BYTES).sum() / n_routers),
    }
    return ipc, stats


def _pair_estimate(w: Workload, st: np.ndarray, d: np.ndarray,
                   quarantine: float, dws: bool, rho: float) -> np.ndarray:
    """Per-pair throughput estimate for the switch controller (no global
    fixed point: uses last epoch's congestion and an equal NoC share)."""
    e = _issue_eff(w, d, st, quarantine, dws)
    ipc_c = ISSUE_PER_PAIR * e
    mpi, _, _ = _memory_terms(w, st)
    mshr = _usable_mshr(w, st, dws)
    congestion = 1.0 / (1.0 - min(rho, 0.90))
    n_routers = PAPER_GPU.num_sms - int((st >= FUSED).sum()) \
        + PAPER_GPU.num_memory_controllers
    rtt = 2.0 * (2.0 / 3.0) * math.sqrt(n_routers) \
        * (PAPER_GPU.noc_router_stages + 1) * congestion
    lat = L2_LAT + rtt + L2_MISS * DRAM_LAT
    ipc_mem = mshr / np.maximum(mpi * lat, 1e-9)
    iface = np.where(st >= FUSED, CHAN_BYTES, 2 * CHAN_BYTES)
    ipc_iface = iface / np.maximum(mpi * LINE_BYTES, 1e-9)
    ipc_cap = (NOC_CAPACITY / N_PAIRS) / np.maximum(mpi * LINE_BYTES, 1e-9)
    if rho < 0.9:                     # capacity not binding — ignore share
        ipc_cap = np.full_like(ipc_cap, np.inf)
    return np.minimum.reduce([ipc_c, ipc_mem, ipc_iface, ipc_cap])


# ---------------------------------------------------------------------------
# Profiling (paper §4.1.1: one CTA / short sample predicts the kernel)
# ---------------------------------------------------------------------------

def profile_features(w: Workload) -> np.ndarray:
    """Sample the §4.1.2 metrics from a short scale-out profiling window."""
    st = np.full(N_PAIRS, SPLIT_BASE)
    jitter = (np.arange(N_PAIRS) * 7) % w.div_period
    # single-CTA sampling (§4.1.1): the short window sees pair-0's phase only
    d0 = float(_divergence(w, np.arange(4), jitter[:1]).mean())
    d = np.full(N_PAIRS, d0)
    ipc, stats = _epoch_throughput(w, st, d, DIRECT_Q, False)
    mpi, c_eff, mu = _memory_terms(w, st)
    traffic = float((ipc * mpi * LINE_BYTES).sum())
    rho = min(traffic / NOC_CAPACITY, 0.995)
    n_routers = PAPER_GPU.num_sms + PAPER_GPU.num_memory_controllers
    rtt = 2.0 * (2.0 / 3.0) * math.sqrt(n_routers) \
        * (PAPER_GPU.noc_router_stages + 1) / (1.0 - min(rho, 0.90))
    lat = L2_LAT + rtt + L2_MISS * DRAM_LAT
    inflight = float(np.mean(ipc * mpi * lat))
    mshr_rate = inflight / (2 * PAPER_GPU.mshr_per_core)
    inactive = float(np.mean(d)) * P_NARROW
    return np.array([
        rho,                          # noc_throughput (utilization)
        rtt,                          # noc_latency
        float(np.mean(c_eff)),        # coalesce rate (actual access rate)
        float(np.mean(mu)),           # l1d miss
        w.l1i_miss,                   # l1i miss
        0.05,                         # l1c (constant cache) miss — tiny
        mshr_rate,                    # MSHR occupancy
        inactive,                     # inactive thread rate
        0.6 * w.mem_frac,             # load instruction rate
        0.4 * w.mem_frac,             # store instruction rate
        float(w.ctas),                # concurrent CTAs
    ])


# ---------------------------------------------------------------------------
# Heterogeneous static chips (Fig 12): rank chip-level compositions
# ---------------------------------------------------------------------------

MIX_FRACTIONS = (0.0, 0.25, 0.5, 0.75, 1.0)


def _mix_state(n_fused: int) -> np.ndarray:
    """A static heterogeneous chip: the first ``n_fused`` pairs fused,
    the rest split — the paper fuses *neighboring* SMs, so a chip
    composition is exactly which contiguous pairs run wide."""
    st = np.full(N_PAIRS, SPLIT_BASE)
    st[:n_fused] = FUSED
    return st


def _static_ipc(w: Workload, st: np.ndarray, epochs: int) -> float:
    jitter = (np.arange(N_PAIRS) * 7) % w.div_period
    d_all = _divergence(w, np.arange(epochs), jitter)
    total = 0.0
    for t in range(epochs):
        ipc, _ = _epoch_throughput(w, st, d_all[t], DIRECT_Q, False)
        total += float(ipc.sum())
    return total / max(epochs, 1)


def rank_chip_mixes(w: Workload, fractions=MIX_FRACTIONS,
                    epochs: int = EPOCHS // 4) -> list:
    """Rank static chip compositions (n fused pairs + rest split) by IPC.

    This is the composition-lattice view of Fig 12's heterogeneous
    chips: between the all-split baseline and the all-fused scale-up
    chip sit mixes that win when only part of the workload coalesces —
    the chip-level analogue of a serving group's ``(5, 3)`` cut.
    Returns dicts sorted best-first: ``{"mix", "n_fused", "ipc"}``.
    """
    rows = []
    for f in fractions:
        n = int(round(f * N_PAIRS))
        rows.append({"mix": f"{n}F+{N_PAIRS - n}S", "n_fused": n,
                     "ipc": _static_ipc(w, _mix_state(n), epochs)})
    rows.sort(key=lambda r: (-r["ipc"], r["n_fused"]))
    return rows


# ---------------------------------------------------------------------------
# Schemes (Fig 12): baseline / scale_up / static_fuse / direct_split /
# warp_regroup, plus DWS (Fig 21) and the static_mix composition chooser
# ---------------------------------------------------------------------------

def run_benchmark(w: Workload, scheme: str, *,
                  fuse_decider: Optional[Callable[[np.ndarray], bool]] = None,
                  epochs: int = EPOCHS,
                  split_threshold: float = 0.28,
                  fuse_threshold: float = 0.18) -> SimResult:
    """Simulate one kernel under one scheme.

    ``fuse_decider`` maps profile features -> fuse? (the trained logistic
    predictor, wrapped in the shared ``repro.control.PredictorPolicy``);
    None = the shared ``OraclePolicy`` (run both static configs, pick the
    better — used to *generate* predictor training labels).
    """
    jitter = (np.arange(N_PAIRS) * 7) % w.div_period
    dws = scheme == "dws"
    dynamic = scheme in ("direct_split", "warp_regroup")
    quarantine = {"direct_split": DIRECT_Q,
                  "warp_regroup": REGROUP_Q}.get(scheme, DIRECT_Q)

    init_st: Optional[np.ndarray] = None
    if scheme == "baseline" or dws:
        want_fused = False
    elif scheme == "scale_up":
        want_fused = True
    elif scheme == "static_mix":
        # the composition chooser: rank Fig 12's heterogeneous chips
        # (n fused pairs + rest split) and pin the best static mix
        want_fused = False
        best = rank_chip_mixes(w, epochs=max(epochs // 4, 8))[0]
        init_st = _mix_state(best["n_fused"])
    else:  # static_fuse / direct_split / warp_regroup: a shared
        # repro.control policy makes the per-kernel static choice
        feats = profile_features(w)
        if fuse_decider is not None:
            policy = PredictorPolicy.from_decider(fuse_decider)
        else:
            # (2,) is the fused pair (one wide SM), (1, 1) the split pair
            policy = OraclePolicy(
                space=ConfigSpace(capacity=2, max_ways=2),
                score=lambda t, fv: run_benchmark(
                    w, "scale_up" if n_parts(t) == 1 else "baseline",
                    epochs=epochs // 2).ipc)
        want_fused = policy.choose_static(feats)

    st = init_st if init_st is not None \
        else np.full(N_PAIRS, FUSED if want_fused else SPLIT_BASE)
    trace = np.zeros((EPOCHS if epochs is None else epochs, N_PAIRS), np.int8)
    total_ipc = 0.0
    switches = 0
    rho_prev = 0.0
    agg: Dict[str, float] = {}
    t_axis = np.arange(epochs)
    d_all = _divergence(w, t_axis, jitter)

    for t in range(epochs):
        d = d_all[t]
        toggled = np.zeros(N_PAIRS, bool)
        if dynamic and want_fused:
            # Fig 10/11: per-pair independent split/fuse with hysteresis —
            # the same repro.control primitive the serving engine runs.
            # §4.3: split only when "wide pipeline leads to a higher
            # performance degradation compared to the benefits from fusion" —
            # the switch controller estimates per-pair throughput in both
            # states (QSPLIT gives up the 64-wide coalescing gain but keeps
            # the merged L1/MSHR/NoC port) and picks the better one.
            est_f = _pair_estimate(w, np.full(N_PAIRS, FUSED), d,
                                   quarantine, dws, rho_prev)
            est_q = _pair_estimate(w, np.full(N_PAIRS, QSPLIT), d,
                                   quarantine, dws, rho_prev)
            split_now, fuse_now = hysteresis_toggle(
                st == QSPLIT, d, split_threshold, fuse_threshold,
                want_split=(st == FUSED) & (est_q > est_f),
                want_fuse=est_f > est_q * 1.02)
            toggled = split_now | fuse_now
            st = np.where(split_now, QSPLIT, st)
            st = np.where(fuse_now, FUSED, st)
            switches += int(toggled.sum())
        trace[t] = st
        ipc, stats = _epoch_throughput(w, st, d, quarantine, dws)
        rho_prev = stats.pop("rho")
        ipc = ipc * np.where(toggled, 1.0 - SWITCH_COST, 1.0)
        total_ipc += float(ipc.sum())
        for k, v in stats.items():
            agg[k] = agg.get(k, 0.0) + v

    n = float(epochs)
    return SimResult(
        ipc=total_ipc / n,
        trace=trace,
        control_stall=agg["control_stall"] / n,
        l1i_miss=agg["l1i_miss"] / n,
        l1d_miss=agg["l1d_miss"] / n,
        actual_mem_rate=agg["actual_mem_rate"] / n,
        noc_stall=agg["noc_stall"] / n,
        injection_rate=agg["injection_rate"] / n,
        switches=switches,
    )


SCHEMES = ("baseline", "scale_up", "static_fuse", "direct_split",
           "warp_regroup", "dws")
# static_mix (the chip-composition chooser) is opt-in: it multiplies the
# run cost by the ranked candidates, so it rides outside the tier-1 sweep
EXTENDED_SCHEMES = SCHEMES + ("static_mix",)


def run_all(scheme: str, fuse_decider=None,
            workloads: Optional[Dict[str, Workload]] = None
            ) -> Dict[str, SimResult]:
    wl = workloads or WORKLOADS
    return {name: run_benchmark(w, scheme, fuse_decider=fuse_decider)
            for name, w in wl.items()}
