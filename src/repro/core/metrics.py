"""Mesh-level scalability metrics — the TPU translation of §4.1.2.

Sources: ``compiled.cost_analysis()`` (FLOPs / HBM bytes), the lowered HLO
text (collective bytes; XLA's cost model does not expose them), and runtime
telemetry (MoE expert load, decode length spread).  The derived roofline
terms are the same three bounds the gpusim solves per epoch — compute,
memory, interconnect — evaluated for a compiled training/serving step on
the production mesh.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.configs.base import HardwareConfig, V5E

# HLO ops whose operand bytes cross the ICI
COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|s8|u32|u8|pred|s64|u64)"
                       r"\[([\d,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}


def _shape_bytes(text: str) -> int:
    """Total bytes of every typed shape literal in an HLO snippet."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every cross-device collective in the HLO.

    Parses the post-SPMD module: each collective line looks like
    ``%x = bf16[512,1024] all-reduce(...)``; the result shape is the payload
    that crosses the network (per participating device).
    """
    out = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        for op in COLLECTIVE_OPS:
            # match op name in the instruction position, not inside metadata
            if f"= {op}" in s or re.match(rf"\S+ = \S+ {op}\(", s) \
               or re.search(rf"\)\s*{op}\(", s):
                lhs = s.split("=", 1)
                shape_part = lhs[1].split(op)[0] if len(lhs) > 1 else s
                out[op] += _shape_bytes(shape_part)
                break
    return out


@dataclass
class StepProfile:
    """Everything the controller needs to know about one compiled phase."""
    name: str
    flops: float                      # HLO FLOPs (per device)
    hbm_bytes: float                  # HLO bytes accessed (per device)
    coll_bytes: float                 # collective payload bytes (per device)
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    peak_memory: float = 0.0          # bytes per device
    chips: int = 1
    model_flops: float = 0.0          # 6*N*D useful flops (whole step)
    per_chip_batch: float = 0.0       # tokens resident per chip
    divergence: float = 0.0           # MoE imbalance / length spread [0,1]
    raw: Dict = field(default_factory=dict)   # cost_analysis + loop details

    def roofline(self, hw: HardwareConfig = V5E) -> Dict[str, float]:
        """Three terms in seconds (per-device figures vs per-chip peaks)."""
        compute = self.flops / hw.peak_flops
        memory = self.hbm_bytes / hw.hbm_bandwidth
        coll = self.coll_bytes / hw.ici_bandwidth
        dom = max(("compute", compute), ("memory", memory),
                  ("collective", coll), key=lambda kv: kv[1])
        step = max(compute, memory, coll)
        useful = (self.model_flops / self.chips) / hw.peak_flops \
            if self.model_flops else 0.0
        return {
            "compute_s": compute,
            "memory_s": memory,
            "collective_s": coll,
            "bottleneck": dom[0],
            "step_s": step,
            "roofline_frac": useful / step if step > 0 else 0.0,
            "useful_flop_frac": (self.model_flops / self.chips) / self.flops
            if self.flops else 0.0,
        }

    def features(self) -> np.ndarray:
        """Feature vector for the mesh-level logistic predictor."""
        f = max(self.flops, 1.0)
        return np.array([
            self.coll_bytes / f,              # "NoC throughput" analogue
            self.hbm_bytes / f,               # arithmetic-intensity inverse
            np.log10(max(self.per_chip_batch, 1.0)),
            np.log10(max(self.peak_memory, 1.0)),
            self.divergence,
            np.log10(f),
        ], dtype=np.float64)


MESH_FEATURE_NAMES = (
    "coll_bytes_per_flop", "hbm_bytes_per_flop", "log_per_chip_batch",
    "log_peak_memory", "divergence", "log_flops",
)


def profile_from_compiled(name: str, lowered, compiled, *, chips: int,
                          model_flops: float = 0.0,
                          per_chip_batch: float = 0.0,
                          divergence: float = 0.0) -> StepProfile:
    """Build a StepProfile from jax .lower()/.compile() artifacts.

    XLA's ``cost_analysis`` counts while-loop bodies once, so the terms come
    from the loop-aware HLO analyzer (repro.core.hlo_analysis) instead; the
    raw cost_analysis values are kept in ``raw`` for reference.
    """
    from repro.core import hlo_analysis
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older jax returns [dict]
        cost = cost[0]
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    hc = hlo_analysis.analyze(hlo)
    mem = 0.0
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0)) + \
            float(getattr(ma, "argument_size_in_bytes", 0)) + \
            float(getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    prof = StepProfile(
        name=name, flops=hc.flops, hbm_bytes=hc.hbm_bytes,
        coll_bytes=hc.coll_bytes,
        coll_breakdown={k: int(v) for k, v in hc.coll_breakdown.items()},
        peak_memory=mem, chips=chips, model_flops=model_flops,
        per_chip_batch=per_chip_batch, divergence=divergence)
    prof.raw = {"cost_analysis_flops": float(cost.get("flops", 0.0)),
                "cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
                "unresolved_loops": hc.unresolved_loops,
                "loops": hc.loops[:50],
                "top_collectives": hc.top_collectives}
    return prof
