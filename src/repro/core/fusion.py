"""Mesh plans: the TPU translation of AMOEBA's SM fuse/split fabric.

A *plan* is a factorization of the same chips into (replica-ish axes x
model axis).  ``fuse`` merges two neighboring DP groups into one group with
2x the tensor-parallel width — parameters are stored once per fused group
(the L1-sharing analogue), the gradient all-reduce has half the
participants (router-bypass analogue), and per-group batch doubles
(coalescing analogue).  ``split`` is the inverse.  The pod axis is never
refactored — fusion happens inside a pod, like the paper fuses *neighboring*
SMs only.

Reconfiguration is not free on TPU: switching plans reshards every weight.
``reshard_cost_s`` estimates the all-to-all bytes and the controller
amortizes it against the predicted per-step win before switching
(paper §3.3: GPUs hide reconfiguration latency; we must account for it).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import HardwareConfig, MeshConfig, V5E


@dataclass(frozen=True)
class MeshPlan:
    """A named (data, model) factorization of the chip grid."""
    name: str
    data: int
    model: int
    pod: int = 1

    @property
    def shape(self) -> Tuple[int, ...]:
        return (self.pod, self.data, self.model) if self.pod > 1 \
            else (self.data, self.model)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.pod > 1 else ("data", "model")

    @property
    def num_devices(self) -> int:
        return self.pod * self.data * self.model

    def build(self, devices=None) -> Mesh:
        if devices is None:
            devices = jax.devices()
        assert len(devices) >= self.num_devices, (len(devices), self)
        arr = np.asarray(devices[: self.num_devices]).reshape(self.shape)
        return Mesh(arr, self.axes)


def plan_family(base: MeshPlan) -> Dict[str, MeshPlan]:
    """The three plans the controller arbitrates between.

    fused:     model x2, data /2   (scale-up: fuse neighboring groups)
    scale_out: model /2, data x2   (scale-out: split groups)
    """
    plans = {"base": base}
    if base.data % 2 == 0:
        plans["fused"] = dataclasses.replace(
            base, name="fused", data=base.data // 2, model=base.model * 2)
    if base.model % 2 == 0:
        plans["scale_out"] = dataclasses.replace(
            base, name="scale_out", data=base.data * 2, model=base.model // 2)
    return plans


def reshard_cost_s(param_bytes_per_chip: float,
                   hw: HardwareConfig = V5E) -> float:
    """Crude upper bound for switching plans: every chip sends + receives
    its parameter shard once over ICI."""
    return 2.0 * param_bytes_per_chip / hw.ici_bandwidth


def amortized_switch_ok(step_gain_s: float, param_bytes_per_chip: float,
                        steps_remaining: float,
                        hw: HardwareConfig = V5E) -> bool:
    """Switch only if the cumulative predicted win repays the reshard."""
    return step_gain_s * steps_remaining > reshard_cost_s(
        param_bytes_per_chip, hw)
