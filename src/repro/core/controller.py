"""Online reconfiguration controller (paper §4.1, Fig 7 + Fig 10/11).

Two nested loops, exactly the paper's structure lifted to the mesh level:

1. **Per-phase (kernel-analogue) plan selection** — when a new phase starts
   (a training job, a prefill wave, a decode wave), profile it (dry-run
   roofline terms or the trained logistic predictor) and pick the mesh plan
   (fused / base / scale_out).  One-time per phase, amortization-checked.

2. **Dynamic split/fuse inside a phase** — track the divergence signal
   (decode length spread, MoE expert imbalance).  When it crosses
   ``split_threshold`` and the regroup policy predicts a win, split the
   fused group's batch across its halves; re-fuse under ``fuse_threshold``
   with hysteresis and a ``min_phase_steps`` dwell to stop thrashing.

The controller is deliberately framework-level: it emits *decisions*
(plan names, split layouts); the launcher/serving engine executes them
(jit under the chosen mesh, reshard parameters, reorder batches).

Since the ``repro.control`` refactor this class is a thin façade: loop 2
(dynamic split/fuse) delegates to the shared
:class:`repro.control.GroupController` driving a
:class:`repro.control.ThresholdPolicy` — the same objects the serving
engine, the fleet, and the gpusim consume — so there is exactly one copy
of the hysteresis+dwell state machine in the codebase.  The public API
(``choose_plan`` / ``observe`` / ``layout`` / ``split_state``) is
unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from typing import TYPE_CHECKING

from repro.configs.base import AmoebaConfig, HardwareConfig, V5E
from repro.core import fusion, predictor, regroup
from repro.core.metrics import StepProfile

if TYPE_CHECKING:
    # repro.control's policies import repro.core.predictor, so the runtime
    # import of the control plane is deferred into __init__/observe to
    # keep `import repro.core` acyclic
    from repro.control import GroupController


@dataclass
class PhaseDecision:
    plan: str                      # chosen mesh plan name
    proba: float                   # P(fuse better) from the predictor
    reason: str
    profiles: Dict[str, Dict] = field(default_factory=dict)


@dataclass
class SplitState:
    """Read-only binary view of the shared ControlState (legacy API)."""
    split: bool = False
    steps_in_state: int = 0
    history: List[Tuple[int, bool, float]] = field(default_factory=list)


class AmoebaController:
    """Decision engine shared by the trainer and the serving engine."""

    def __init__(self, cfg: AmoebaConfig = AmoebaConfig(),
                 model: Optional[predictor.LogisticModel] = None,
                 hw: HardwareConfig = V5E,
                 group: Optional["GroupController"] = None):
        from repro.control import (ConfigSpace, GroupController,
                                   ThresholdPolicy)
        self.cfg = cfg
        self.model = model
        self.hw = hw
        self.group = group or GroupController(
            policy=ThresholdPolicy(cfg.split_threshold, cfg.fuse_threshold,
                                   cfg.regroup_policy),
            space=ConfigSpace(capacity=2, max_ways=2,
                              min_gain=cfg.min_gain),
            dwell=cfg.min_phase_steps,
            regroup_policy=cfg.regroup_policy)
        self.decisions: List[PhaseDecision] = []

    @property
    def split_state(self) -> SplitState:
        st = self.group.state
        return SplitState(
            split=st.ways > 1, steps_in_state=st.steps_in_state,
            history=[(s, w > 1, d) for s, w, d in st.history])

    # -- loop 1: per-phase plan selection ---------------------------------

    def choose_plan(self, profiles: Dict[str, StepProfile],
                    param_bytes_per_chip: float = 0.0,
                    steps_remaining: float = np.inf) -> PhaseDecision:
        """Pick the best mesh plan from compiled per-plan profiles.

        ``profiles`` maps plan name -> StepProfile (from the dry-run of the
        phase's step under each candidate mesh).  When exact profiles exist
        we compare rooflines directly (the paper's 'oracle' static upper
        bound); the logistic model covers the online case where only the
        base profile was measured.
        """
        if not self.cfg.enabled:
            d = PhaseDecision(plan="base", proba=0.5, reason="amoeba off")
            self.decisions.append(d)
            return d
        rts = {name: p.roofline(self.hw) for name, p in profiles.items()}
        if len(rts) > 1:
            best = min(rts, key=lambda n: rts[n]["step_s"])
            base_s = rts.get("base", rts[best])["step_s"]
            gain = base_s - rts[best]["step_s"]
            if best != "base" and not fusion.amortized_switch_ok(
                    gain, param_bytes_per_chip, steps_remaining, self.hw):
                best, reason = "base", "win does not amortize reshard"
            else:
                reason = f"roofline: {best} step {rts[best]['step_s']:.4g}s"
            proba = 1.0 if best == "fused" else 0.0
        else:
            (name, profile), = profiles.items()
            feats = profile.features()
            if self.model is not None:
                proba = float(predictor.predict_proba(self.model, feats))
                best = "fused" if proba > 0.5 else "scale_out"
                reason = f"predictor P(fuse)={proba:.3f}"
            else:
                # heuristic fallback mirroring §4.1.2: interconnect- or
                # memory-pressure-bound phases fuse; divergent ones scale out
                r = profile.roofline(self.hw)
                fuse = r["bottleneck"] == "collective" or (
                    r["bottleneck"] == "memory"
                    and profile.divergence < self.cfg.split_threshold)
                proba = 0.75 if fuse else 0.25
                best = "fused" if fuse else "scale_out"
                reason = f"heuristic: bottleneck={r['bottleneck']}"
        d = PhaseDecision(plan=best, proba=proba, reason=reason,
                          profiles=rts)
        self.decisions.append(d)
        return d

    # -- loop 2: dynamic split/fuse on divergence --------------------------

    def observe(self, divergence: float,
                remaining: Optional[Sequence[float]] = None) -> bool:
        """Feed one step's divergence signal; returns current split state.

        Implements Fig 10/11 with hysteresis + dwell (via the shared
        ``repro.control.GroupController``): split when divergence exceeds
        the threshold *and* the regroup policy predicts a win; re-fuse
        when it drops below ``fuse_threshold`` (the slow half drained).
        """
        from repro.control import FeatureVector
        fv = FeatureVector(
            divergence=float(divergence),
            remaining=None if remaining is None
            else np.asarray(remaining, np.float64))
        return self.group.observe(fv) > 1

    def layout(self, indices: Sequence[int],
               remaining: Sequence[float]) -> Tuple[List[int], List[int]]:
        """Current batch layout: (fast, slow) under the active policy."""
        if self.group.state.ways <= 1:
            return list(indices), []
        return regroup.POLICIES[self.cfg.regroup_policy](indices, remaining)
