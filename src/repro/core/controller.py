"""Online reconfiguration controller (paper §4.1, Fig 7 + Fig 10/11).

Two nested loops, exactly the paper's structure lifted to the mesh level:

1. **Per-phase (kernel-analogue) plan selection** — when a new phase starts
   (a training job, a prefill wave, a decode wave), profile it (dry-run
   roofline terms or the trained logistic predictor) and pick the mesh plan
   (fused / base / scale_out).  One-time per phase, amortization-checked.

2. **Dynamic split/fuse inside a phase** — track the divergence signal
   (decode length spread, MoE expert imbalance).  When it crosses
   ``split_threshold`` and the regroup policy predicts a win, split the
   fused group's batch across its halves; re-fuse under ``fuse_threshold``
   with hysteresis and a ``min_phase_steps`` dwell to stop thrashing.

The controller is deliberately framework-level: it emits *decisions*
(plan names, split layouts); the launcher/serving engine executes them
(jit under the chosen mesh, reshard parameters, reorder batches).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import AmoebaConfig, HardwareConfig, V5E
from repro.core import fusion, predictor, regroup
from repro.core.metrics import StepProfile


@dataclass
class PhaseDecision:
    plan: str                      # chosen mesh plan name
    proba: float                   # P(fuse better) from the predictor
    reason: str
    profiles: Dict[str, Dict] = field(default_factory=dict)


@dataclass
class SplitState:
    split: bool = False
    steps_in_state: int = 0
    history: List[Tuple[int, bool, float]] = field(default_factory=list)


class AmoebaController:
    """Decision engine shared by the trainer and the serving engine."""

    def __init__(self, cfg: AmoebaConfig = AmoebaConfig(),
                 model: Optional[predictor.LogisticModel] = None,
                 hw: HardwareConfig = V5E):
        self.cfg = cfg
        self.model = model
        self.hw = hw
        self.split_state = SplitState()
        self.decisions: List[PhaseDecision] = []
        self._step = 0

    # -- loop 1: per-phase plan selection ---------------------------------

    def choose_plan(self, profiles: Dict[str, StepProfile],
                    param_bytes_per_chip: float = 0.0,
                    steps_remaining: float = np.inf) -> PhaseDecision:
        """Pick the best mesh plan from compiled per-plan profiles.

        ``profiles`` maps plan name -> StepProfile (from the dry-run of the
        phase's step under each candidate mesh).  When exact profiles exist
        we compare rooflines directly (the paper's 'oracle' static upper
        bound); the logistic model covers the online case where only the
        base profile was measured.
        """
        if not self.cfg.enabled:
            d = PhaseDecision(plan="base", proba=0.5, reason="amoeba off")
            self.decisions.append(d)
            return d
        rts = {name: p.roofline(self.hw) for name, p in profiles.items()}
        if len(rts) > 1:
            best = min(rts, key=lambda n: rts[n]["step_s"])
            base_s = rts.get("base", rts[best])["step_s"]
            gain = base_s - rts[best]["step_s"]
            if best != "base" and not fusion.amortized_switch_ok(
                    gain, param_bytes_per_chip, steps_remaining, self.hw):
                best, reason = "base", "win does not amortize reshard"
            else:
                reason = f"roofline: {best} step {rts[best]['step_s']:.4g}s"
            proba = 1.0 if best == "fused" else 0.0
        else:
            (name, profile), = profiles.items()
            feats = profile.features()
            if self.model is not None:
                proba = float(predictor.predict_proba(self.model, feats))
                best = "fused" if proba > 0.5 else "scale_out"
                reason = f"predictor P(fuse)={proba:.3f}"
            else:
                # heuristic fallback mirroring §4.1.2: interconnect- or
                # memory-pressure-bound phases fuse; divergent ones scale out
                r = profile.roofline(self.hw)
                fuse = r["bottleneck"] == "collective" or (
                    r["bottleneck"] == "memory"
                    and profile.divergence < self.cfg.split_threshold)
                proba = 0.75 if fuse else 0.25
                best = "fused" if fuse else "scale_out"
                reason = f"heuristic: bottleneck={r['bottleneck']}"
        d = PhaseDecision(plan=best, proba=proba, reason=reason,
                          profiles=rts)
        self.decisions.append(d)
        return d

    # -- loop 2: dynamic split/fuse on divergence --------------------------

    def observe(self, divergence: float,
                remaining: Optional[Sequence[float]] = None) -> bool:
        """Feed one step's divergence signal; returns current split state.

        Implements Fig 10/11 with hysteresis + dwell: split when divergence
        exceeds the threshold *and* the regroup policy predicts a win;
        re-fuse when it drops below ``fuse_threshold`` (the slow half
        drained).
        """
        st = self.split_state
        self._step += 1
        st.steps_in_state += 1
        if st.steps_in_state < self.cfg.min_phase_steps:
            st.history.append((self._step, st.split, divergence))
            return st.split

        if not st.split and divergence > self.cfg.split_threshold:
            gain = (regroup.regroup_gain(remaining, self.cfg.regroup_policy)
                    if remaining is not None else divergence)
            if gain > 0.0:
                st.split = True
                st.steps_in_state = 0
        elif st.split and divergence < self.cfg.fuse_threshold:
            st.split = False
            st.steps_in_state = 0
        st.history.append((self._step, st.split, divergence))
        return st.split

    def layout(self, indices: Sequence[int],
               remaining: Sequence[float]) -> Tuple[List[int], List[int]]:
        """Current batch layout: (fast, slow) under the active policy."""
        if not self.split_state.split:
            return list(indices), []
        return regroup.POLICIES[self.cfg.regroup_policy](indices, remaining)
