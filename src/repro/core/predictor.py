"""Binary logistic regression scalability predictor (paper §4.1.3).

The paper trains the model offline on simulator data and evaluates it online
as a single MAC per feature ("since the model is in fact linear, its
implementation overhead is quite low").  We reproduce exactly that: a JAX
gradient-descent trainer (fp32, L2-regularized) and an inference path that
is one dot product + sigmoid.  The same class serves both levels of the
system:

* **gpusim level** — features are the paper's §4.1.2 metrics (NoC
  throughput/latency, coalescing rate, L1 miss rates, MSHR rate, inactive
  thread rate, load/store rates, concurrent CTAs); label = "fused SMs beat
  split SMs on this kernel".
* **mesh level** — features are roofline terms of a compiled step (collective
  bytes/FLOP, HBM bytes/FLOP, per-chip batch, memory pressure, divergence);
  label = "the TP-heavy (fused) mesh plan beats the DP-heavy (scale-out)
  plan".
"""
from __future__ import annotations

import json
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class LogisticModel(NamedTuple):
    w: jnp.ndarray          # (F,)
    b: jnp.ndarray          # ()
    mu: jnp.ndarray         # (F,) feature standardization
    sigma: jnp.ndarray      # (F,)
    feature_names: Tuple[str, ...] = ()

    def standardize(self, x: jnp.ndarray) -> jnp.ndarray:
        return (x - self.mu) / self.sigma


def predict_proba(model: LogisticModel, x: jnp.ndarray) -> jnp.ndarray:
    """P(scale-up / fuse is better). x: (..., F)."""
    z = model.standardize(x) @ model.w + model.b
    return jax.nn.sigmoid(z)


def predict_fuse(model: LogisticModel, x: jnp.ndarray) -> jnp.ndarray:
    return predict_proba(model, x) > 0.5


def feature_impacts(model: LogisticModel, x: jnp.ndarray) -> jnp.ndarray:
    """Paper Fig. 20: per-feature impact magnitude = coefficient x value.

    Positive entries push toward scale-up (fuse), negative toward scale-out.
    """
    return model.standardize(x) * model.w


def train_logistic(X: np.ndarray, y: np.ndarray, *,
                   feature_names: Sequence[str] = (),
                   l2: float = 1e-3, lr: float = 0.3, steps: int = 3000,
                   seed: int = 0,
                   sample_weight: Optional[np.ndarray] = None
                   ) -> Tuple[LogisticModel, dict]:
    """Offline training (paper: 'a large amount of offline experimental
    data').  Full-batch gradient descent on the regularized NLL.

    ``sample_weight`` scales each example's loss term (normalized to
    mean 1) — the online-refit path passes exponential recency weights
    so a stale regime stops steering the fit before the FIFO evicts it.
    ``info["loss_history"]`` carries the per-step NLL trajectory so that
    path (repro.control.policies.OnlinePolicy) can monitor convergence
    across refits.
    """
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    if sample_weight is None:
        sw = jnp.ones_like(y)
    else:
        sw = jnp.asarray(sample_weight, jnp.float32)
        sw = sw / jnp.maximum(jnp.mean(sw), 1e-9)
    mu = jnp.mean(X, axis=0)
    sigma = jnp.maximum(jnp.std(X, axis=0), 1e-6)
    Xs = (X - mu) / sigma
    F = X.shape[1]

    def nll(params):
        w, b = params
        z = Xs @ w + b
        # numerically stable logistic loss
        loss = jnp.mean(sw * (jnp.logaddexp(0.0, z) - y * z))
        return loss + l2 * jnp.sum(w ** 2)

    w = jnp.zeros((F,), jnp.float32)
    b = jnp.zeros((), jnp.float32)

    @jax.jit
    def step(params, _):
        loss, g = jax.value_and_grad(nll)(params)
        return jax.tree.map(lambda p, gg: p - lr * gg, params, g), loss

    params, losses = jax.lax.scan(step, (w, b), None, length=steps)
    w, b = params
    model = LogisticModel(w=w, b=b, mu=mu, sigma=sigma,
                          feature_names=tuple(feature_names))
    z = Xs @ w + b
    acc = float(jnp.mean(((z > 0) == (y > 0.5)).astype(jnp.float32)))
    # a plain float list: info dicts flow verbatim into json benchmark
    # artifacts (fig20), where an ndarray would serialize as a lossy repr
    loss_history = np.asarray(losses, np.float64).tolist()
    info = {"train_accuracy": acc, "final_nll": float(nll((w, b))),
            "n": int(X.shape[0]), "loss_history": loss_history}
    return model, info


# ---------------------------------------------------------------------------
# (De)serialization — the controller loads trained coefficients at runtime
# ---------------------------------------------------------------------------

def save_model(model: LogisticModel, path: str) -> None:
    blob = {
        "w": np.asarray(model.w).tolist(),
        "b": float(model.b),
        "mu": np.asarray(model.mu).tolist(),
        "sigma": np.asarray(model.sigma).tolist(),
        "feature_names": list(model.feature_names),
    }
    with open(path, "w") as f:
        json.dump(blob, f, indent=1)


def load_model(path: str) -> LogisticModel:
    with open(path) as f:
        blob = json.load(f)
    return LogisticModel(
        w=jnp.asarray(blob["w"], jnp.float32),
        b=jnp.asarray(blob["b"], jnp.float32),
        mu=jnp.asarray(blob["mu"], jnp.float32),
        sigma=jnp.asarray(blob["sigma"], jnp.float32),
        feature_names=tuple(blob["feature_names"]),
    )
