"""Loop-aware analysis of post-optimization HLO.

``compiled.cost_analysis()`` counts every while-loop body ONCE, so a
96-layer scan + grad-accum + chunked-loss program under-reports FLOPs,
bytes, and collective payloads by 2-3 orders of magnitude.  This module
parses the compiled HLO module text, reconstructs the call graph
(while bodies, fusions, calls, conditionals), extracts loop trip counts,
and tallies:

* ``flops``            — 2 x |result| x contracted-dim product per dot,
                         trip-count weighted (matmul-dominated programs:
                         this is the real compute term).
* ``collective_bytes`` — result-shape payload of every all-gather /
                         all-reduce / reduce-scatter / all-to-all /
                         collective-permute, trip-count weighted.
* ``hbm_bytes``        — estimator: every top-level op result is written
                         once and read ~once downstream (2x result bytes),
                         plus the entry arguments read once; documented in
                         EXPERIMENTS.md §Roofline.

Trip counts: jax scans lower to ``while`` whose condition is
``compare(%iter, %bound), direction=LT``; both iter-init and bound arrive
through the init tuple, so the bound is recovered by tracing the compare's
condition-parameter index back to the init-tuple operand in the parent
computation (a constant).  Unresolvable loops fall back to trip=1 and are
counted in ``unresolved_loops``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
          "u16": 2, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_OPCODE_RE = re.compile(
    r"^((?:\([^=]*?\))|[\w\[\]{},\/\*=\s]+?)\s*([\w\-]+)\(")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SKIP_RESULT_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "conditional", "call", "after-all",
                    "partition-id", "replica-id", "iota"}


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_list(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _BYTES[dt]
    return total


@dataclass
class Instruction:
    name: str
    opcode: str
    result_text: str
    body: str
    operands_text: str


@dataclass
class Computation:
    name: str
    params: List[str] = field(default_factory=list)
    instrs: List[Instruction] = field(default_factory=list)
    by_name: Dict[str, Instruction] = field(default_factory=dict)
    constants: Dict[str, int] = field(default_factory=dict)


def _split_opcode(rhs: str):
    """rhs: '<result types> <opcode>(<operands>), attrs' -> pieces."""
    # find the first opcode token immediately followed by '('
    m = re.search(r"([\w\-]+)\(", rhs)
    while m:
        op = m.group(1)
        # opcode must be preceded by whitespace or start (not part of type)
        pre = rhs[:m.start()].strip()
        if pre.endswith(("]", ")", "}")) or pre == "" or pre[-1].isspace():
            return pre, op, rhs[m.end() - 1:]
        m = re.search(r"([\w\-]+)\(", rhs[m.end():])
        if m:
            m = re.search(re.escape(m.group(0)), rhs)
            break
    return None, None, None


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        is_hdr = (") -> " in s and s.endswith("{") and " = " not in s
                  and (s.startswith("%") or s.startswith("ENTRY")))
        if is_hdr:
            name_m = re.match(r"(?:ENTRY\s+)?%([\w.\-]+)\s*\(", s)
            if not name_m:
                continue
            cur = Computation(name_m.group(1))
            hdr_args = s[s.index("("):s.rindex(") -> ")]
            cur.params = _PARAM_RE.findall(hdr_args)
            comps[cur.name] = cur
            if s.startswith("ENTRY"):
                entry = cur.name
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = re.search(r"\s([\w\-]+)\(", " " + rhs)
        if not om:
            continue
        opcode = om.group(1)
        result_text = rhs[:om.start(1) - 1].strip()
        # operands: balanced paren group right after opcode
        start = om.start(1) - 1 + len(opcode) + 1
        depth, i = 1, start + 1
        while i < len(rhs) and depth:
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
            i += 1
        operands = rhs[start:i]
        ins = Instruction(name, opcode, result_text, rhs, operands)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
        if opcode == "constant":
            lit = re.search(r"constant\((-?\d+)\)", rhs)
            if lit and re.match(r"^[su]\d+\[\]", result_text):
                cur.constants[name] = int(lit.group(1))
    return comps, entry


def _operand_names(ins: Instruction) -> List[str]:
    return re.findall(r"%([\w.\-]+)", ins.operands_text)


def _resolve_trip(while_ins: Instruction, parent: Computation,
                  comps: Dict[str, Computation]) -> Optional[int]:
    cm = re.search(r"condition=%?([\w.\-]+)", while_ins.body)
    if not cm or cm.group(1) not in comps:
        return None
    cond = comps[cm.group(1)]
    # init tuple in the parent (possibly behind copies)
    init_names = _operand_names(while_ins)
    init_ops: Optional[List[str]] = None
    if len(init_names) == 1 and init_names[0] in parent.by_name \
            and parent.by_name[init_names[0]].opcode == "tuple":
        init_ops = _operand_names(parent.by_name[init_names[0]])
    elif len(init_names) > 1:
        init_ops = init_names

    def chase_parent_const(name: str, depth: int = 0) -> Optional[int]:
        if depth > 4:
            return None
        if name in parent.constants:
            return parent.constants[name]
        ins = parent.by_name.get(name)
        if ins is not None and ins.opcode in ("copy", "convert", "bitcast"):
            ops = _operand_names(ins)
            if ops:
                return chase_parent_const(ops[0], depth + 1)
        return None

    def init_const(idx: int) -> Optional[int]:
        if init_ops is None or idx >= len(init_ops):
            return None
        return chase_parent_const(init_ops[idx])

    def value_in_cond(name: str) -> Optional[int]:
        """Resolve an s32[] value referenced inside the condition."""
        if name in cond.constants:
            return cond.constants[name]
        if name in cond.params:
            return init_const(cond.params.index(name))
        ins = cond.by_name.get(name)
        if ins is None:
            return None
        if ins.opcode == "get-tuple-element":
            im = re.search(r"index=(\d+)", ins.body)
            if im:
                return init_const(int(im.group(1)))
        if ins.opcode in ("copy", "convert", "bitcast"):
            ops = _operand_names(ins)
            if ops:
                return value_in_cond(ops[0])
        if ins.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", ins.body)
            if pm:
                return init_const(int(pm.group(1)))
        return None

    def compare_sites():
        # compares directly in the condition, or inside fusions it calls
        for ins in cond.instrs:
            if ins.opcode == "compare":
                yield ins, value_in_cond
            elif ins.opcode == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", ins.body)
                if not fm or fm.group(1) not in comps:
                    continue
                fused = comps[fm.group(1)]
                call_ops = _operand_names(ins)

                def resolve(name, fused=fused, call_ops=call_ops):
                    fi = fused.by_name.get(name)
                    if fi is not None and fi.opcode == "parameter":
                        pm = re.search(r"parameter\((\d+)\)", fi.body)
                        if pm and int(pm.group(1)) < len(call_ops):
                            return value_in_cond(call_ops[int(pm.group(1))])
                    if name in fused.constants:
                        return fused.constants[name]
                    return None

                for fins in fused.instrs:
                    if fins.opcode == "compare":
                        yield fins, resolve

    for ins, resolve in compare_sites():
        dm = re.search(r"direction=(\w+)", ins.body)
        direction = dm.group(1) if dm else "LT"
        ops = _operand_names(ins)
        vals = [resolve(n) for n in ops[:2]]
        if len(vals) == 2 and vals[0] is not None and vals[1] is not None:
            lo, hi = vals
            if direction in ("GT", "GE"):
                lo, hi = hi, lo
            trip = hi - lo + (1 if direction in ("LE", "GE") else 0)
            if trip >= 0:
                return trip
    return None


def _operand_shape_text(comp: Computation, name: str,
                        bindings: List[str]) -> str:
    """Result-type text of an operand (scheduled HLO has name-only
    operands): defining instruction, or the caller binding for params."""
    ins = comp.by_name.get(name)
    if ins is None:
        return ""
    if ins.opcode == "parameter":
        pm = re.search(r"parameter\((\d+)\)", ins.body)
        if pm and int(pm.group(1)) < len(bindings):
            return bindings[int(pm.group(1))]
    return ins.result_text


def _dot_flops(ins: Instruction, comp: Computation,
               bindings: List[str]) -> float:
    res = _shape_list(ins.result_text)
    if not res:
        return 0.0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.body)
    ops = _operand_names(ins)
    if not ops:
        return 0.0
    lhs_shapes = _shape_list(_operand_shape_text(comp, ops[0], bindings))
    if not lhs_shapes:
        return 2.0 * out_elems          # unknown contraction: lower bound
    lhs_dims = lhs_shapes[0][1]
    contract = 1
    if cm:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


@dataclass
class HLOCost:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = field(default_factory=dict)
    hbm_bytes: float = 0.0
    unresolved_loops: int = 0
    loops: List[Tuple[str, int]] = field(default_factory=list)
    # (total_bytes, op, result_shape, mult, op_name metadata) largest first
    top_collectives: List[Tuple[float, str, str, float, str]] = \
        field(default_factory=list)

    def finalize(self, keep: int = 12) -> "HLOCost":
        self.top_collectives.sort(reverse=True)
        self.top_collectives = self.top_collectives[:keep]
        return self


def analyze(hlo: str, default_trip: int = 1) -> HLOCost:
    comps, entry = parse_module(hlo)
    cost = HLOCost(coll_breakdown={op: 0.0 for op in COLLECTIVE_OPS})

    def visit(comp_name: str, mult: float, stack: tuple,
              bindings: List[str], in_fusion: bool) -> None:
        comp = comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.body)
                trip = _resolve_trip(ins, comp, comps)
                if trip is None:
                    trip = default_trip
                    cost.unresolved_loops += 1
                cost.loops.append((ins.name, trip))
                if bm:
                    visit(bm.group(1), mult * max(trip, 0),
                          stack + (comp_name,), [ins.result_text], False)
                continue
            if op in ("fusion", "call"):
                key = "calls=" if op == "fusion" else "to_apply="
                fm = re.search(key + r"%?([\w.\-]+)", ins.body)
                if fm:
                    binds = [_operand_shape_text(comp, n, bindings)
                             for n in _operand_names(ins)]
                    visit(fm.group(1), mult, stack + (comp_name,), binds,
                          in_fusion or op == "fusion")
            elif op == "conditional" and "branch_computations={" in ins.body:
                brs = ins.body.split("branch_computations={")[1].split("}")[0]
                for br in re.findall(r"%([\w.\-]+)", brs):
                    visit(br, mult, stack + (comp_name,), [], in_fusion)
            if op in ("dot", "convolution"):
                cost.flops += mult * _dot_flops(ins, comp, bindings)
            if op in COLLECTIVE_OPS:
                b = _shape_bytes(ins.result_text)
                cost.coll_bytes += mult * b
                cost.coll_breakdown[op] += mult * b
                md = re.search(r'op_name="([^"]+)"', ins.body)
                cost.top_collectives.append(
                    (mult * b, op, ins.result_text[:48], mult,
                     (md.group(1) if md else "")[:90]))
            elif op == "parameter":
                if comp_name == entry:
                    cost.hbm_bytes += _shape_bytes(ins.result_text)
            elif op not in _SKIP_RESULT_OPS and not in_fusion:
                # fusion internals live in registers/VMEM; only top-level
                # results round-trip HBM (written once, read ~once)
                cost.hbm_bytes += 2.0 * mult * _shape_bytes(ins.result_text)
        return

    if entry:
        visit(entry, 1.0, (), [], False)
    return cost.finalize()
