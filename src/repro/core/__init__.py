"""AMOEBA core: the paper's contribution, at two levels.

* ``gpusim``      — faithful reproduction of the paper's GPU (pillar A).
* ``predictor``   — binary logistic regression scalability model (§4.1.3).
* ``metrics``     — mesh-level scalability metrics / roofline terms.
* ``fusion``      — mesh plans: fuse/split chip-group factorizations.
* ``controller``  — online reconfiguration controller (Fig 7, 10, 11).
* ``regroup``     — direct-split / warp-regroup batch policies (§4.3).
"""
from repro.core.controller import AmoebaController, PhaseDecision
from repro.core.fusion import MeshPlan, plan_family
from repro.core.metrics import StepProfile, collective_bytes
from repro.core.predictor import (LogisticModel, predict_fuse, predict_proba,
                                  train_logistic)

__all__ = [
    "AmoebaController", "PhaseDecision", "MeshPlan", "plan_family",
    "StepProfile", "collective_bytes", "LogisticModel", "predict_fuse",
    "predict_proba", "train_logistic",
]
