"""Batch regrouping policies — the warp-regrouping analogue (paper §4.3).

A decode batch is the TPU's warp: every sequence in it pays one forward
step per token of the *longest* member, exactly the "entire warp waits for
the last thread" pathology.  When the spread of remaining lengths crosses
the controller's threshold, the batch splits across the two halves of a
fused group:

* ``direct_split``  — cut the batch in the middle (paper: cheap, but slow
  and fast sequences stay mixed in both halves).
* ``warp_regroup``  — sort by remaining length; the slow half gets the
  long tail, the fast half drains early and (backfill) picks up queued
  requests — the paper's "periodically move some fast warps".

The same machinery scores MoE expert imbalance for training-side divergence
(a capacity-overflowing expert stalls its whole group the way a divergent
warp stalls a wide pipe).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np


def divergence_score(remaining: Sequence[float]) -> float:
    """Normalized length spread in [0, 1): 0 = lockstep batch.

    mean waste fraction = 1 - mean(remaining) / max(remaining): the fraction
    of decode slots that will run for already-finished sequences.
    """
    r = np.asarray(remaining, dtype=np.float64)
    if r.size == 0 or r.max() <= 0:
        return 0.0
    return float(1.0 - r.mean() / r.max())


def moe_divergence(expert_load: Sequence[float]) -> float:
    """Imbalance of expert load fractions in [0, 1): 0 = perfectly even."""
    p = np.asarray(expert_load, dtype=np.float64)
    if p.size == 0 or p.sum() <= 0:
        return 0.0
    p = p / p.sum()
    return float(1.0 - 1.0 / (p.size * (p ** 2).sum()))


def direct_split(indices: Sequence[int],
                 remaining: Sequence[float]) -> Tuple[List[int], List[int]]:
    """Cut the batch in the middle (arrival order) — paper's cheap policy."""
    idx = list(indices)
    mid = len(idx) // 2
    return idx[:mid], idx[mid:]


def warp_regroup(indices: Sequence[int],
                 remaining: Sequence[float]) -> Tuple[List[int], List[int]]:
    """Sort by remaining work: fast half (short) / slow half (long)."""
    order = np.argsort(np.asarray(remaining, dtype=np.float64), kind="stable")
    idx = [indices[i] for i in order]
    mid = len(idx) // 2
    return idx[:mid], idx[mid:]            # (fast, slow)


POLICIES = {"direct_split": direct_split, "warp_regroup": warp_regroup}


def regroup_gain(remaining: Sequence[float], policy: str) -> float:
    """Predicted slot-waste saving of splitting vs staying fused, in [0, 1).

    A batch of B sequences costs ``B x max(remaining)`` decode slot-steps
    (every slot runs until the longest member finishes — the warp-waits-
    for-the-last-thread pathology).  Splitting runs each half for its own
    maximum, so a drained fast half frees its slots for queued work
    (the paper's backfill of fast warps).  Gain = relative waste reduction;
    ``direct_split`` only wins if arrival order happens to correlate with
    length, which is exactly the paper's critique of it.
    """
    r = np.asarray(remaining, dtype=np.float64)
    if r.size < 2 or r.max() <= 0:
        return 0.0
    fused_cost = float(r.size * r.max())
    halves = POLICIES[policy](list(range(r.size)), r)
    split_cost = float(sum(len(h) * r[h].max() for h in halves if len(h)))
    return (fused_cost - split_cost) / fused_cost
