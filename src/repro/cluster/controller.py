"""Chip-level control: the tiered planner and the cluster controller.

The hierarchy mirrors the paper's levels.  A
:class:`repro.control.GroupController` reconfigures one group (one SM
pair); a :class:`repro.control.FleetController` manages one chip's mix
of groups; the :class:`ClusterController` here does to *chips* what the
fleet controller does to groups:

* **per-chip pressure** — each cluster tick it folds every chip's live
  remaining-lengths, queue depth, and completion rate into the same
  :class:`repro.control.FeatureVector` the policy stack consumes
  (divergence = tail mass, queue_frac = queue mass) plus a drain rate,
  kept as :class:`ChipPressure`;

* **split-mix steering** — one chip-scoped
  :class:`~repro.control.FleetController` per chip nudges that chip's
  fused/split mix against its *own* long fraction (a hot chip deepens
  while a cold one stays fused), with the quarantine reservation
  maintained on whichever chip hosts it;

* **region gather** — the :class:`repro.cluster.regions.RegionManager`
  fuses adjacent same-chip groups into a deep tail unit when a chip
  turns long-heavy (see :mod:`repro.cluster.regions`);

* **tiered migration** — a :class:`ClusterPlanner` plans steals
  chip-first and authorizes cross-chip steals/live-migrations only when
  the *tiered* cost amortizes on the same ``move_gain`` scale the
  topology lattice uses.

:class:`ClusterPlanner` extends the flat
:class:`repro.fleet.migrate.MigrationPlanner`.  Planning: steals are
matched within each chip first (the NoC is near-free), then residual
backlog may cross chips, each candidate vetoed unless the transfer
arrives before the donor would have locally started the request
(normalized margin > ``min_gain``); live migrations inherit the flat
planner's amortization check but with a per-destination *tiered* stall,
so a same-chip move can clear the bar where the identical cross-node
move fails it.  Execution always charges the **true** tiered cost —
also under ``ClusterConfig.distance_blind``, where planning prices
every pair at the flat link bandwidth (the A/B baseline): a blind plan
cashes out at physical prices, which is exactly how distance-blind
stealing thrashes slow links.  Cross-chip steals travel as in-flight
transfers delivered ``steal_ticks`` later; an unreachable transfer
(zero bandwidth on its tier) is vetoed at plan time and dropped at
execution, so zero inter-chip bandwidth stops every cross-chip move
while intra-chip traffic keeps flowing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, \
    Set, Tuple

from repro.configs.base import ClusterConfig, FleetConfig, MigrationConfig, \
    ModelConfig
from repro.cluster.mesh import TIERS, TOKEN_BYTES, ClusterMesh, \
    TieredTransferCost
from repro.cluster.regions import RegionManager
from repro.control.controller import FleetController
from repro.control.features import FeatureVector
from repro.obs.events import NULL_LOG
from repro.fleet.migrate import Addr, KVTransferCost, Migration, \
    MigrationPlanner, STEAL, _GroupView, charge_ticks
from repro.serve.engine import Request


# -- the tiered planner --------------------------------------------------------

class ClusterPlanner(MigrationPlanner):
    """Tier-aware work mover: chip-first steals, amortized crossings."""

    def __init__(self, cfg: MigrationConfig, model_cfg: ModelConfig,
                 mesh: ClusterMesh, cost: TieredTransferCost,
                 ccfg: ClusterConfig, long_threshold: int = 24,
                 window: Optional[int] = None):
        # the *planning* cost: tiered normally, flat under the
        # distance-blind baseline (plans priced as if all links were
        # MigrationConfig.link_bandwidth)
        plan_cost = KVTransferCost(
            link_bandwidth=cfg.link_bandwidth,
            dtype_bytes=cfg.kv_dtype_bytes,
            quantized=cfg.quantized_kv) if ccfg.distance_blind else cost
        super().__init__(cfg, model_cfg, long_threshold=long_threshold,
                         window=window, cost=plan_cost)
        self.mesh = mesh
        self.ccfg = ccfg
        # the *physical* cost every executed move is charged at
        self.true_cost = cost
        self._region_groups: FrozenSet[int] = frozenset()
        # cross-chip steals in the air: (arrive_tick, seq, request, dst)
        self._in_flight: List[Tuple[int, int, Request, Addr]] = []
        self._flight_seq = 0
        # per-tier traffic counters (fleet telemetry's cluster block)
        self.tier_bytes: Dict[str, int] = {t: 0 for t in TIERS}
        self.tier_stall_ticks: Dict[str, int] = {t: 0 for t in TIERS}
        self.intra_chip_steals = 0
        self.cross_chip_steals = 0
        self.intra_chip_live = 0
        self.cross_chip_live = 0
        self.vetoed_cross_chip = 0     # crossings rejected at plan time
        self.dropped_unreachable = 0   # plans priced at inf at execution

    # -- region interplay ------------------------------------------------------

    def set_regions(self, region_groups: Iterable[int]) -> None:
        self._region_groups = frozenset(region_groups)

    def _recip_priority(self, v: _GroupView) -> Tuple:
        # gathered region groups first: their deep splits exist to host
        # the tail mass steals redistribute
        return (v.gi in self._region_groups, v.total_free)

    # -- planning --------------------------------------------------------------

    def plan(self, tick: int, groups: Sequence,
             reserved: Optional[Iterable[Addr]] = None) -> List[Migration]:
        if self.ccfg.distance_blind:
            # one global distance-blind pool — the flat baseline
            return super().plan(tick, groups, reserved)
        self.plan_ticks += 1
        res: Set[Addr] = set(reserved or ())
        views = [self._view(tick, gi, g, res)
                 for gi, g in enumerate(groups)]
        self._pressure = {v.gi: v.queue_len / max(v.drain_rate, 1e-3)
                          if v.queue_len else 0.0 for v in views}
        plans: List[Migration] = []
        # chip-first: each chip resolves what its own NoC can absorb
        for ci in range(self.mesh.num_chips):
            gids = set(self.mesh.chip_groups(ci))
            plans += self._plan_steals(
                [v for v in views if v.gi in gids], groups)
        # only the residual backlog may cross chips, and only amortized;
        # victims the chip phase already claimed stay claimed
        claimed = {id(m.request) for m in plans}
        plans += self._plan_cross_steals(views, groups, claimed)
        if self.cfg.live:
            plans += self._plan_live(views, groups, res)
        self.planned += len(plans)
        return plans

    def _plan_cross_steals(self, views: List[_GroupView],
                           groups: Sequence,
                           claimed: Set[int]) -> List[Migration]:
        """Cross-chip steals that clear the tiered amortization bar.

        A steal's benefit is the queue wait it skips: the donor's
        expected ticks-to-drain.  Its tiered cost is the in-flight
        transfer time.  On the same normalized scale as
        ``ConfigSpace.move_gain`` — saving over the cost of staying put
        — the move must clear ``min_gain``:

        ``(wait - transfer) / max(wait, 1) > min_gain``

        so an unreachable pair (infinite transfer) or a slow link under
        a shallow backlog is vetoed, while a deep backlog amortizes even
        a multi-hop crossing.
        """
        thresh = self.cfg.steal_threshold
        budget = self.ccfg.max_cross_steals
        donors = sorted(
            (v for v in views if v.queue_len > thresh),
            key=lambda v: v.queue_len / max(v.drain_rate, 1e-3),
            reverse=True)
        recips = sorted(
            (v for v in views
             if v.total_free > 0 and v.queue_len < v.total_free
             and v.queue_len <= thresh),
            key=self._recip_priority, reverse=True)
        plans: List[Migration] = []
        for donor in donors:
            if budget <= 0:
                break
            wait = donor.queue_len / max(donor.drain_rate, 1e-3)
            queue = [q for q in groups[donor.gi].queue
                     if id(q) not in claimed]
            queue.reverse()        # steal from the tail, like the base
            for recip in recips:
                if budget <= 0 or not queue:
                    break
                if self.mesh.chip_of(recip.gi) == self.mesh.chip_of(donor.gi):
                    continue       # same chip was the chip-first phase
                while (budget > 0 and queue
                       and donor.queue_len > thresh
                       and recip.total_free > 0):
                    victim = queue[0]
                    part = self._fit_part(recip, victim)
                    if part is None:
                        break
                    ticks = self.true_cost.steal_ticks(
                        len(victim.prompt), donor.gi, recip.gi)
                    # price at the whole-tick charge the transfer will
                    # actually pay (ceil past a tick boundary, sub-tick
                    # free) so the amortization check matches the bill
                    charged = 0 if math.isinf(ticks) else charge_ticks(ticks)
                    gain = -math.inf if math.isinf(ticks) \
                        else (wait - charged) / max(wait, 1.0)
                    if gain <= self.cfg.min_gain:
                        # every victim of this pair prices the same tier:
                        # move on to the next recipient
                        self.vetoed_cross_chip += 1
                        break
                    queue.pop(0)
                    plans.append(Migration(STEAL, victim,
                                           src=(donor.gi, None),
                                           dst=(recip.gi, part),
                                           stall=charged, gain=gain))
                    recip.free[part] -= 1
                    donor.queue_len -= 1
                    budget -= 1
        return plans

    # -- execution (always at physical prices) ---------------------------------

    def _account(self, tier: str, nbytes: int, ticks: int) -> None:
        if tier in self.tier_bytes:
            self.tier_bytes[tier] += int(nbytes)
            self.tier_stall_ticks[tier] += int(ticks)

    def _execute_steal(self, m: Migration, groups: Sequence,
                       now: int) -> int:
        src_gi, dst_gi = m.src[0], m.dst[0]
        nbytes = max(len(m.request.prompt), 1) * TOKEN_BYTES
        ticks = self.true_cost.steal_ticks(
            len(m.request.prompt), src_gi, dst_gi)
        if math.isinf(ticks):
            # a blind plan across a dead link: physically impossible
            self.dropped_unreachable += 1
            return 0
        tier = self.mesh.tier(src_gi, dst_gi)
        charged = charge_ticks(ticks)
        if charged <= 0:
            done = super()._execute_steal(m, groups, now)
        else:
            src = groups[src_gi]
            idx = next((i for i, q in enumerate(src.queue)
                        if q is m.request), None)
            if idx is None:
                return 0
            del src.queue[idx]
            src.stats.steals_out += 1
            self.steals += 1
            # in the air until the transfer lands (deliver_in_flight)
            self._flight_seq += 1
            self._in_flight.append(
                (now + charged, self._flight_seq, m.request, m.dst))
            if self.obs.enabled:
                self.obs.emit("steal", gid=m.dst[0], part=m.dst[1],
                              tick=now, rid=m.request.rid,
                              src=m.src, dst=m.dst, gain=float(m.gain),
                              in_flight=True, arrive=now + charged,
                              tier=tier)
            done = 1
        if done:
            if tier == "noc":
                self.intra_chip_steals += 1
            else:
                self.cross_chip_steals += 1
            self._account(tier, nbytes, charged)
        return done

    def _execute_live(self, m: Migration, groups: Sequence) -> int:
        src_gi, dst_gi = m.src[0], m.dst[0]
        seq_len = len(m.request.prompt) + len(m.request.generated)
        true = self.true_cost.stall_ticks(
            seq_len, self.model_cfg, self.window, src=src_gi, dst=dst_gi)
        if math.isinf(true):
            self.dropped_unreachable += 1
            return 0
        # the destination part stalls for the *physical* transfer, not
        # whatever a (possibly blind) plan assumed
        m.stall = charge_ticks(true)
        done = super()._execute_live(m, groups)
        if done:
            tier = self.mesh.tier(src_gi, dst_gi)
            if tier == "noc":
                self.intra_chip_live += 1
            else:
                self.cross_chip_live += 1
            self._account(tier, self.true_cost.kv_bytes(
                seq_len, self.model_cfg, self.window), m.stall)
        return done

    # -- in-flight transfers ---------------------------------------------------

    def deliver_in_flight(self, now: int, groups: Sequence) -> int:
        """Land every transfer whose arrival tick has passed."""
        if not self._in_flight:
            return 0
        ready = sorted(e for e in self._in_flight if e[0] <= now)
        if not ready:
            return 0
        self._in_flight = [e for e in self._in_flight if e[0] > now]
        for _, _, req, (gi, pi) in ready:
            groups[gi].submit([req], now=now, part=pi)
            groups[gi].stats.steals_in += 1
        return len(ready)

    def next_arrival(self) -> Optional[int]:
        """Earliest in-flight landing tick (the engine's idle horizon)."""
        return min((e[0] for e in self._in_flight), default=None)

    def in_flight_requests(self) -> List[Request]:
        """Requests currently in the air — part of conservation books."""
        return [e[2] for e in self._in_flight]

    # -- telemetry -------------------------------------------------------------

    def summary(self) -> Dict:
        s = super().summary()
        s.update({
            "intra_chip_steals": self.intra_chip_steals,
            "cross_chip_steals": self.cross_chip_steals,
            "intra_chip_live": self.intra_chip_live,
            "cross_chip_live": self.cross_chip_live,
            "vetoed_cross_chip": self.vetoed_cross_chip,
            "dropped_unreachable": self.dropped_unreachable,
            "in_flight": len(self._in_flight),
            "tier_bytes": dict(self.tier_bytes),
            "tier_stall_ticks": dict(self.tier_stall_ticks),
        })
        return s


# -- per-chip pressure ---------------------------------------------------------

@dataclass
class ChipPressure:
    """One chip's pressure sample on the shared feature scale."""
    chip: int
    fv: FeatureVector              # divergence=tail mass, queue_frac=queue mass
    drain_rate: float              # completions per tick since last sample
    long_frac: float               # fraction of outstanding work past threshold

    def as_dict(self) -> Dict:
        return {"divergence": round(self.fv.divergence, 3),
                "spread": round(self.fv.spread, 3),
                "queue_frac": round(self.fv.queue_frac, 3),
                "live_frac": round(self.fv.live_frac, 3),
                "drain_rate": round(self.drain_rate, 3),
                "long_frac": round(self.long_frac, 3)}


# -- the cluster controller ----------------------------------------------------

class ClusterController:
    """One control plane above the fleet: chips are its unit of steering.

    Presents the same surface ``FleetEngine.run`` drives on a
    :class:`~repro.control.FleetController` — ``rebalance(tick,
    groups)``, ``take_plans()``, ``planner``, ``rebalances``,
    ``quarantine``, ``reserved_parts(groups)`` — so the engine loop
    does not change; plus :meth:`cluster_summary` for the telemetry
    block.
    """

    def __init__(self, mesh: ClusterMesh, ccfg: ClusterConfig,
                 fleet: FleetConfig, model_cfg: ModelConfig,
                 cost: Optional[TieredTransferCost] = None):
        self.mesh = mesh
        self.ccfg = ccfg
        self.fleet = fleet
        self.cost = cost or TieredTransferCost.from_config(
            mesh, ccfg, dtype_bytes=fleet.migrate.kv_dtype_bytes,
            quantized=fleet.migrate.quantized_kv)
        self.every = fleet.rebalance_every if fleet.rebalance_every > 0 \
            else max(fleet.migrate.every, 1)
        self.long_threshold = fleet.long_threshold
        self.quarantine = fleet.quarantine_group
        self.planner = ClusterPlanner(
            fleet.migrate, model_cfg, mesh=mesh, cost=self.cost,
            ccfg=ccfg, long_threshold=fleet.long_threshold,
            window=fleet.window)
        # optional repro.fleet.lease.LeasePlanner, wired (with the mesh
        # and the physical cost) by ClusterEngine when leases are on
        self.leases = None
        # one chip-scoped mix controller per chip: each chip's
        # fused/split mix tracks its *own* long fraction (gated here,
        # so every=1; no planner — migration is the cluster's job)
        self.chip_controllers = [
            FleetController(long_threshold=fleet.long_threshold, every=1,
                            planner=None,
                            quarantine=self._local_quarantine(ci),
                            mix=True)
            for ci in range(mesh.num_chips)]
        self.regions = RegionManager(
            mesh, ccfg, long_threshold=fleet.long_threshold) \
            if ccfg.region_gather else None
        self.rebalances = 0
        self._plans: List[Migration] = []
        self.chip_pressure: Dict[int, ChipPressure] = {}
        self._chip_done: Dict[int, Tuple[int, int]] = {}  # ci -> (tick, done)
        # event stream (repro.obs); the cluster engine wires its log in
        self.obs = NULL_LOG

    def _local_quarantine(self, ci: int) -> Optional[int]:
        q = self.quarantine
        if q is None or self.mesh.chip_of(q) != ci:
            return None
        return self.mesh.chip_groups(ci).index(q)

    # -- engine surface --------------------------------------------------------

    def take_plans(self) -> List[Migration]:
        plans, self._plans = self._plans, []
        return plans

    def reserved_parts(self, groups: Sequence) -> set:
        """The quarantine reservation, in global group indices."""
        out = set()
        q = self.quarantine
        if q is not None and 0 <= q < len(groups):
            topo = groups[q].controller.state.topology
            if len(topo) >= 2 and topo[-1] == 1:
                out.add((q, len(topo) - 1))
        return out

    # -- pressure --------------------------------------------------------------

    def _pressure_sample(self, ci: int, tick: int,
                         cgroups: Sequence) -> ChipPressure:
        remaining = [r.remaining for g in cgroups
                     for r in g.live_requests()]
        queue_depth = sum(len(g.queue) for g in cgroups)
        capacity = sum(sum(getattr(g, "topology", (1,))) for g in cgroups)
        fv = FeatureVector.from_group(remaining, queue_depth,
                                      arrival_rate=0.0,
                                      capacity=max(capacity, 1))
        done = sum(g.stats.completed for g in cgroups)
        prev = self._chip_done.get(ci)
        self._chip_done[ci] = (tick, done)
        rate = 0.0 if prev is None or tick <= prev[0] \
            else (done - prev[1]) / (tick - prev[0])
        total, long_n = 0, 0
        for g in cgroups:
            for r in g.live_requests():
                total += 1
                long_n += r.remaining >= self.long_threshold
            for r in g.queue:
                total += 1
                long_n += r.max_new_tokens >= self.long_threshold
        return ChipPressure(chip=ci, fv=fv, drain_rate=rate,
                            long_frac=long_n / total if total else 0.0)

    # -- the control tick ------------------------------------------------------

    def rebalance(self, tick: int, groups: Sequence) -> int:
        if tick % self.every != 0:
            return 0
        issued = 0
        long_fracs: Dict[int, float] = {}
        for ci, fc in enumerate(self.chip_controllers):
            gids = [g for g in self.mesh.chip_groups(ci)
                    if g < len(groups)]
            if not gids:
                continue
            cgroups = [groups[g] for g in gids]
            p = self._pressure_sample(ci, tick, cgroups)
            self.chip_pressure[ci] = p
            long_fracs[ci] = p.long_frac
            issued += fc.rebalance(tick, cgroups)
        if self.regions is not None:
            before = {ci: tuple(r.groups)
                      for ci, r in self.regions.active.items()} \
                if self.obs.enabled else {}
            # gather first would fight this tick's mix nudges; stepping
            # after lets the re-asserted deep hints win (last hint wins)
            issued += self.regions.step(tick, groups, long_fracs,
                                        quarantine=self.quarantine)
            if self.obs.enabled:
                after = {ci: tuple(r.groups)
                         for ci, r in self.regions.active.items()}
                for ci in sorted(set(before) | set(after)):
                    b, a = before.get(ci), after.get(ci)
                    if b == a:
                        continue
                    action = ("gather" if b is None
                              else "release" if a is None else "resize")
                    gids = a if a is not None else b
                    self.obs.emit("region_grab", gid=gids[0], tick=tick,
                                  chip=ci, action=action,
                                  groups=list(gids))
            self.planner.set_regions(self.regions.region_groups())
        self._plans = self.planner.plan(
            tick, groups, reserved=self.reserved_parts(groups))
        if self.leases is not None:
            self.leases.step(tick, groups,
                             reserved=self.reserved_parts(groups))
        self.rebalances += issued > 0
        return issued

    # -- telemetry -------------------------------------------------------------

    def cluster_summary(self, groups: Optional[Sequence] = None) -> Dict:
        out = {
            "chips": self.mesh.num_chips,
            "groups_per_chip": self.mesh.groups_per_chip,
            "nodes": self.mesh.num_nodes,
            "distance_blind": self.ccfg.distance_blind,
            "chip_pressure": {str(ci): p.as_dict()
                              for ci, p in sorted(self.chip_pressure.items())},
            "tier_bytes": dict(self.planner.tier_bytes),
            "tier_stall_ticks": dict(self.planner.tier_stall_ticks),
        }
        if self.regions is not None:
            out["regions"] = self.regions.summary()
        return out
