"""Hierarchical fleet-of-fleets on a 2D chip mesh with tiered costs.

The layer above ``repro.fleet``: groups sit at 2D coordinates,
partitioned into chips (and chips into nodes), and moving state between
two groups is priced by the *tier* of the pair — intra-chip NoC,
inter-chip link, inter-node network — with per-hop latency.  A
:class:`ClusterController` steers each chip's split-mix against its own
pressure, gathers regions of adjacent groups for long-context tail
mass, and authorizes cross-chip steals and live migrations only when
the tiered cost amortizes; a :class:`ClusterEngine` drives it all with
the unchanged ``FleetEngine`` loop.
"""
from repro.cluster.controller import (ChipPressure, ClusterController,
                                      ClusterPlanner)
from repro.cluster.engine import ClusterEngine
from repro.cluster.mesh import TIERS, ClusterMesh, TieredTransferCost
from repro.cluster.regions import Region, RegionManager

__all__ = [
    "TIERS", "ClusterMesh", "TieredTransferCost",
    "ClusterPlanner", "ClusterController", "ChipPressure",
    "ClusterEngine", "Region", "RegionManager",
]
