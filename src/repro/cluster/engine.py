"""ClusterEngine: a FleetEngine whose control plane is the cluster stack.

The wiring is deliberately thin: ``FleetEngine.run`` already drives a
controller (``rebalance`` / ``take_plans``) and a planner (``execute``)
between decode ticks, so swapping the flat
:class:`~repro.control.FleetController` for a
:class:`~repro.cluster.ClusterController` — which presents the same
surface — re-uses the whole loop.  Only two hooks differ:

* ``_deliver`` also lands in-flight cross-chip steals whose transfer
  time has elapsed (the slow-link ticks a stolen request spends in the
  air before it can even queue at its recipient);
* ``_next_event`` folds the earliest in-flight landing into the idle
  fast-forward horizon, so an otherwise-idle fleet never terminates
  with requests still on the wire.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ClusterConfig, FleetConfig, ModelConfig
from repro.cluster.controller import ClusterController
from repro.cluster.mesh import ClusterMesh
from repro.fleet.scheduler import FleetEngine


class ClusterEngine(FleetEngine):
    """N groups on a 2D chip mesh under hierarchical, tiered control.

    ``cluster`` may come as an argument or as ``fleet.cluster``; the
    cluster layer needs a dynamic fleet with migration enabled (its
    planner *is* the migration planner, tiered).
    """

    def __init__(self, model_cfg: ModelConfig, params, *,
                 fleet: FleetConfig = FleetConfig(),
                 cluster: Optional[ClusterConfig] = None, **kw):
        cluster = cluster or fleet.cluster or ClusterConfig()
        fleet = fleet.replace(cluster=cluster)
        if fleet.mode != "dynamic" or not fleet.migrate.enabled:
            raise ValueError(
                "ClusterEngine needs mode='dynamic' and "
                "fleet.migrate.enabled (the cluster planner is the "
                "tiered migration planner)")
        super().__init__(model_cfg, params, fleet=fleet, **kw)
        self.mesh = ClusterMesh(
            num_groups=fleet.num_groups,
            groups_per_chip=cluster.groups_per_chip,
            chips_per_node=cluster.chips_per_node)
        self.cluster = ClusterController(self.mesh, cluster, fleet,
                                         model_cfg)
        # swap the flat chip-level control plane for the cluster stack;
        # run()/telemetry drive .controller/.planner exactly as before
        self.controller = self.cluster
        self.planner = self.cluster.planner
        if self.leases is not None:
            # cross-group leases now confine to adjacent same-chip pairs
            # and price their NoC tax with the *physical* tiered cost
            self.leases.mesh = self.mesh
            self.leases.cost = self.cluster.cost
            self.cluster.leases = self.leases
        # the router's admission-spill pressure view rides the tiered
        # planner now
        self._router_state["planner"] = self.planner
        # one event stream for the whole hierarchy: the tiered planner's
        # steals/migrations and the region gathers land in the same log,
        # and exporters get the mesh layout for chip-grouped rendering
        self.planner.obs = self.obs
        self.cluster.obs = self.obs
        self.obs.meta["mesh"] = self.mesh.layout()

    def _deliver(self) -> None:
        self.planner.deliver_in_flight(self.wall, self.groups)
        super()._deliver()

    def _next_event(self) -> Optional[int]:
        events = [t for t in (super()._next_event(),
                              self.planner.next_arrival())
                  if t is not None]
        return min(events) if events else None
