"""Region gather: adjacent same-chip groups fused into one deep tail unit.

The zamlet mesh-of-Amlets design gathers a *region* — a connected patch
of the mesh — into one larger logical processor while a workload needs
it, and releases the patch when it drains.  The serving translation:
when a chip's outstanding work turns long-heavy
(``ClusterConfig.region_long_frac``), the :class:`RegionManager` picks a
connected set of adjacent same-chip groups carrying the most long mass
and drives each of them — through the *existing* composition API,
:meth:`repro.control.GroupController.request_topology` — to its deepest
legal balanced composition.  The region then acts as one deep logical
group for the long-context tail: many narrow slices, each quarantining
one long request at minimal slot-step waste, and the cluster planner
boosts region groups as steal recipients so tail work actually lands
there.  When the chip's long fraction falls back under
``region_release_frac`` (and the region has dwelt ``region_dwell``
ticks), the member groups are hinted back to fused and returned to
their own policy's control.

Hints, not force: every gather/release flows through the per-part dwell
clocks and legality checks of the group controller, exactly like a
fleet-level mix nudge — a region can never bypass a group's pacing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.configs.base import ClusterConfig
from repro.cluster.mesh import ClusterMesh
from repro.control.space import Topology, balanced


@dataclass
class Region:
    """One gathered patch: adjacent groups on one chip, plus its clock."""
    chip: int
    groups: Tuple[int, ...]
    opened: int                    # tick the gather was issued


class RegionManager:
    """Opens, maintains, and releases at most one region per chip."""

    def __init__(self, mesh: ClusterMesh, ccfg: ClusterConfig,
                 long_threshold: int = 24):
        self.mesh = mesh
        self.ccfg = ccfg
        self.long_threshold = long_threshold
        self.active: Dict[int, Region] = {}      # chip -> region
        self.gathered = 0
        self.released = 0

    # -- queries ---------------------------------------------------------------

    def region_groups(self) -> FrozenSet[int]:
        """Every group currently inside a gathered region."""
        return frozenset(g for r in self.active.values() for g in r.groups)

    def summary(self) -> Dict:
        return {"gathered": self.gathered, "released": self.released,
                "active": [list(r.groups)
                           for _, r in sorted(self.active.items())]}

    # -- the deep target -------------------------------------------------------

    @staticmethod
    def deep_topology(space) -> Topology:
        """Deepest legal balanced composition of a group's space."""
        for ways in range(min(space.max_ways, space.capacity), 1, -1):
            t = balanced(space.capacity, ways)
            if space.legal(t):
                return t
        return (space.capacity,)

    # -- long-mass scoring -----------------------------------------------------

    def _long_mass(self, g) -> int:
        thr = self.long_threshold
        return (sum(1 for r in g.live_requests() if r.remaining >= thr)
                + sum(1 for r in g.queue if r.max_new_tokens >= thr))

    def _pick(self, ci: int, groups: Sequence,
              quarantine: Optional[int]) -> List[int]:
        """A connected, adjacency-grown set of the chip's longest groups."""
        cands = [g for g in self.mesh.chip_groups(ci)
                 if g < len(groups) and g != quarantine]
        score = {g: self._long_mass(groups[g]) for g in cands}
        if not cands or max(score.values()) <= 0:
            return []
        seed = max(cands, key=lambda g: (score[g], -g))
        region = [seed]
        while len(region) < self.ccfg.region_max_groups:
            adj = [g for g in cands if g not in region
                   and any(self.mesh.adjacent(g, m) for m in region)]
            if not adj:
                break
            region.append(max(adj, key=lambda g: (score[g], -g)))
        return sorted(region)

    # -- the control tick ------------------------------------------------------

    def _assert_deep(self, region: Region, groups: Sequence) -> int:
        """(Re-)hint every member toward its deep target; returns hints."""
        issued = 0
        for gi in region.groups:
            ctl = groups[gi].controller
            target = self.deep_topology(ctl.space)
            if ctl.state.topology != target:
                ctl.request_topology(target)
                issued += 1
        return issued

    def step(self, tick: int, groups: Sequence,
             long_fracs: Dict[int, float],
             quarantine: Optional[int] = None) -> int:
        """One cluster control tick of gather/maintain/release decisions.

        ``long_fracs`` maps chip -> fraction of its outstanding work
        past ``long_threshold`` (the tail-mass half of the chip
        pressure the :class:`~repro.cluster.ClusterController` tracks).
        Re-asserting the deep hints each tick keeps a region's members
        from being re-absorbed by the chip's split-mix nudging while
        the region is open.
        """
        issued = 0
        for ci in range(self.mesh.num_chips):
            frac = long_fracs.get(ci, 0.0)
            region = self.active.get(ci)
            if region is not None:
                drained = frac <= self.ccfg.region_release_frac
                if drained and tick - region.opened >= self.ccfg.region_dwell:
                    for gi in region.groups:
                        ctl = groups[gi].controller
                        ctl.request_topology((ctl.space.capacity,))
                    del self.active[ci]
                    self.released += 1
                    issued += 1
                else:
                    issued += self._assert_deep(region, groups)
            elif frac >= self.ccfg.region_long_frac:
                picked = self._pick(ci, groups, quarantine)
                if picked:
                    region = Region(ci, tuple(picked), tick)
                    self.active[ci] = region
                    issued += max(self._assert_deep(region, groups), 1)
                    self.gathered += 1
        return issued
