"""2D chip-mesh geometry and the tiered transfer-cost model.

AMOEBA's design-parameter study makes the NoC a first-order term: how
far fusing pays off depends on what moving state between cores costs,
and that cost is not flat — it depends on where the cores sit.  The
fleet layer (PRs 1-4) priced every migration over one
``link_bandwidth`` as if all groups were equidistant.  This module adds
the missing geometry:

* :class:`ClusterMesh` places every group at a 2D coordinate and
  partitions groups into **chips** (and chips into **nodes**), following
  the mesh-of-Amlets shape: a chip is a small contiguous tile of groups
  wired by a fast network-on-chip, chips on one node share a board-level
  link, and nodes talk over the datacenter network.

* :class:`TieredTransferCost` generalizes
  :class:`repro.fleet.migrate.KVTransferCost`: the bytes model is
  inherited unchanged (including quantized int8 pricing), but the
  stall conversion picks per-**tier** bandwidth and a per-hop latency
  from the pair's position — intra-chip NoC, inter-chip link, or
  inter-node network — so a same-chip move can amortize where the
  identical move across nodes is vetoed.  A zero bandwidth on any tier
  prices that tier at infinity, which vetoes every move that must cross
  it while leaving the cheaper tiers flowing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import cached_property
from typing import List, Optional, Tuple

from repro.configs.base import ClusterConfig, ModelConfig
from repro.fleet.migrate import KVTransferCost

# transfer tiers, cheapest first; "self" (same group) never transfers
TIERS = ("noc", "link", "net")

# a pinned request handoff (a queue steal) ships the prompt tokens, not
# the KV cache; int32 token ids on the wire
TOKEN_BYTES = 4


@dataclass(frozen=True)
class ClusterMesh:
    """Group placement: chips of groups tiled on a 2D grid.

    Groups ``[0, num_groups)`` are assigned to chips contiguously
    (``chip_of(g) = g // groups_per_chip``).  Each chip lays its groups
    out row-major on a near-square sub-grid, and the chips themselves
    tile row-major on a near-square chip grid, so every group gets a
    global ``(x, y)`` coordinate and distances are Manhattan hop counts
    — the standard 2D-mesh NoC metric.
    """
    num_groups: int
    groups_per_chip: int = ClusterConfig.groups_per_chip
    chips_per_node: Optional[int] = ClusterConfig.chips_per_node

    def __post_init__(self):
        if self.num_groups < 1 or self.groups_per_chip < 1:
            raise ValueError("mesh needs >=1 group and >=1 group per chip")
        if self.chips_per_node is not None and self.chips_per_node < 1:
            raise ValueError("chips_per_node must be >=1 (or None)")

    # -- partition -------------------------------------------------------------

    @property
    def num_chips(self) -> int:
        return -(-self.num_groups // self.groups_per_chip)

    @property
    def num_nodes(self) -> int:
        if self.chips_per_node is None:
            return 1
        return -(-self.num_chips // self.chips_per_node)

    def chip_of(self, gi: int) -> int:
        return gi // self.groups_per_chip

    def node_of(self, ci: int) -> int:
        return 0 if self.chips_per_node is None else ci // self.chips_per_node

    def chip_groups(self, ci: int) -> List[int]:
        lo = ci * self.groups_per_chip
        return list(range(lo, min(lo + self.groups_per_chip,
                                  self.num_groups)))

    # -- geometry --------------------------------------------------------------

    @cached_property
    def _chip_cols(self) -> int:
        return max(int(math.ceil(math.sqrt(self.groups_per_chip))), 1)

    @cached_property
    def _chip_shape(self) -> Tuple[int, int]:
        w = self._chip_cols
        return w, -(-self.groups_per_chip // w)

    @cached_property
    def _grid_cols(self) -> int:
        return max(int(math.ceil(math.sqrt(self.num_chips))), 1)

    def coord(self, gi: int) -> Tuple[int, int]:
        """Global 2D coordinate of group ``gi``."""
        if not 0 <= gi < self.num_groups:
            raise IndexError(f"group {gi} outside mesh of {self.num_groups}")
        ci, li = divmod(gi, self.groups_per_chip)
        w, h = self._chip_shape
        ox, oy = (ci % self._grid_cols) * w, (ci // self._grid_cols) * h
        return ox + li % w, oy + li // w

    def hops(self, a: int, b: int) -> int:
        """Manhattan distance between two groups' coordinates."""
        (ax, ay), (bx, by) = self.coord(a), self.coord(b)
        return abs(ax - bx) + abs(ay - by)

    def adjacent(self, a: int, b: int) -> bool:
        """Same-chip nearest neighbors — region-gather's fuse criterion."""
        return a != b and self.chip_of(a) == self.chip_of(b) \
            and self.hops(a, b) == 1

    def tier(self, a: int, b: int) -> str:
        """Transfer tier of the pair: self | noc | link | net."""
        if a == b:
            return "self"
        ca, cb = self.chip_of(a), self.chip_of(b)
        if ca == cb:
            return "noc"
        if self.node_of(ca) == self.node_of(cb):
            return "link"
        return "net"

    def layout(self) -> dict:
        """JSON-able placement map for trace exporters (repro.obs).

        Keys are strings so the dict survives a JSONL round-trip
        unchanged — json object keys are always strings.
        """
        return {
            "num_groups": self.num_groups,
            "groups_per_chip": self.groups_per_chip,
            "chips_per_node": self.chips_per_node,
            "chip_of": {str(g): self.chip_of(g)
                        for g in range(self.num_groups)},
            "node_of_chip": {str(c): self.node_of(c)
                             for c in range(self.num_chips)},
            "coord": {str(g): list(self.coord(g))
                      for g in range(self.num_groups)},
        }

    def describe(self) -> str:
        """One line per chip — the example/demo layout dump."""
        lines = []
        for ci in range(self.num_chips):
            coords = ", ".join(f"g{g}@{self.coord(g)}"
                               for g in self.chip_groups(ci))
            lines.append(f"chip {ci} (node {self.node_of(ci)}): {coords}")
        return "\n".join(lines)


@dataclass(frozen=True)
class TieredTransferCost(KVTransferCost):
    """Distance-tiered pricing for moving state between groups.

    The bytes model is the parent's (attention KV rows + recurrent
    state, window-capped, optionally int8-quantized); only the
    bytes-to-stall conversion changes.  A transfer between groups
    ``src`` and ``dst`` is priced

    ``ticks = ceil(hop_latency(tier) * hops(src, dst) + bytes / bandwidth(tier))``

    with ``(bandwidth, hop_latency)`` chosen by the pair's tier — the
    wormhole-routing shape where the head of the message pays one
    latency per hop while the body streams at the bottleneck tier's
    bandwidth.  Without ``src``/``dst`` the parent's flat pricing
    applies (``link_bandwidth``, no hop term), so a tiered cost object
    degrades gracefully wherever a flat one is expected.
    """
    mesh: Optional[ClusterMesh] = None
    noc_bandwidth: float = ClusterConfig.noc_bandwidth
    noc_latency: float = ClusterConfig.noc_latency
    # link_bandwidth inherited: the inter-chip tier
    link_latency: float = ClusterConfig.link_latency
    net_bandwidth: float = ClusterConfig.net_bandwidth
    net_latency: float = ClusterConfig.net_latency

    @classmethod
    def from_config(cls, mesh: ClusterMesh, ccfg: ClusterConfig,
                    dtype_bytes: int, quantized: bool
                    ) -> "TieredTransferCost":
        return cls(mesh=mesh, dtype_bytes=dtype_bytes, quantized=quantized,
                   noc_bandwidth=ccfg.noc_bandwidth,
                   noc_latency=ccfg.noc_latency,
                   link_bandwidth=ccfg.link_bandwidth,
                   link_latency=ccfg.link_latency,
                   net_bandwidth=ccfg.net_bandwidth,
                   net_latency=ccfg.net_latency)

    def tier_params(self, tier: str) -> Tuple[float, float]:
        """(bandwidth bytes/tick, per-hop latency ticks) for a tier."""
        return {"noc": (self.noc_bandwidth, self.noc_latency),
                "link": (self.link_bandwidth, self.link_latency),
                "net": (self.net_bandwidth, self.net_latency)}[tier]

    def transfer_ticks(self, nbytes: int, src: Optional[int],
                       dst: Optional[int]) -> float:
        """Wall ticks for ``nbytes`` between two groups (0 if same)."""
        if src is None or dst is None or self.mesh is None:
            # flat fallback: the parent's link pricing, no hop term
            if self.link_bandwidth <= 0:
                return math.inf
            return math.ceil(nbytes / self.link_bandwidth)
        tier = self.mesh.tier(src, dst)
        if tier == "self":
            return 0.0
        bw, lat = self.tier_params(tier)
        if bw <= 0:
            return math.inf
        t = lat * self.mesh.hops(src, dst) + nbytes / bw
        # the wall tick is the cost quantum: a transfer that fits in a
        # fraction of a tick (a NoC hop) hides behind the decode tick,
        # and a vanishing bandwidth term must not bump an exact integer
        # latency to the next tick
        return math.ceil(t - 1e-6) if t >= 1.0 else 0.0

    def stall_ticks(self, seq_len: int, model_cfg: ModelConfig,
                    window: Optional[int] = None,
                    src: Optional[int] = None,
                    dst: Optional[int] = None) -> float:
        return self.transfer_ticks(self.kv_bytes(seq_len, model_cfg, window),
                                   src, dst)

    def steal_ticks(self, prompt_len: int, src: Optional[int],
                    dst: Optional[int]) -> float:
        """In-flight ticks for a queue steal (only the prompt travels)."""
        return self.transfer_ticks(max(int(prompt_len), 1) * TOKEN_BYTES,
                                   src, dst)
