"""Telemetry feature extraction for the reconfiguration policies.

The paper's controller samples §4.1.2 hardware metrics from a short
profiling window and feeds them to the scalability predictor.  The serving
analogue samples the live state of one reconfigurable group each wall
tick: how divergent the decode batch is, how deep the admission queue is,
how fast work is arriving, and how spread-out the remaining lengths are.
Every policy in :mod:`repro.control.policies` consumes the same
:class:`FeatureVector`; the gpusim level keeps its own 11-metric vector
(``repro.core.gpusim.sim.FEATURE_NAMES``) but flows through the same
policy objects.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Deque, Optional, Sequence, Tuple

import numpy as np

# canonical serve-level feature order (mirrors §4.1.2's sampled metrics)
SERVE_FEATURES = (
    "divergence",        # 1 - mean(remaining)/max(remaining) of the live batch
    "spread",            # std(remaining)/mean(remaining) — tail heaviness
    "queue_frac",        # queue depth / capacity — backfill availability
    "arrival_rate",      # recent admissions per tick
    "live_frac",         # live requests / capacity — how full the batch is
)


@dataclass
class FeatureVector:
    """One decision point's worth of live telemetry."""
    divergence: float = 0.0
    spread: float = 0.0
    queue_frac: float = 0.0
    arrival_rate: float = 0.0
    live_frac: float = 0.0
    # raw remaining lengths: the oracle and the regroup gain need the true
    # per-request state, not just its summary statistics
    remaining: Optional[np.ndarray] = None

    def to_array(self) -> np.ndarray:
        return np.array([self.divergence, self.spread, self.queue_frac,
                         self.arrival_rate, self.live_frac], np.float64)

    @staticmethod
    def from_group(remaining: Sequence[float], queue_depth: int,
                   arrival_rate: float, capacity: int) -> "FeatureVector":
        # keep already-drained rows as zeros: a fused batch whose short
        # members finished is *exactly* the divergence signal (those slots
        # run for nothing until the longest member drains)
        r = np.maximum(np.asarray(remaining, np.float64), 0.0)
        if r.size == 0 or r.max() <= 0:
            return FeatureVector(queue_frac=queue_depth / max(capacity, 1),
                                 arrival_rate=arrival_rate,
                                 remaining=r)
        mean = float(r.mean())
        return FeatureVector(
            divergence=float(1.0 - mean / r.max()),
            spread=float(r.std() / mean) if mean > 0 else 0.0,
            queue_frac=queue_depth / max(capacity, 1),
            arrival_rate=arrival_rate,
            live_frac=float((r > 0).sum()) / max(capacity, 1),
            remaining=r,
        )


class ArrivalRateTracker:
    """Rolling admissions-per-tick estimate over a short window."""

    def __init__(self, window: int = 32):
        self.window = window
        self._events: Deque[Tuple[int, int]] = collections.deque()

    def record(self, tick: int, n: int) -> None:
        if n:
            self._events.append((tick, n))
        while self._events and self._events[0][0] < tick - self.window:
            self._events.popleft()

    def rate(self, tick: int) -> float:
        while self._events and self._events[0][0] < tick - self.window:
            self._events.popleft()
        if not self._events:
            return 0.0
        return sum(n for _, n in self._events) / float(self.window)


class ReplayBuffer:
    """Bounded FIFO of (features, realized-win label) decision samples.

    The fleet telemetry logs one sample per decision tick; the
    :class:`~repro.control.policies.OnlinePolicy` periodically refits its
    logistic model from the buffer — the online-retraining loop the paper
    leaves as future work ("the model could be retrained on-line").

    Two staleness controls keep a regime change (bursty -> steady) from
    dominating the fit for ``maxlen`` samples: :meth:`weighted_dataset`
    decays each sample's fit weight exponentially with its age, and
    :meth:`reset` is the drift-reset hook that drops everything but the
    newest window outright.
    """

    def __init__(self, maxlen: int = 4096):
        self.maxlen = maxlen
        self._x: Deque[np.ndarray] = collections.deque(maxlen=maxlen)
        self._y: Deque[float] = collections.deque(maxlen=maxlen)
        # lifetime add count: sample i's absolute index survives eviction,
        # so the decision audit (repro.obs.audit) can map an event's
        # replay_idx back to a retained row via total_added - len(self)
        self.total_added = 0

    def add(self, features: np.ndarray, label: float) -> int:
        """Append a sample; returns its absolute (lifetime) index."""
        self._x.append(np.asarray(features, np.float64))
        self._y.append(float(label))
        idx = self.total_added
        self.total_added += 1
        return idx

    def __len__(self) -> int:
        return len(self._x)

    def dataset(self) -> Tuple[np.ndarray, np.ndarray]:
        if not self._x:
            return np.zeros((0, len(SERVE_FEATURES))), np.zeros((0,))
        return np.stack(list(self._x)), np.asarray(list(self._y))

    def weighted_dataset(self, half_life: Optional[int] = None
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(X, y, w) with recency weights ``w = 0.5 ** (age / half_life)``.

        The newest sample has age 0 (weight 1.0); a sample one half-life
        older counts half as much in the refit.  ``half_life=None``
        returns uniform weights (the legacy FIFO behavior).
        """
        X, y = self.dataset()
        n = X.shape[0]
        if half_life is None or n == 0:
            return X, y, np.ones(n)
        age = np.arange(n - 1, -1, -1, dtype=np.float64)
        return X, y, 0.5 ** (age / max(half_life, 1))

    def tail(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """The newest ``n`` samples — the drift-detection window."""
        X, y = self.dataset()
        return X[-n:], y[-n:]

    def reset(self, keep_last: int = 0) -> None:
        """Drift-reset hook: forget everything but the newest samples."""
        if keep_last <= 0:
            self._x.clear()
            self._y.clear()
            return
        xs, ys = list(self._x)[-keep_last:], list(self._y)[-keep_last:]
        self._x.clear()
        self._y.clear()
        self._x.extend(xs)
        self._y.extend(ys)

    def label_balance(self) -> float:
        """Fraction of positive (split-wins) labels — refit gate."""
        if not self._y:
            return 0.0
        return float(np.mean(list(self._y)))
