"""repro.control — the unified reconfiguration control plane.

One policy stack drives every layer that reconfigures: the gpusim pair
fabric, the serving groups, the fleet, and the trainer.  The paper's
monitor -> predict -> reconfigure loop (§4.1, Fig 7) lives here once:

* ``features``   — FeatureVector from live telemetry + the replay buffer
                   (recency-weighted refits, drift reset).
* ``space``      — ConfigSpace: composition topologies (``(8,)`` fused,
                   ``(4, 4)`` the pair, ``(5, 3)`` a skewed cut) with
                   per-part amortization-checked moves.
* ``policies``   — ReconfigPolicy protocol: Threshold / Predictor /
                   Oracle / Online implementations + the shared
                   hysteresis primitive.
* ``controller`` — GroupController (per-part dwell + transition
                   enforcement) and FleetController (chip-wide split-mix
                   rebalancing, including deepening under tail mass).
* ``offline``    — serve-level predictor training corpus + the Fig 20
                   feature ablation.
"""
from repro.control.controller import (ControlState, FleetController,
                                      GroupController)
from repro.control.features import (SERVE_FEATURES, ArrivalRateTracker,
                                    FeatureVector, ReplayBuffer)
from repro.control.offline import (build_serve_corpus,
                                   serve_feature_ablation,
                                   train_serve_predictor)
from repro.control.policies import (POLICY_NAMES, Decision, OnlinePolicy,
                                    OraclePolicy, PredictorPolicy,
                                    ReconfigPolicy, ThresholdPolicy,
                                    hysteresis_toggle, make_policy)
from repro.control.space import (ConfigSpace, Topology, balanced, n_parts,
                                 topology_name)

__all__ = [
    "ControlState", "FleetController", "GroupController",
    "SERVE_FEATURES", "ArrivalRateTracker", "FeatureVector", "ReplayBuffer",
    "build_serve_corpus", "serve_feature_ablation", "train_serve_predictor",
    "POLICY_NAMES", "Decision", "OnlinePolicy", "OraclePolicy",
    "PredictorPolicy", "ReconfigPolicy", "ThresholdPolicy",
    "hysteresis_toggle", "make_policy",
    "ConfigSpace", "Topology", "balanced", "n_parts", "topology_name",
]
