"""Reconfiguration policies: one decision stack for every layer.

The paper's monitor -> predict -> reconfigure loop (§4.1, Fig 7) appears
at three levels of this reproduction — the cycle-level simulator, the
serving engine, and the trainer.  Each policy here answers the same
question at a decision point: *given the telemetry, what topology should
this group take?*  Topologies are integer compositions of the group's
capacity (:mod:`repro.control.space`), so a proposal may be the paper's
heterogeneous cut — ``(5, 3)`` for a skewed tail — not just a ladder
rung.

* :class:`ThresholdPolicy` — the paper's fixed-ratio hysteresis: split
  past ``split_threshold`` when the regroup gain is positive, re-fuse
  under ``fuse_threshold`` (Fig 10/11, lifted verbatim from the old
  ``AmoebaController.observe``).
* :class:`PredictorPolicy` — §4.1.3's logistic scalability model run
  online over a feature vector ("a single MAC per feature").
* :class:`OraclePolicy` — run-both-pick-better: searches the composition
  lattice with a caller-supplied measure (the simulator's dual static
  runs, or the true slot-cost of the live batch) and steps toward the
  argmax one move at a time.
* :class:`OnlinePolicy` — PredictorPolicy plus periodic recency-weighted
  refits from a replay buffer of (features, realized-win) labels, with a
  drift-reset hook; bootstraps from the threshold rule until the first
  fit.

Policies are *advisory*: they propose a topology; the
:class:`~repro.control.controller.GroupController` enforces per-part
dwell and the :class:`~repro.control.space.ConfigSpace` amortization
check before any transition happens.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Tuple

import numpy as np

from repro.control.features import SERVE_FEATURES, FeatureVector, ReplayBuffer
from repro.control.space import ConfigSpace, Topology, TopologyLike, n_parts
from repro.core import predictor as P
from repro.core.regroup import regroup_gain
from repro.obs.events import NULL_LOG


@dataclass
class Decision:
    """A proposed topology with the evidence behind it.

    ``ways`` is the part count (the legacy scalar every caller already
    understands); ``topology`` carries the exact composition when the
    policy could compute one — the controller materializes a skew-aware
    move itself when it is None.
    """
    ways: int
    proba: float = 0.5            # P(more-split is better), when meaningful
    gain: float = 0.0             # predicted relative slot-waste saving
    reason: str = ""
    topology: Optional[Topology] = None


def _normalize(cur: TopologyLike, space: Optional[ConfigSpace]
               ) -> Tuple[Optional[Topology], int]:
    """(topology or None, part count) from an int-or-tuple current state."""
    if isinstance(cur, int):
        return (space.as_topology(cur) if space is not None else None,
                cur)
    return tuple(cur), len(cur)


# -- the shared hysteresis primitive -----------------------------------------
# Both the scalar serve/train path and the vectorized 24-pair simulator loop
# are instances of this one rule, so it lives here and nowhere else.

def hysteresis_toggle(is_split: np.ndarray, divergence: np.ndarray,
                      split_threshold: float, fuse_threshold: float,
                      want_split: np.ndarray, want_fuse: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """(split_now, fuse_now) masks under hysteresis (paper Fig 10/11).

    Split when fused, divergent past the threshold, *and* the caller's
    benefit estimate agrees; fuse when split and either calm below the
    lower threshold or the estimate says fused is better again.
    """
    is_split = np.asarray(is_split, bool)
    split_now = (~is_split) & (np.asarray(divergence) > split_threshold) \
        & np.asarray(want_split, bool)
    fuse_now = is_split & ((np.asarray(divergence) < fuse_threshold)
                           | np.asarray(want_fuse, bool))
    return split_now, fuse_now


class ReconfigPolicy(Protocol):
    """Protocol every policy implements."""
    name: str

    def decide(self, fv: FeatureVector, ways: TopologyLike) -> Decision:
        """Propose a topology given telemetry and the current topology."""
        ...


# ---------------------------------------------------------------------------
# ThresholdPolicy — today's hysteresis + regroup-gain veto
# ---------------------------------------------------------------------------

@dataclass
class ThresholdPolicy:
    """Fixed-ratio hysteresis with a regroup-gain veto on splits."""
    split_threshold: float = 0.25
    fuse_threshold: float = 0.10
    regroup_policy: str = "warp_regroup"
    space: Optional[ConfigSpace] = None
    name: str = "threshold"

    def decide(self, fv: FeatureVector, cur: TopologyLike) -> Decision:
        topo, ways = _normalize(cur, self.space)
        smart = self.space is not None and topo is not None \
            and fv.remaining is not None
        split_now, fuse_now = hysteresis_toggle(
            np.array(ways > 1), np.array(fv.divergence),
            self.split_threshold, self.fuse_threshold,
            want_split=np.array(True), want_fuse=np.array(False))
        if bool(split_now):
            if smart:
                t = self.space.suggest_split(topo, fv.remaining,
                                             self.regroup_policy)
                if t is not None:
                    g = self.space.move_gain(fv.remaining, topo, t,
                                             self.regroup_policy)
                    if g > 0.0:
                        return Decision(
                            len(t), proba=1.0, gain=g, topology=t,
                            reason=f"divergence {fv.divergence:.3f} > "
                                   f"{self.split_threshold}")
                return Decision(ways, reason="hold")
            gain = (regroup_gain(fv.remaining, self.regroup_policy)
                    if fv.remaining is not None else fv.divergence)
            if gain > 0.0:
                return Decision(ways * 2, proba=1.0, gain=gain,
                                reason=f"divergence {fv.divergence:.3f} > "
                                       f"{self.split_threshold}")
        elif ways > 1 and fv.divergence > self.split_threshold and smart:
            # already split but the live mix drifted divergent again:
            # deepen or re-cut the composition (the hysteresis pair
            # above only handles the fused<->split toggle)
            t = self.space.suggest_improve(topo, fv.remaining,
                                           self.regroup_policy)
            if t is not None:
                g = self.space.move_gain(fv.remaining, topo, t,
                                         self.regroup_policy)
                if g > 0.0:
                    return Decision(
                        len(t), proba=1.0, gain=g, topology=t,
                        reason=f"recut: divergence {fv.divergence:.3f} > "
                               f"{self.split_threshold}")
            return Decision(ways, reason="hold")
        elif bool(fuse_now):
            t = None
            if self.space is not None and topo is not None:
                t = self.space.suggest_fuse(topo, fv.remaining,
                                            self.regroup_policy)
            return Decision(len(t) if t is not None else ways // 2,
                            proba=0.0, gain=0.0, topology=t,
                            reason=f"divergence {fv.divergence:.3f} < "
                                   f"{self.fuse_threshold}")
        return Decision(ways, reason="hold")


# ---------------------------------------------------------------------------
# PredictorPolicy — logistic inference over live telemetry
# ---------------------------------------------------------------------------

@dataclass
class PredictorPolicy:
    """§4.1.3's binary logistic model in the loop.

    ``positive_means_split`` fixes the label convention: serve-level
    corpora label 1 = "splitting wins", while the gpusim corpus labels
    1 = "fused/scale-up wins" (the paper's convention).  ``proba_band``
    is the hysteresis band around 0.5 that rate-limits topology flapping.
    """
    model: Optional[P.LogisticModel] = None
    proba_band: float = 0.10
    regroup_policy: str = "warp_regroup"
    positive_means_split: bool = True
    space: Optional[ConfigSpace] = None
    name: str = "predictor"

    @classmethod
    def from_decider(cls, fuse_decider: Callable[[np.ndarray], bool]
                     ) -> "PredictorPolicy":
        """Wrap a bare features->fuse? callable (the gpusim interface)."""
        pol = cls(model=None, positive_means_split=False)
        pol._decider = fuse_decider
        return pol

    def proba_split(self, x: np.ndarray) -> float:
        """P(the more-split configuration wins) under the model."""
        decider = getattr(self, "_decider", None)
        if decider is not None:
            return 0.0 if bool(decider(np.asarray(x))) else 1.0
        if self.model is None:
            raise ValueError("PredictorPolicy needs a model or a decider")
        p = float(P.predict_proba(self.model, np.asarray(x, np.float64)))
        return p if self.positive_means_split else 1.0 - p

    def feature_impacts(self, x: np.ndarray) -> Dict[str, float]:
        """Paper Fig 20 at the serve level: per-feature impact of one
        decision point (standardized value x coefficient).  Positive
        entries push toward splitting under the serve label convention.
        """
        if self.model is None:
            raise ValueError("feature_impacts needs a trained model")
        imp = np.asarray(P.feature_impacts(self.model,
                                           np.asarray(x, np.float64)))
        if not self.positive_means_split:
            imp = -imp
        names = self.model.feature_names or SERVE_FEATURES
        return {name: float(v) for name, v in zip(names, imp)}

    def choose_static(self, features: np.ndarray) -> bool:
        """One-shot per-kernel choice: True = fuse (the gpusim path).

        Fusing needs a strict majority — a 0.5 tie stays scale-out, the
        paper's default configuration.
        """
        return self.proba_split(features) < 0.5

    def decide(self, fv: FeatureVector, cur: TopologyLike) -> Decision:
        topo, ways = _normalize(cur, self.space)
        p = self.proba_split(fv.to_array())
        if p > 0.5 + self.proba_band / 2:
            # gain is the *true* predicted slot-waste saving so the
            # ConfigSpace amortization floor still gates a confident but
            # wrong model; model confidence only stands in when no live
            # remaining lengths exist to score (computed in this branch
            # only — hold/fuse ticks never consume it)
            t = None
            if fv.remaining is None:
                gain = p - 0.5
            elif topo is not None and self.space is not None:
                # deepen from fused; deepen-or-recut once already split
                t = self.space.suggest_improve(topo, fv.remaining,
                                               self.regroup_policy)
                gain = 0.0 if t is None else self.space.move_gain(
                    fv.remaining, topo, t, self.regroup_policy)
            else:
                gain = regroup_gain(fv.remaining, self.regroup_policy)
            return Decision(len(t) if t is not None else ways * 2,
                            proba=p, gain=gain, topology=t,
                            reason=f"P(split)={p:.3f}")
        if p < 0.5 - self.proba_band / 2 and ways > 1:
            t = None if self.space is None or topo is None \
                else self.space.suggest_fuse(topo, fv.remaining,
                                             self.regroup_policy)
            return Decision(len(t) if t is not None else ways // 2,
                            proba=p, topology=t, reason=f"P(split)={p:.3f}")
        return Decision(ways, proba=p, reason="inside hysteresis band")


# ---------------------------------------------------------------------------
# OraclePolicy — run-both-pick-better over the composition lattice
# ---------------------------------------------------------------------------

@dataclass
class OraclePolicy:
    """Search the composition lattice; step toward the argmax.

    ``score(topology, fv) -> utility`` is caller-supplied: the simulator
    measures both static configurations' IPC (the label-generation path
    that used to live inside ``gpusim.sim.run_benchmark``); the serving
    engine defaults to the true relative slot-waste saving of the live
    batch.  With the default score the target comes from
    :meth:`ConfigSpace.best_topology` (the global lattice argmax); with
    a custom score only the current topology's one-move frontier is
    scored each tick — either way the oracle emits exactly one legal
    move per decision.  ``margin`` is the improvement a split must show
    over the current topology's score — the oracle's hysteresis; fusing
    back is preferred on ties (it restores the wide configuration's
    coalescing for free).
    """
    space: ConfigSpace = field(default_factory=lambda: ConfigSpace(2))
    score: Optional[Callable[[TopologyLike, Optional[FeatureVector]],
                             float]] = None
    margin: float = 0.02
    regroup_policy: str = "warp_regroup"
    name: str = "oracle"

    def _score(self, t: TopologyLike, fv: Optional[FeatureVector]) -> float:
        if self.score is not None:
            return float(self.score(t, fv))
        if fv is None or fv.remaining is None:
            return 0.0
        return self.space.gain(fv.remaining, t, self.regroup_policy)

    def choose_static(self, features=None) -> bool:
        """One-shot choice: True = fused (ways=1) scores strictly higher."""
        return self._score(1, None) > self._score(2, None)

    def _target(self, cur: Topology, fv: FeatureVector
                ) -> Tuple[Topology, float, float]:
        """(target, best_score, cur_score) under the active measure.

        The target is the *least-split* topology scoring within
        ``margin`` of the lattice best — the fuse-back hysteresis: a
        split whose edge over wider configurations has shrunk below the
        margin is not worth its lost coalescing, so the target drops
        back toward fused.
        """
        cur_score = self._score(cur, fv)
        if self.score is None and fv.remaining is not None:
            try:
                comps = self.space.compositions()
            except ValueError:              # lattice too large to scan
                comps = None
            if comps is not None:
                # one pass: compositions are ordered fused-first by part
                # count, so the first within-margin hit is least-split
                gains = [(t, self.space.gain(fv.remaining, t,
                                             self.regroup_policy))
                         for t in comps]
                top = max(g for _, g in gains)
                for t, g in gains:
                    if g >= top - self.margin:
                        return t, top, cur_score
            best, top = self.space.best_topology(
                fv.remaining, self.regroup_policy)
            if 0.0 >= top - self.margin:    # fused is within margin
                return (self.space.capacity,), top, cur_score
            return best, top, cur_score
        best, best_score = cur, cur_score
        for nb in self.space.neighbors(cur):
            s = self._score(nb, fv)
            if s > best_score + 1e-12 or (
                    s > best_score - 1e-12 and len(nb) < len(best)):
                best, best_score = nb, s
        if self._score((self.space.capacity,), fv) >= best_score - self.margin:
            best = (self.space.capacity,)
        return best, best_score, cur_score

    def decide(self, fv: FeatureVector, cur: TopologyLike) -> Decision:
        cur_t, ways = _normalize(cur, self.space)
        if cur_t is None:
            cur_t = self.space.as_topology(ways)
        target, top, cur_score = self._target(cur_t, fv)
        if target != cur_t and len(target) >= len(cur_t) \
                and top > cur_score + self.margin:
            # deeper or re-cut: take the best single improving move
            step = self.space.suggest_improve(cur_t, fv.remaining,
                                              self.regroup_policy)
            if step is None:
                step = self.space.suggest_split(cur_t, fv.remaining,
                                                self.regroup_policy)
        elif len(target) < len(cur_t):
            step = self.space.suggest_fuse(cur_t, fv.remaining,
                                           self.regroup_policy)
        else:
            return Decision(ways, gain=cur_score, reason="oracle: hold")
        if step is None:
            return Decision(ways, gain=cur_score, reason="oracle: hold")
        gain = self.space.move_gain(fv.remaining, cur_t, step,
                                    self.regroup_policy) \
            if fv.remaining is not None else abs(top - cur_score)
        return Decision(len(step), topology=step,
                        proba=1.0 if len(step) > len(cur_t) else 0.0,
                        gain=gain,
                        reason=f"oracle: {self.space.name(target)} scores "
                               f"{top:.3f} vs {cur_score:.3f}")


# ---------------------------------------------------------------------------
# OnlinePolicy — predictor + periodic refit from the replay buffer
# ---------------------------------------------------------------------------

@dataclass
class OnlinePolicy:
    """Logistic inference that retrains itself from realized outcomes.

    Bootstraps from :class:`ThresholdPolicy` until the replay buffer has
    ``min_samples`` with both labels present, then fits (and every
    ``refit_every`` decisions refits) a logistic model via
    ``predictor.train_logistic`` — whose per-epoch loss history is kept
    in ``refit_info`` so convergence is observable.

    Refits are *recency-weighted*: each replay sample's weight decays
    exponentially with its age (``half_life`` newer samples count double
    vs samples one half-life older), so a regime change stops dominating
    the fit long before the FIFO evicts it.  A drift check runs before
    every refit: when the fitted model's accuracy over the newest
    ``drift_window`` labels falls below ``drift_threshold`` the buffer
    resets to that window and the policy drops back to the threshold
    bootstrap until enough fresh samples accumulate (the explicit
    forget-now path for bursty -> steady regime changes).
    """
    replay: ReplayBuffer = field(default_factory=ReplayBuffer)
    bootstrap: ThresholdPolicy = field(default_factory=ThresholdPolicy)
    proba_band: float = 0.10
    refit_every: int = 64
    min_samples: int = 48
    train_steps: int = 300
    space: Optional[ConfigSpace] = None
    half_life: Optional[int] = 512
    drift_window: int = 32
    drift_threshold: float = 0.35
    name: str = "online"

    def __post_init__(self):
        self._inner = PredictorPolicy(
            model=None, proba_band=self.proba_band,
            regroup_policy=self.bootstrap.regroup_policy,
            positive_means_split=True, space=self.space)
        self._decisions = 0
        self.refits = 0
        self.drift_resets = 0
        self.refit_info: List[Dict] = []
        # event stream (repro.obs); the engine that owns the run wires
        # its log in, so refits/drift-resets land in the same trace as
        # the decisions they retrain on
        self.obs = NULL_LOG

    @property
    def fitted(self) -> bool:
        return self._inner.model is not None

    def drift_detected(self) -> bool:
        """True when the model disagrees with the newest realized labels."""
        if not self.fitted or len(self.replay) < self.drift_window:
            return False
        X, y = self.replay.tail(self.drift_window)
        if len(set(y.tolist())) < 2:
            return False                    # one-class window: no signal
        # one batched predict over the whole window (the inner policy is
        # always positive_means_split, so proba IS P(split wins))
        proba = np.asarray(P.predict_proba(self._inner.model,
                                           np.asarray(X, np.float64)))
        return float(np.mean((proba > 0.5) == (y > 0.5))) \
            < self.drift_threshold

    def reset_on_drift(self) -> bool:
        """The drift-reset hook: forget the stale regime immediately.

        Keeps only the newest ``drift_window`` samples, drops the fitted
        model (back to the threshold bootstrap), and lets the normal
        refit cadence pick the fresh regime up.  Also callable by an
        outer controller that detects drift out-of-band.
        """
        self.replay.reset(keep_last=self.drift_window)
        self._inner.model = None
        self.drift_resets += 1
        return True

    def maybe_refit(self) -> bool:
        if self.drift_detected():
            self.reset_on_drift()
            if self.obs.enabled:
                self.obs.emit("refit", event="drift_reset",
                              drift_resets=self.drift_resets,
                              kept=min(len(self.replay), self.drift_window))
            return False
        buf = self.replay
        if len(buf) < self.min_samples:
            return False
        balance = buf.label_balance()
        if balance <= 0.02 or balance >= 0.98:
            return False                    # one-class buffer: nothing to fit
        X, y, w = buf.weighted_dataset(self.half_life)
        model, info = P.train_logistic(
            X, y, feature_names=SERVE_FEATURES, steps=self.train_steps,
            sample_weight=w)
        self._inner.model = model
        self.refits += 1
        self.refit_info.append({
            "n": info["n"], "train_accuracy": info["train_accuracy"],
            "final_nll": info["final_nll"],
            "loss_history_tail": [round(float(v), 5)
                                  for v in info["loss_history"][-5:]],
            "drift_resets": self.drift_resets,
        })
        if self.obs.enabled:
            self.obs.emit("refit", event="refit", refits=self.refits,
                          n=int(info["n"]),
                          train_accuracy=float(info["train_accuracy"]))
        return True

    def decide(self, fv: FeatureVector, cur: TopologyLike) -> Decision:
        self._decisions += 1
        if (not self.fitted and len(self.replay) >= self.min_samples) \
                or (self.refit_every and
                    self._decisions % self.refit_every == 0):
            self.maybe_refit()
        if self.fitted:
            d = self._inner.decide(fv, cur)
            d.reason = f"online[{self.refits} fits] {d.reason}"
            return d
        d = self.bootstrap.decide(fv, cur)
        d.reason = f"online[bootstrap] {d.reason}"
        return d


POLICY_NAMES = ("threshold", "predictor", "oracle", "online")


def make_policy(name: str, *, space: ConfigSpace,
                split_threshold: float = 0.25, fuse_threshold: float = 0.10,
                regroup_policy: str = "warp_regroup",
                model: Optional[P.LogisticModel] = None,
                model_path: Optional[str] = None,
                replay: Optional[ReplayBuffer] = None,
                proba_band: float = 0.10, oracle_margin: float = 0.02,
                refit_every: int = 64) -> ReconfigPolicy:
    """Factory mapping ``AmoebaConfig.policy`` names onto policy objects."""
    if name == "threshold":
        return ThresholdPolicy(split_threshold, fuse_threshold,
                               regroup_policy, space=space)
    if name == "predictor":
        if model is None and model_path:
            model = P.load_model(model_path)
        if model is None:
            raise ValueError("policy='predictor' needs a trained model "
                             "(AmoebaConfig.predictor_path or model=...)")
        return PredictorPolicy(model=model, proba_band=proba_band,
                               regroup_policy=regroup_policy, space=space)
    if name == "oracle":
        return OraclePolicy(space=space, margin=oracle_margin,
                            regroup_policy=regroup_policy)
    if name == "online":
        return OnlinePolicy(
            replay=replay if replay is not None else ReplayBuffer(),
            bootstrap=ThresholdPolicy(split_threshold, fuse_threshold,
                                      regroup_policy, space=space),
            proba_band=proba_band, refit_every=refit_every, space=space)
    raise ValueError(f"unknown policy {name!r}; have {POLICY_NAMES}")
