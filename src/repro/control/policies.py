"""Reconfiguration policies: one decision stack for every layer.

The paper's monitor -> predict -> reconfigure loop (§4.1, Fig 7) appears
at three levels of this reproduction — the cycle-level simulator, the
serving engine, and the trainer.  Each policy here answers the same
question at a decision point: *given the telemetry, how many ways should
this group be partitioned?*

* :class:`ThresholdPolicy` — the paper's fixed-ratio hysteresis: split
  past ``split_threshold`` when the regroup gain is positive, re-fuse
  under ``fuse_threshold`` (Fig 10/11, lifted verbatim from the old
  ``AmoebaController.observe``).
* :class:`PredictorPolicy` — §4.1.3's logistic scalability model run
  online over a feature vector ("a single MAC per feature").
* :class:`OraclePolicy` — run-both-pick-better: scores every candidate
  topology with a caller-supplied measure (the simulator's dual static
  runs, or the true slot-cost of the live batch) and takes the argmax.
* :class:`OnlinePolicy` — PredictorPolicy plus periodic refit from a
  replay buffer of (features, realized-win) labels; bootstraps from the
  threshold rule until the first fit.

Policies are *advisory*: they propose a topology; the
:class:`~repro.control.controller.GroupController` enforces dwell and the
:class:`~repro.control.space.ConfigSpace` amortization check before any
transition happens.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Tuple

import numpy as np

from repro.control.features import SERVE_FEATURES, FeatureVector, ReplayBuffer
from repro.control.space import ConfigSpace
from repro.core import predictor as P
from repro.core.regroup import regroup_gain


@dataclass
class Decision:
    """A proposed topology with the evidence behind it."""
    ways: int
    proba: float = 0.5            # P(more-split is better), when meaningful
    gain: float = 0.0             # predicted relative slot-waste saving
    reason: str = ""


# -- the shared hysteresis primitive -----------------------------------------
# Both the scalar serve/train path and the vectorized 24-pair simulator loop
# are instances of this one rule, so it lives here and nowhere else.

def hysteresis_toggle(is_split: np.ndarray, divergence: np.ndarray,
                      split_threshold: float, fuse_threshold: float,
                      want_split: np.ndarray, want_fuse: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """(split_now, fuse_now) masks under hysteresis (paper Fig 10/11).

    Split when fused, divergent past the threshold, *and* the caller's
    benefit estimate agrees; fuse when split and either calm below the
    lower threshold or the estimate says fused is better again.
    """
    is_split = np.asarray(is_split, bool)
    split_now = (~is_split) & (np.asarray(divergence) > split_threshold) \
        & np.asarray(want_split, bool)
    fuse_now = is_split & ((np.asarray(divergence) < fuse_threshold)
                           | np.asarray(want_fuse, bool))
    return split_now, fuse_now


class ReconfigPolicy(Protocol):
    """Protocol every policy implements."""
    name: str

    def decide(self, fv: FeatureVector, ways: int) -> Decision:
        """Propose a topology given telemetry and the current topology."""
        ...


# ---------------------------------------------------------------------------
# ThresholdPolicy — today's hysteresis + regroup-gain veto
# ---------------------------------------------------------------------------

@dataclass
class ThresholdPolicy:
    """Fixed-ratio hysteresis with a regroup-gain veto on splits."""
    split_threshold: float = 0.25
    fuse_threshold: float = 0.10
    regroup_policy: str = "warp_regroup"
    name: str = "threshold"

    def decide(self, fv: FeatureVector, ways: int) -> Decision:
        split_now, fuse_now = hysteresis_toggle(
            np.array(ways > 1), np.array(fv.divergence),
            self.split_threshold, self.fuse_threshold,
            want_split=np.array(True), want_fuse=np.array(False))
        if bool(split_now):
            gain = (regroup_gain(fv.remaining, self.regroup_policy)
                    if fv.remaining is not None else fv.divergence)
            if gain > 0.0:
                return Decision(ways * 2, proba=1.0, gain=gain,
                                reason=f"divergence {fv.divergence:.3f} > "
                                       f"{self.split_threshold}")
        elif bool(fuse_now):
            return Decision(ways // 2, proba=0.0, gain=0.0,
                            reason=f"divergence {fv.divergence:.3f} < "
                                   f"{self.fuse_threshold}")
        return Decision(ways, reason="hold")


# ---------------------------------------------------------------------------
# PredictorPolicy — logistic inference over live telemetry
# ---------------------------------------------------------------------------

@dataclass
class PredictorPolicy:
    """§4.1.3's binary logistic model in the loop.

    ``positive_means_split`` fixes the label convention: serve-level
    corpora label 1 = "splitting wins", while the gpusim corpus labels
    1 = "fused/scale-up wins" (the paper's convention).  ``proba_band``
    is the hysteresis band around 0.5 that rate-limits topology flapping.
    """
    model: Optional[P.LogisticModel] = None
    proba_band: float = 0.10
    regroup_policy: str = "warp_regroup"
    positive_means_split: bool = True
    space: Optional[ConfigSpace] = None
    name: str = "predictor"

    @classmethod
    def from_decider(cls, fuse_decider: Callable[[np.ndarray], bool]
                     ) -> "PredictorPolicy":
        """Wrap a bare features->fuse? callable (the gpusim interface)."""
        pol = cls(model=None, positive_means_split=False)
        pol._decider = fuse_decider
        return pol

    def proba_split(self, x: np.ndarray) -> float:
        """P(the more-split configuration wins) under the model."""
        decider = getattr(self, "_decider", None)
        if decider is not None:
            return 0.0 if bool(decider(np.asarray(x))) else 1.0
        if self.model is None:
            raise ValueError("PredictorPolicy needs a model or a decider")
        p = float(P.predict_proba(self.model, np.asarray(x, np.float64)))
        return p if self.positive_means_split else 1.0 - p

    def choose_static(self, features: np.ndarray) -> bool:
        """One-shot per-kernel choice: True = fuse (the gpusim path).

        Fusing needs a strict majority — a 0.5 tie stays scale-out, the
        paper's default configuration.
        """
        return self.proba_split(features) < 0.5

    def decide(self, fv: FeatureVector, ways: int) -> Decision:
        p = self.proba_split(fv.to_array())
        if p > 0.5 + self.proba_band / 2:
            # gain is the *true* predicted slot-waste saving so the
            # ConfigSpace amortization floor still gates a confident but
            # wrong model; model confidence only stands in when no live
            # remaining lengths exist to score (computed in this branch
            # only — hold/fuse ticks never consume it)
            if fv.remaining is None:
                gain = p - 0.5
            elif self.space is not None:
                gain = self.space.gain(fv.remaining, max(ways, 1) * 2,
                                       self.regroup_policy)
            else:
                gain = regroup_gain(fv.remaining, self.regroup_policy)
            return Decision(ways * 2, proba=p, gain=gain,
                            reason=f"P(split)={p:.3f}")
        if p < 0.5 - self.proba_band / 2 and ways > 1:
            return Decision(ways // 2, proba=p, reason=f"P(split)={p:.3f}")
        return Decision(ways, proba=p, reason="inside hysteresis band")


# ---------------------------------------------------------------------------
# OraclePolicy — run-both-pick-better
# ---------------------------------------------------------------------------

@dataclass
class OraclePolicy:
    """Score every candidate topology; move to the argmax.

    ``score(ways, fv) -> utility`` is caller-supplied: the simulator
    measures both static configurations' IPC (the label-generation path
    that used to live inside ``gpusim.sim.run_benchmark``); the serving
    engine defaults to the true relative slot-waste saving of the live
    batch.  ``margin`` is the improvement a move must show over the
    current topology's score — the oracle's hysteresis.
    """
    space: ConfigSpace = field(default_factory=lambda: ConfigSpace(2))
    score: Optional[Callable[[int, Optional[FeatureVector]], float]] = None
    margin: float = 0.02
    regroup_policy: str = "warp_regroup"
    name: str = "oracle"

    def _score(self, ways: int, fv: Optional[FeatureVector]) -> float:
        if self.score is not None:
            return float(self.score(ways, fv))
        if fv is None or fv.remaining is None:
            return 0.0
        return self.space.gain(fv.remaining, ways, self.regroup_policy)

    def choose_static(self, features=None) -> bool:
        """One-shot choice: True = fused (ways=1) scores strictly higher."""
        return self._score(1, None) > self._score(2, None)

    def decide(self, fv: FeatureVector, ways: int) -> Decision:
        scores = {w: self._score(w, fv) for w in self.space.topologies()}
        cur = scores.get(ways, 0.0)
        top = max(scores.values())
        # least-split topology whose score is within the margin of the best:
        # splitting needs a strict win, fusing back is preferred on ties
        # (it restores the wide configuration's coalescing for free)
        target = min(w for w, s in scores.items() if s >= top - self.margin)
        if target > ways and top > cur + self.margin:
            step = ways * 2
        elif target < ways:
            step = ways // 2
        else:
            return Decision(ways, gain=cur, reason="oracle: hold")
        gain = self.space.gain(fv.remaining, step, self.regroup_policy) \
            if fv.remaining is not None else abs(top - cur)
        return Decision(step, proba=1.0 if step > ways else 0.0, gain=gain,
                        reason=f"oracle: {self.space.name(target)} scores "
                               f"{scores[target]:.3f} vs {cur:.3f}")


# ---------------------------------------------------------------------------
# OnlinePolicy — predictor + periodic refit from the replay buffer
# ---------------------------------------------------------------------------

@dataclass
class OnlinePolicy:
    """Logistic inference that retrains itself from realized outcomes.

    Bootstraps from :class:`ThresholdPolicy` until the replay buffer has
    ``min_samples`` with both labels present, then fits (and every
    ``refit_every`` decisions refits) a logistic model via
    ``predictor.train_logistic`` — whose per-epoch loss history is kept
    in ``refit_info`` so convergence is observable.
    """
    replay: ReplayBuffer = field(default_factory=ReplayBuffer)
    bootstrap: ThresholdPolicy = field(default_factory=ThresholdPolicy)
    proba_band: float = 0.10
    refit_every: int = 64
    min_samples: int = 48
    train_steps: int = 300
    space: Optional[ConfigSpace] = None
    name: str = "online"

    def __post_init__(self):
        self._inner = PredictorPolicy(
            model=None, proba_band=self.proba_band,
            regroup_policy=self.bootstrap.regroup_policy,
            positive_means_split=True, space=self.space)
        self._decisions = 0
        self.refits = 0
        self.refit_info: List[Dict] = []

    @property
    def fitted(self) -> bool:
        return self._inner.model is not None

    def maybe_refit(self) -> bool:
        buf = self.replay
        if len(buf) < self.min_samples:
            return False
        balance = buf.label_balance()
        if balance <= 0.02 or balance >= 0.98:
            return False                    # one-class buffer: nothing to fit
        X, y = buf.dataset()
        model, info = P.train_logistic(
            X, y, feature_names=SERVE_FEATURES, steps=self.train_steps)
        self._inner.model = model
        self.refits += 1
        self.refit_info.append({
            "n": info["n"], "train_accuracy": info["train_accuracy"],
            "final_nll": info["final_nll"],
            "loss_history_tail": [round(float(v), 5)
                                  for v in info["loss_history"][-5:]],
        })
        return True

    def decide(self, fv: FeatureVector, ways: int) -> Decision:
        self._decisions += 1
        if (not self.fitted and len(self.replay) >= self.min_samples) \
                or (self.refit_every and
                    self._decisions % self.refit_every == 0):
            self.maybe_refit()
        if self.fitted:
            d = self._inner.decide(fv, ways)
            d.reason = f"online[{self.refits} fits] {d.reason}"
            return d
        d = self.bootstrap.decide(fv, ways)
        d.reason = f"online[bootstrap] {d.reason}"
        return d


POLICY_NAMES = ("threshold", "predictor", "oracle", "online")


def make_policy(name: str, *, space: ConfigSpace,
                split_threshold: float = 0.25, fuse_threshold: float = 0.10,
                regroup_policy: str = "warp_regroup",
                model: Optional[P.LogisticModel] = None,
                model_path: Optional[str] = None,
                replay: Optional[ReplayBuffer] = None,
                proba_band: float = 0.10, oracle_margin: float = 0.02,
                refit_every: int = 64) -> ReconfigPolicy:
    """Factory mapping ``AmoebaConfig.policy`` names onto policy objects."""
    if name == "threshold":
        return ThresholdPolicy(split_threshold, fuse_threshold,
                               regroup_policy)
    if name == "predictor":
        if model is None and model_path:
            model = P.load_model(model_path)
        if model is None:
            raise ValueError("policy='predictor' needs a trained model "
                             "(AmoebaConfig.predictor_path or model=...)")
        return PredictorPolicy(model=model, proba_band=proba_band,
                               regroup_policy=regroup_policy, space=space)
    if name == "oracle":
        return OraclePolicy(space=space, margin=oracle_margin,
                            regroup_policy=regroup_policy)
    if name == "online":
        return OnlinePolicy(
            replay=replay if replay is not None else ReplayBuffer(),
            bootstrap=ThresholdPolicy(split_threshold, fuse_threshold,
                                      regroup_policy),
            proba_band=proba_band, refit_every=refit_every, space=space)
    raise ValueError(f"unknown policy {name!r}; have {POLICY_NAMES}")
