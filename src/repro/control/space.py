"""Configuration space: the k-way generalization of fused-vs-split.

The paper's pair has two hardware states (one wide SM or two narrow
halves).  A capacity-``C`` serving group generalizes this to a ladder of
topologies ``1xC, 2x(C/2), 4x(C/4), ...`` — ``ways`` independent
partitions of ``C/ways`` decode slots each, named like the chip
configurations of Fig 12 (``1x4`` = fully fused, ``4x1`` = fully split).
Transitions climb or descend one rung at a time (a split halves every
partition, a fuse merges neighbors — the paper fuses *neighboring* SMs
only) and must pass an amortization check: the predicted slot-waste
saving has to repay the reconfiguration tick it costs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.regroup import POLICIES


def topology_name(ways: int, capacity: int) -> str:
    return f"{ways}x{max(capacity // ways, 1)}"


@dataclass(frozen=True)
class ConfigSpace:
    """Legal topologies for one capacity-``C`` group and their transitions.

    ``min_gain`` is the amortization floor: a transition is only legal
    when its predicted relative slot-waste saving exceeds it (the serving
    translation of ``fusion.amortized_switch_ok`` — a reconfiguration
    consumes one wall tick of the group's decode budget, so a move that
    saves less than ``min_gain`` of the fused cost never repays itself).
    """
    capacity: int
    max_ways: int = 2
    min_gain: float = 0.0

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")
        if self.max_ways < 1:
            raise ValueError("max_ways must be >= 1")

    # -- topology enumeration ------------------------------------------------

    def topologies(self) -> Tuple[int, ...]:
        """Power-of-two ways with at least one slot per partition."""
        out: List[int] = []
        w = 1
        while w <= self.max_ways and self.capacity // w >= 1:
            out.append(w)
            w *= 2
        return tuple(out)

    def name(self, ways: int) -> str:
        return topology_name(ways, self.capacity)

    def legal(self, ways: int) -> bool:
        return ways in self.topologies()

    def clamp(self, ways: int) -> int:
        tops = self.topologies()
        return max(w for w in tops if w <= max(ways, 1))

    def neighbors(self, ways: int) -> Tuple[int, ...]:
        """One-rung moves: fuse neighbors (ways/2) or split halves (ways*2)."""
        return tuple(w for w in (ways // 2, ways * 2) if self.legal(w))

    # -- cost model ----------------------------------------------------------

    def slot_cost(self, remaining: Sequence[float], ways: int,
                  policy: str = "warp_regroup") -> float:
        """Predicted slot-steps to drain ``remaining`` under ``ways``.

        Fused (ways=1) cost is ``C x max(remaining)`` — every slot runs
        until the longest member finishes.  A k-way partition runs each
        part for its own maximum on ``C/ways`` slots.
        """
        r = np.asarray(remaining, np.float64)
        if r.size == 0 or r.max() <= 0:
            return 0.0
        slots = max(self.capacity // ways, 1)
        parts = self.partition(list(range(r.size)), r, ways, policy)
        return float(sum(slots * r[p].max() for p in parts if len(p)))

    def gain(self, remaining: Sequence[float], ways: int,
             policy: str = "warp_regroup") -> float:
        """Relative slot-waste saving of ``ways`` vs fully fused, in [0, 1)."""
        r = np.asarray(remaining, np.float64)
        if r.size < 2 or r.max() <= 0 or ways <= 1:
            return 0.0
        fused = float(self.capacity * r.max())
        return (fused - self.slot_cost(r, ways, policy)) / fused

    def best_ways(self, remaining: Sequence[float],
                  policy: str = "warp_regroup") -> Tuple[int, float]:
        """(ways, gain) maximizing the predicted saving — the oracle's move."""
        best, best_gain = 1, 0.0
        for w in self.topologies():
            g = self.gain(remaining, w, policy)
            if g > best_gain + 1e-12:
                best, best_gain = w, g
        return best, best_gain

    # -- transitions -----------------------------------------------------------

    def transition_ok(self, cur: int, new: int, gain: float) -> bool:
        """Amortization-checked legality of a ``cur -> new`` move.

        Splitting further must predict at least ``min_gain`` of saving;
        fusing back (new < cur) is always amortized — it frees no work
        but restores the wide configuration's coalescing, and the
        hysteresis band upstream already rate-limits it.
        """
        if not (self.legal(cur) and self.legal(new)) or new == cur:
            return False
        if new not in self.neighbors(cur):
            return False
        if new > cur:
            return gain > self.min_gain
        return True

    def partition(self, indices: Sequence[int], remaining: Sequence[float],
                  ways: int, policy: str = "warp_regroup"
                  ) -> List[List[int]]:
        """Split ``indices`` into ``ways`` equal parts under ``policy``.

        ``ways=2`` reduces exactly to the paper's (fast, slow) pair from
        :mod:`repro.core.regroup`; deeper ladders recurse: each half is
        re-partitioned with the same policy, so ``warp_regroup`` yields
        contiguous sorted chunks and ``direct_split`` arrival-order chunks.
        """
        idx = list(indices)
        if ways <= 1 or len(idx) < 2:
            return [idx] + [[] for _ in range(max(ways - 1, 0))]
        r = np.asarray(remaining, np.float64)
        fast, slow = POLICIES[policy](idx, r)
        if ways == 2:
            return [fast, slow]
        sub = ways // 2
        pos = {j: k for k, j in enumerate(idx)}
        out = []
        for half in (fast, slow):
            rr = np.asarray([remaining[pos[j]] for j in half], np.float64)
            out.extend(self.partition(half, rr, sub, policy))
        return out
