"""Configuration space: integer-composition topologies of one group.

The paper's pair has two hardware states (one wide SM or two narrow
halves).  A capacity-``C`` serving group generalizes this to the full
*composition lattice*: a topology is an integer composition of ``C`` —
``(8,)`` fully fused, ``(4, 4)`` the equal pair, ``(5, 3)`` a skewed cut
that quarantines a long tail on 3 slots while 5 slots drain the short
head, down to ``(1,) * C`` fully split.  This is the paper's "dynamic
creation of heterogeneous SMs through independent fusing or splitting"
(§5, Fig 12): parts move independently — one part may split into two
children, or two *neighboring* parts may fuse — and every move is
amortization-checked on its own predicted saving.

The legacy equal-ways ladder (``1x8 -> 2x4 -> 4x2``) falls out as the
special case ``topology == (C // k,) * k``: integer ``ways`` arguments
are accepted everywhere and coerced to the balanced composition, and the
2-way pair reduces bit-for-bit to :mod:`repro.core.regroup`'s
(fast, slow) semantics.  ``hetero=False`` pins the space to exactly that
ladder (the pre-composition behavior, kept for A/B benchmarking).

``min_gain`` is the amortization floor: a split transition is only legal
when its predicted relative slot-waste saving exceeds it (the serving
translation of ``fusion.amortized_switch_ok`` — a reconfiguration
consumes one wall tick of the group's decode budget, so a move that
saves less than ``min_gain`` of the fused cost never repays itself).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.regroup import POLICIES

# a topology: one slot count per independent part, summing to capacity
Topology = Tuple[int, ...]
TopologyLike = Union[int, Topology]

# compositions() refuses to materialize lattices beyond this; callers
# (best_topology) fall back to greedy neighbor search instead
MAX_ENUMERATION = 100_000


def n_parts(t: TopologyLike) -> int:
    """Part count of an int-or-tuple topology spec."""
    return t if isinstance(t, int) else len(t)


def balanced(capacity: int, ways: int) -> Topology:
    """The most even ``ways``-part composition of ``capacity``.

    Larger parts lead (the fast head keeps the wider slice so a drained
    part frees the most backfill slots).  ``balanced(8, 2) == (4, 4)``;
    ``balanced(6, 4) == (2, 2, 1, 1)`` — note the parts always sum to
    ``capacity``, unlike the old ``capacity // ways`` pricing that
    silently dropped the remainder slots.
    """
    ways = max(min(ways, capacity), 1)
    base, extra = divmod(capacity, ways)
    return tuple([base + 1] * extra + [base] * (ways - extra))


def topology_name(t: TopologyLike, capacity: Optional[int] = None) -> str:
    """Human name: ``2x4`` for equal parts, ``5+3`` for a skewed cut.

    The legacy ``topology_name(ways, capacity)`` call shape still works
    and now names the *balanced* composition — ``topology_name(4, 6)``
    is ``2+2+1+1``, not the lossy ``4x1`` that priced only 4 of 6 slots.
    """
    if isinstance(t, int):
        if capacity is None:
            raise ValueError("int topology needs a capacity")
        t = balanced(capacity, t)
    if len(set(t)) == 1:
        return f"{len(t)}x{t[0]}"
    return "+".join(str(p) for p in t)


def _count_compositions(capacity: int, max_parts: int) -> int:
    return sum(math.comb(capacity - 1, k - 1)
               for k in range(1, min(max_parts, capacity) + 1))


@functools.lru_cache(maxsize=128)
def _enumerate_compositions(capacity: int, max_parts: int
                            ) -> Tuple[Topology, ...]:
    """All compositions of ``capacity`` into at most ``max_parts`` parts,
    ordered by part count then lexicographically (fused first)."""
    out: List[Topology] = []

    def rec(rest: int, parts: List[int], budget: int) -> None:
        if rest == 0:
            out.append(tuple(parts))
            return
        if budget == 0:
            return
        for p in range(rest, 0, -1):
            parts.append(p)
            rec(rest - p, parts, budget - 1)
            parts.pop()

    rec(capacity, [], min(max_parts, capacity))
    out.sort(key=lambda t: (len(t), tuple(-p for p in t)))
    return tuple(out)


@functools.lru_cache(maxsize=65536)
def _partition_counts(B: int, topo: Topology) -> Tuple[int, ...]:
    """Requests-per-part for ``B`` requests on ``topo``.

    Pure function of the batch size and the topology — the slice sizes
    :meth:`ConfigSpace.partition` cuts its policy ordering into
    (largest-remainder quotas plus the overshoot / min-one repairs),
    factored out and cached so candidate scoring never recomputes them.
    Mirrors ``partition()`` exactly, including its degenerate path
    (one part or fewer than two requests: everything in part 0).
    """
    k = len(topo)
    if k <= 1 or B < 2:
        return (B,) + (0,) * max(k - 1, 0)
    C = sum(topo)
    quota = [B * s / C for s in topo]
    counts = [int(q) for q in quota]
    extras = B - sum(counts)
    by_frac = sorted(range(k), key=lambda i: (quota[i] - counts[i], i),
                     reverse=True)
    for i in by_frac[:extras]:
        counts[i] += 1
    if B <= C:                          # repair any budget overshoot
        for i in range(k):
            while counts[i] > topo[i]:
                j = min((m for m in range(k) if counts[m] < topo[m]),
                        key=lambda m: (abs(m - i), m))
                counts[j] += 1
                counts[i] -= 1
    if B >= k:
        # every part hosts at least one request: an empty part would
        # price its slots at zero and fake a gain by stranding them
        for i in range(k):
            while counts[i] == 0:
                j = max(range(k), key=lambda m: (counts[m], -m))
                counts[j] -= 1
                counts[i] += 1
    return tuple(counts)


@dataclass(frozen=True)
class ConfigSpace:
    """Legal topologies for one capacity-``C`` group and their transitions.

    ``hetero=True`` (the default) admits every integer composition up to
    ``max_ways`` parts with per-part moves; ``hetero=False`` restricts
    the space to the balanced power-of-two ladder with whole-group
    split/fuse moves — exactly the pre-composition behavior.
    """
    capacity: int
    max_ways: int = 2
    min_gain: float = 0.0
    hetero: bool = True

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")
        if self.max_ways < 1:
            raise ValueError("max_ways must be >= 1")

    # -- topology coercion / enumeration --------------------------------------

    def as_topology(self, t: TopologyLike) -> Topology:
        """Coerce an integer ``ways`` to its balanced composition."""
        if isinstance(t, int):
            return balanced(self.capacity, t)
        return tuple(t)

    def topologies(self) -> Tuple[int, ...]:
        """Legacy view: power-of-two ways with at least one slot each."""
        out: List[int] = []
        w = 1
        while w <= self.max_ways and self.capacity // w >= 1:
            out.append(w)
            w *= 2
        return tuple(out)

    def compositions(self) -> Tuple[Topology, ...]:
        """Every legal topology, fused first.

        Exhaustive over the composition lattice when ``hetero``;
        the balanced ladder otherwise.  Raises for lattices past
        ``MAX_ENUMERATION`` — use :meth:`best_topology`, which falls
        back to greedy neighbor search, instead of materializing those.
        """
        if not self.hetero:
            return tuple(balanced(self.capacity, w)
                         for w in self.topologies())
        if _count_compositions(self.capacity, self.max_ways) \
                > MAX_ENUMERATION:
            raise ValueError(
                f"composition lattice of capacity={self.capacity} "
                f"max_ways={self.max_ways} is too large to enumerate; "
                f"use best_topology()'s neighbor search")
        return _enumerate_compositions(self.capacity, self.max_ways)

    def name(self, t: TopologyLike) -> str:
        return topology_name(t, self.capacity)

    def legal(self, t: TopologyLike) -> bool:
        if isinstance(t, int):
            if self.hetero:
                return 1 <= t <= min(self.max_ways, self.capacity)
            return t in self.topologies()
        if not t or len(t) > self.max_ways or any(p < 1 for p in t):
            return False
        if sum(t) != self.capacity:
            return False
        return self.hetero or (len(t) in self.topologies()
                               and t == balanced(self.capacity, len(t)))

    def clamp(self, ways: int) -> int:
        tops = self.topologies()
        return max(w for w in tops if w <= max(ways, 1))

    # -- moves -----------------------------------------------------------------

    def split_moves(self, t: TopologyLike) -> Tuple[Topology, ...]:
        """Topologies reachable by splitting: every single-part cut
        (part ``p`` -> children ``(a, p - a)``), plus the ladder move
        that halves every part at once (the legacy whole-group split)."""
        cur = self.as_topology(t)
        out: List[Topology] = []
        if self.hetero and len(cur) + 1 <= self.max_ways:
            for i, p in enumerate(cur):
                for a in range(p - 1, 0, -1):
                    out.append(cur[:i] + (a, p - a) + cur[i + 1:])
        # ladder: split every part >= 2 into near-halves simultaneously
        wide = sum(1 for p in cur if p >= 2)
        if wide and len(cur) + wide <= self.max_ways:
            lad: List[int] = []
            for p in cur:
                if p >= 2:
                    lad.extend(balanced(p, 2))
                else:
                    lad.append(p)
            out.append(tuple(lad))
        seen, uniq = set(), []
        for c in out:
            if c not in seen:
                seen.add(c)
                uniq.append(c)
        return tuple(uniq)

    def fuse_moves(self, t: TopologyLike) -> Tuple[Topology, ...]:
        """Topologies reachable by fusing: every neighboring-part merge
        (the paper fuses *neighboring* SMs only), plus the ladder move
        that merges every adjacent pair at once."""
        cur = self.as_topology(t)
        if len(cur) < 2:
            return ()
        out: List[Topology] = []
        if self.hetero:
            for i in range(len(cur) - 1):
                out.append(cur[:i] + (cur[i] + cur[i + 1],) + cur[i + 2:])
        lad = tuple(sum(cur[i:i + 2]) for i in range(0, len(cur), 2))
        out.append(lad)
        seen, uniq = set(), []
        for c in out:
            if c not in seen:
                seen.add(c)
                uniq.append(c)
        return tuple(uniq)

    def resize_moves(self, t: TopologyLike) -> Tuple[Topology, ...]:
        """Re-cut two neighboring parts without changing the part count.

        A resize is one fuse and one split of the same neighboring pair
        executed in a single reconfiguration — how a group already at
        its part budget adapts its cut as the live mix drifts (a
        ``(7, 1)`` quarantine widening to ``(5, 3)`` when more of the
        tail arrives).  Empty in ladder spaces: every unequal cut is
        off-ladder.
        """
        cur = self.as_topology(t)
        if not self.hetero or len(cur) < 2:
            return ()
        out: List[Topology] = []
        for i in range(len(cur) - 1):
            c = cur[i] + cur[i + 1]
            for a in range(c - 1, 0, -1):
                nt = cur[:i] + (a, c - a) + cur[i + 2:]
                if nt != cur and nt not in out:
                    out.append(nt)
        return tuple(out)

    def neighbors(self, t: TopologyLike) -> Tuple[Topology, ...]:
        """Single-move reachable topologies (splits, fuses, resizes)."""
        return self.split_moves(t) + self.fuse_moves(t) \
            + self.resize_moves(t)

    def touched_parts(self, cur: TopologyLike, new: TopologyLike
                      ) -> Tuple[int, ...]:
        """Indices of ``cur``'s parts a ``cur -> new`` move reconfigures.

        Untouched parts keep their dwell clocks; only the split/fused
        parts reset (per-part amortization, §5's independent moves).
        """
        c, n = self.as_topology(cur), self.as_topology(new)
        p = 0
        while p < min(len(c), len(n)) and c[p] == n[p]:
            p += 1
        q = 0
        while q < min(len(c), len(n)) - p and c[len(c) - 1 - q] == n[len(n) - 1 - q]:
            q += 1
        touched = tuple(range(p, len(c) - q))
        return touched if touched else tuple(range(len(c)))

    # -- cost model ----------------------------------------------------------

    def slot_cost(self, remaining: Sequence[float], t: TopologyLike,
                  policy: str = "warp_regroup") -> float:
        """Predicted slot-steps to drain ``remaining`` under topology ``t``.

        Each part runs its own slot count until its longest member
        finishes; fused ``(C,)`` cost is ``C x max(remaining)``.  Parts
        always price their full slot budget (the old equal-ways pricing
        charged ``C // ways`` per part, silently dropping the remainder
        slots of non-power-of-two capacities and inflating the gain).
        """
        r = np.asarray(remaining, np.float64)
        if r.size == 0 or r.max() <= 0:
            return 0.0
        topo = self.as_topology(t)
        parts = self.partition(list(range(r.size)), r, topo, policy)
        return float(sum(s * r[p].max()
                         for s, p in zip(topo, parts) if len(p)))

    def _policy_order(self, r: np.ndarray, policy: str) -> np.ndarray:
        """``r`` permuted into the policy's full (fast + slow) ordering.

        The key to fast candidate scoring: :meth:`partition`'s ordering
        is a pure function of ``remaining`` and the policy — it never
        depends on the candidate topology — so the sort happens *once*
        and every candidate is priced against the same ordered array.
        """
        fast, slow = POLICIES[policy](list(range(r.size)), r)
        return r[np.asarray(fast + slow, np.int64)]

    def _ordered_cost(self, r_ord: np.ndarray, t: TopologyLike) -> float:
        """:meth:`slot_cost` from a pre-ordered ``remaining`` array.

        Replaces the O(parts x capacity) per-candidate scan (re-sort,
        re-partition, fancy-index every part) with cached per-part
        counts (:func:`_partition_counts`) and one ``maximum.reduceat``
        over the contiguous chunks.  Bit-identical to ``slot_cost``:
        the chunks are the same members in the same order, ``max`` /
        ``reduceat`` pick an element (no arithmetic), and the ``sum``
        accumulates the same np.float64 terms in the same order.
        """
        if r_ord.size == 0 or r_ord.max() <= 0:
            return 0.0
        topo = self.as_topology(t)
        counts = _partition_counts(r_ord.size, topo)
        if 0 not in counts:                 # the common case: B >= parts
            starts, pos = [], 0
            for c in counts:
                starts.append(pos)
                pos += c
            maxes = np.maximum.reduceat(r_ord, starts)
            return float(sum(s * m for s, m in zip(topo, maxes)))
        chunks, pos = [], 0
        for s, c in zip(topo, counts):
            if c:
                chunks.append(s * r_ord[pos:pos + c].max())
            pos += c
        return float(sum(chunks))

    def gain(self, remaining: Sequence[float], t: TopologyLike,
             policy: str = "warp_regroup") -> float:
        """Relative slot-waste saving of ``t`` vs fully fused, in [0, 1).

        Topologies with more parts than live requests score zero: their
        inevitably empty parts would price their slots at nothing and
        report a phantom saving from stranding them.
        """
        r = np.asarray(remaining, np.float64)
        if r.size < 2 or r.max() <= 0 or n_parts(t) <= 1:
            return 0.0
        if len(self.as_topology(t)) > r.size:
            return 0.0
        fused = float(self.capacity * r.max())
        return (fused - self.slot_cost(r, t, policy)) / fused

    def move_gain(self, remaining: Sequence[float], cur: TopologyLike,
                  new: TopologyLike, policy: str = "warp_regroup") -> float:
        """Predicted saving of the single move ``cur -> new``, normalized
        by the fused cost so it shares the scale of :meth:`gain` (and of
        ``min_gain``) — the quantity each per-part move must amortize.

        A move into a topology with more parts than live requests never
        amortizes: its saving would come from empty parts pricing their
        slots at zero (the same stranding guard as :meth:`gain`).
        """
        r = np.asarray(remaining, np.float64)
        if r.size < 2 or r.max() <= 0:
            return 0.0
        if len(self.as_topology(new)) > r.size:
            return 0.0
        fused = float(self.capacity * r.max())
        return (self.slot_cost(r, cur, policy)
                - self.slot_cost(r, new, policy)) / fused

    def best_ways(self, remaining: Sequence[float],
                  policy: str = "warp_regroup") -> Tuple[int, float]:
        """(ways, gain) over the balanced ladder — the legacy oracle."""
        r = np.asarray(remaining, np.float64)
        best, best_gain = 1, 0.0
        for w in self.topologies():
            if w > r.size:                  # would strand empty parts
                continue
            g = self.gain(r, w, policy)
            if g > best_gain + 1e-12:
                best, best_gain = w, g
        return best, best_gain

    def best_topology(self, remaining: Sequence[float],
                      policy: str = "warp_regroup"
                      ) -> Tuple[Topology, float]:
        """(topology, gain) maximizing the predicted saving.

        Exhaustive over :meth:`compositions` when the lattice is small
        enough to enumerate; greedy best-neighbor ascent from fused
        otherwise (each step is a legal single move, so the returned
        topology is always reachable).  Ties prefer fewer parts.
        """
        fused = (self.capacity,)
        r = np.asarray(remaining, np.float64)
        if r.size < 2 or r.max() <= 0:
            return fused, 0.0
        if not self.hetero or _count_compositions(
                self.capacity, self.max_ways) <= MAX_ENUMERATION:
            best, best_gain = fused, 0.0
            for t in self.compositions():
                if len(t) > r.size:         # would strand empty parts
                    continue
                g = self.gain(r, t, policy)
                if g > best_gain + 1e-12:
                    best, best_gain = t, g
            return best, best_gain
        cur, cur_gain = fused, 0.0
        for _ in range(self.capacity):        # lattice depth bound
            step, step_gain = None, cur_gain
            for nb in self.neighbors(cur):
                g = self.gain(r, nb, policy)
                if g > step_gain + 1e-12:
                    step, step_gain = nb, g
            if step is None:
                break
            cur, cur_gain = step, step_gain
        return cur, cur_gain

    def suggest_split(self, cur: TopologyLike,
                      remaining: Optional[Sequence[float]] = None,
                      policy: str = "warp_regroup",
                      max_parts: Optional[int] = None
                      ) -> Optional[Topology]:
        """The best single split move from ``cur`` (skew-aware).

        With live ``remaining`` lengths the move minimizing predicted
        slot cost wins — on a skewed tail that is an unequal cut like
        ``(5, 3)``, not the balanced halving.  Without telemetry the
        ladder move (or the halving of the widest part) stands in.
        """
        cands = [t for t in self.split_moves(cur)
                 if max_parts is None or len(t) <= max_parts]
        if not cands:
            return None
        r = None if remaining is None \
            else np.asarray(remaining, np.float64)
        if r is None or r.size < 2 or r.max() <= 0:
            c = self.as_topology(cur)
            lad = [t for t in cands if len(t) > len(c) + 1]
            if lad:
                return lad[0]
            i = max(range(len(c)), key=lambda j: c[j])
            even = c[:i] + balanced(c[i], 2) + c[i + 1:]
            return even if even in cands else cands[0]
        cands = [t for t in cands if len(t) <= r.size] or None
        if cands is None:
            return None                     # every cut would strand a part
        r_ord = self._policy_order(r, policy)
        return min(cands, key=lambda t: (self._ordered_cost(r_ord, t),
                                         len(t), t))

    def suggest_improve(self, cur: TopologyLike,
                        remaining: Optional[Sequence[float]] = None,
                        policy: str = "warp_regroup",
                        max_parts: Optional[int] = None
                        ) -> Optional[Topology]:
        """The best cost-reducing split *or* resize move from ``cur``.

        From fused this is exactly :meth:`suggest_split`; from a split
        topology it also considers re-cutting neighboring parts, so a
        group whose quarantine slice went stale (new tail arrivals
        landed in the wide part) re-shapes instead of holding a wrong
        cut.  Returns None when no move strictly improves the predicted
        slot cost.
        """
        if remaining is None:
            return self.suggest_split(cur, None, policy, max_parts)
        r = np.asarray(remaining, np.float64)
        if r.size < 2 or r.max() <= 0:
            return self.suggest_split(cur, None, policy, max_parts)
        c = self.as_topology(cur)
        # candidates are capped at the live request count — a cut with
        # more parts than requests strands empty slots priced at zero
        # and its "gain" is phantom (see gain()/move_gain())
        cands = [t for t in self.split_moves(c) + self.resize_moves(c)
                 if (max_parts is None or len(t) <= max_parts)
                 and len(t) <= r.size]
        if not cands:
            return None
        r_ord = self._policy_order(r, policy)
        best = min(cands, key=lambda t: (self._ordered_cost(r_ord, t),
                                         len(t), t))
        if self._ordered_cost(r_ord, best) \
                < self._ordered_cost(r_ord, c) - 1e-12:
            return best
        return None

    def suggest_fuse(self, cur: TopologyLike,
                     remaining: Optional[Sequence[float]] = None,
                     policy: str = "warp_regroup") -> Optional[Topology]:
        """The least-costly single fuse move from ``cur``.

        Fusing usually *adds* predicted slot cost (it trades waste for
        the wide configuration's coalescing), so the argmin is the merge
        that gives up the least.  Without telemetry the ladder merge
        stands in.
        """
        c = self.as_topology(cur)
        cands = self.fuse_moves(c)
        if not cands:
            return None
        r = None if remaining is None \
            else np.asarray(remaining, np.float64)
        if r is None or r.size < 2 or r.max() <= 0:
            lad = tuple(sum(c[i:i + 2]) for i in range(0, len(c), 2))
            return lad if lad in cands else cands[0]
        r_ord = self._policy_order(r, policy)
        return min(cands, key=lambda t: (self._ordered_cost(r_ord, t),
                                         len(t), t))

    # -- transitions -----------------------------------------------------------

    def transition_ok(self, cur: TopologyLike, new: TopologyLike,
                      gain: float) -> bool:
        """Amortization-checked legality of a single ``cur -> new`` move.

        ``new`` must be one move away (a single part split, a single
        neighboring fuse or re-cut, or the whole-group ladder move).
        Splitting further or re-cutting must predict at least
        ``min_gain`` of saving; fusing back is always amortized — it
        frees no work but restores the wide configuration's coalescing,
        and the hysteresis band upstream already rate-limits it.
        """
        c, n = self.as_topology(cur), self.as_topology(new)
        if not (self.legal(c) and self.legal(n)) or n == c:
            return False
        if n in self.split_moves(c) or n in self.resize_moves(c):
            return gain > self.min_gain
        return n in self.fuse_moves(c)

    def partition(self, indices: Sequence[int], remaining: Sequence[float],
                  t: TopologyLike, policy: str = "warp_regroup"
                  ) -> List[List[int]]:
        """Assign ``indices`` to the parts of ``t``, sized to slot budgets.

        Requests are ordered by ``policy`` (``warp_regroup`` sorts by
        remaining work, ``direct_split`` keeps arrival order) and cut
        into contiguous chunks whose sizes follow each part's share of
        the slot budget (largest-remainder rounding, ties to the later
        part) — so part ``i`` never exceeds ``t[i]`` requests as long as
        the batch fits the group.  The equal pair ``(C/2, C/2)`` reduces
        bit-for-bit to the paper's (fast, slow) split from
        :mod:`repro.core.regroup`.
        """
        topo = self.as_topology(t)
        k = len(topo)
        idx = list(indices)
        if k <= 1 or len(idx) < 2:
            return [idx] + [[] for _ in range(max(k - 1, 0))]
        r = np.asarray(remaining, np.float64)
        fast, slow = POLICIES[policy](idx, r)
        order = fast + slow                 # full policy ordering
        out, pos = [], 0
        for c in _partition_counts(len(idx), topo):
            out.append(order[pos:pos + c])
            pos += c
        return out
