"""Group- and chip-level reconfiguration controllers.

:class:`GroupController` is the single split/fuse state machine in the
codebase: it owns a topology (an integer composition of the group's
capacity), enforces the *per-part* dwell clocks that amortize
reconfiguration cost — a part that just reconfigured blocks its own next
move without freezing its siblings, the paper's independent
neighboring-SM moves — asks its
:class:`~repro.control.policies.ReconfigPolicy` for a proposal each
decision tick, and applies the
:class:`~repro.control.space.ConfigSpace` amortization check before any
transition.  Every consumer — the ``AmoebaController`` façade, the
serving ``ReconfigurableGroup``, the trainer's straggler monitor — drives
this one object.

:class:`FleetController` is the paper's chip-wide view: 24 SM pairs each
reconfigure independently, but the *mix* of fused and split pairs is a
chip property.  It watches the fleet's long-request fraction and nudges
individual group controllers (through the same dwell-checked transition
path) so the number of split groups — and, under sustained tail mass,
how deeply the divergent ones are split — tracks the load.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.control.features import FeatureVector, ReplayBuffer
from repro.control.policies import Decision, ReconfigPolicy, ThresholdPolicy
from repro.control.space import ConfigSpace, Topology, TopologyLike, n_parts
from repro.obs.events import NULL_LOG, EventLog


@dataclass
class ControlState:
    """The one copy of a group's reconfiguration state."""
    topology: Topology = (1,)
    steps_in_state: int = 0
    step: int = 0
    # ticks since each part was last reconfigured — the per-part dwell
    # clocks (aligned with ``topology``)
    part_ages: List[int] = field(default_factory=lambda: [0])
    # (step, ways, divergence) per observe call — Fig 19's timeline
    history: List[Tuple[int, int, float]] = field(default_factory=list)
    # (step, from_topology, to_topology, gain, reason) per applied move
    transitions: List[Tuple[int, Topology, Topology, float, str]] = \
        field(default_factory=list)

    @property
    def ways(self) -> int:
        return len(self.topology)

    @property
    def split(self) -> bool:
        return len(self.topology) > 1


class GroupController:
    """Per-part dwell + policy + amortization check for one group."""

    def __init__(self, policy: Optional[ReconfigPolicy] = None,
                 space: Optional[ConfigSpace] = None,
                 dwell: int = 8,
                 replay: Optional[ReplayBuffer] = None,
                 label_margin: float = 0.02,
                 regroup_policy: str = "warp_regroup",
                 obs: Optional[EventLog] = None,
                 gid: int = -1):
        self.policy = policy or ThresholdPolicy()
        self.space = space or ConfigSpace(capacity=2, max_ways=2)
        self.dwell = dwell
        self.replay = replay
        self.label_margin = label_margin
        self.regroup_policy = regroup_policy
        self.obs = obs if obs is not None else NULL_LOG
        self.gid = gid
        self.state = ControlState(topology=(self.space.capacity,))
        self._hint: Optional[TopologyLike] = None

    # -- fleet-level override ------------------------------------------------

    def request_topology(self, t: TopologyLike) -> None:
        """Chip-level hint: move toward ``t`` when dwell next allows.

        ``t`` may be a part count (the fleet's usual nudge) or an exact
        composition (e.g. the ``(C-1, 1)`` quarantine reservation).  The
        hint flows through the same transition path as policy proposals
        (one move per decision tick, dwell-checked), so a fleet rebalance
        can never bypass the group's own pacing.  An exact-composition
        hint retires only when the group holds *exactly* that topology;
        a part-count hint retires on reaching the count.
        """
        self._hint = t if self.space.legal(t) else None

    def _hint_reached(self) -> bool:
        if self._hint is None:
            return False
        if isinstance(self._hint, int):
            return self.state.ways == self._hint
        return self.state.topology == self.space.as_topology(self._hint)

    def _hint_exact(self, target: Topology) -> bool:
        """Is ``target`` the exact composition a fleet hint asked for?"""
        return (self._hint is not None
                and not isinstance(self._hint, int)
                and target == self.space.as_topology(self._hint))

    # -- the decision tick ----------------------------------------------------

    def _log_label(self, fv: FeatureVector
                   ) -> Optional[Tuple[int, float, float]]:
        """Log one (features, realized-win) sample; returns the sample's
        (absolute replay index, realized gain, label) for the decision
        audit, or None when no label was logged."""
        if self.replay is None or fv.remaining is None \
                or fv.remaining.size < 2:
            return None
        # the lattice argmax scores up to ~hundred candidate partitions of
        # a <=capacity batch — microseconds against the jitted decode step
        # each tick pays for, and only paid when a replay buffer is wired
        _, gain = self.space.best_topology(fv.remaining, self.regroup_policy)
        label = 1.0 if gain > self.label_margin else 0.0
        idx = self.replay.add(fv.to_array(), label)
        return idx, float(gain), label

    def observe(self, fv: FeatureVector, max_ways_now: Optional[int] = None
                ) -> int:
        """Feed one decision tick's telemetry; returns the current ways.

        ``max_ways_now`` caps how far the group may split *right now*
        (e.g. a single-request batch cannot be partitioned) without
        touching the configured space.  The applied composition is read
        from ``state.topology``.
        """
        st = self.state
        st.step += 1
        st.steps_in_state += 1
        for i in range(len(st.part_ages)):
            st.part_ages[i] += 1
        label_info = self._log_label(fv)
        # no part has dwelt long enough for *any* move to touch it
        if max(st.part_ages) < self.dwell:
            st.history.append((st.step, st.ways, fv.divergence))
            return st.ways

        d = self._proposal(fv)
        target = self._resolve(d, fv, max_ways_now)
        cur = st.topology
        applied = False
        gain = d.gain
        if target is not None:
            gain = d.gain if d.topology == target else self._move_gain(
                fv, st.topology, target, d.gain)
            touched = self.space.touched_parts(st.topology, target)
            ok = self.space.transition_ok(st.topology, target, gain)
            if not ok and self._hint_exact(target):
                # a reservation's value (tenant isolation) lies outside
                # the slot-cost model, so an exact fleet hint skips the
                # min-gain floor — but must still be a legal single move
                # and (below) clear every touched part's dwell clock
                ok = target in self.space.neighbors(st.topology)
            if ok and all(st.part_ages[i] >= self.dwell for i in touched):
                st.transitions.append((st.step, st.topology, target, gain,
                                       d.reason))
                st.part_ages = self._rebuild_ages(st.topology, target,
                                                  st.part_ages)
                st.topology = target
                st.steps_in_state = 0
                applied = True
        if self.obs.enabled:
            payload = {"from": cur, "target": target, "applied": applied,
                       "proba": float(d.proba), "gain": float(gain),
                       "reason": d.reason, "features": fv.to_array(),
                       "step": st.step}
            if label_info is not None:
                payload["replay_idx"], payload["label_gain"], \
                    payload["label"] = label_info
            self.obs.emit("policy_decision", gid=self.gid, **payload)
        # a fleet hint survives rejected attempts (capped by a momentary
        # max_ways_now or an under-floor gain) and retires only once the
        # group actually reaches the requested topology
        if self._hint_reached():
            self._hint = None
        st.history.append((st.step, st.ways, fv.divergence))
        return st.ways

    def _move_gain(self, fv: FeatureVector, cur: Topology, new: Topology,
                   fallback: float) -> float:
        if fv.remaining is None:
            return fallback
        return self.space.move_gain(fv.remaining, cur, new,
                                    self.regroup_policy)

    def _rebuild_ages(self, cur: Topology, new: Topology,
                      ages: List[int]) -> List[int]:
        """Carry untouched parts' dwell clocks across a move."""
        touched = self.space.touched_parts(cur, new)
        p = touched[0]
        q = len(cur) - (touched[-1] + 1)
        fresh = [0] * (len(new) - p - q)
        return list(ages[:p]) + fresh + list(ages[len(cur) - q:])

    def _resolve(self, d: Decision, fv: FeatureVector,
                 max_ways_now: Optional[int]) -> Optional[Topology]:
        """Materialize a Decision into one legal topology move (or None)."""
        cur = self.state.topology
        t = d.topology
        if t is not None and (not self.space.legal(t) or t == cur):
            t = None
        if t is None:
            k = d.ways
            if k == len(cur):
                return None
            if k > len(cur):
                t = self.space.suggest_split(cur, fv.remaining,
                                             self.regroup_policy)
            else:
                t = self.space.suggest_fuse(cur, fv.remaining,
                                            self.regroup_policy)
        if t is None or t == cur:
            return None
        if max_ways_now is not None and len(t) > len(cur):
            limit = max(max_ways_now, len(cur))
            if len(t) > limit:
                t = self.space.suggest_split(
                    cur, fv.remaining, self.regroup_policy,
                    max_parts=limit) if len(cur) < limit else None
        return None if t == cur else t

    def _proposal(self, fv: FeatureVector) -> Decision:
        if self._hint is not None and not self._hint_reached():
            cur = self.state.topology
            if not isinstance(self._hint, int):
                want_t = self.space.as_topology(self._hint)
                if want_t in self.space.neighbors(cur):
                    gain = self._move_gain(fv, cur, want_t, fv.divergence)
                    return Decision(len(want_t), topology=want_t, gain=gain,
                                    reason="fleet rebalance")
                # not single-move reachable yet: fall through to the
                # part-count nudge and converge over later ticks
                if len(want_t) == len(cur):
                    # same part count but a different cut, and no single
                    # re-cut reaches it — let the policy act this tick
                    return self.policy.decide(fv, self.state.topology)
            want = n_parts(self._hint)
            if want > len(cur):
                t = self.space.suggest_split(cur, fv.remaining,
                                             self.regroup_policy)
            else:
                t = self.space.suggest_fuse(cur, fv.remaining,
                                            self.regroup_policy)
            if t is not None:
                gain = self._move_gain(fv, cur, t, fv.divergence)
                return Decision(len(t), topology=t, gain=gain,
                                reason="fleet rebalance")
        return self.policy.decide(fv, self.state.topology)

    def reset(self) -> None:
        self.state = ControlState(topology=(self.space.capacity,))
        self._hint = None


class FleetController:
    """Chip-wide heterogeneity management across N group controllers.

    The target number of split groups tracks the fraction of outstanding
    *long* work (live + queued requests past ``long_threshold`` tokens),
    re-evaluated every ``every`` wall ticks.  Groups are nudged — never
    forced — via :meth:`GroupController.request_topology`; the per-group
    dwell and amortization check still gate the actual move.  Because
    groups hold heterogeneous compositions, the rebalance also *deepens*
    the split mix: when every group the tail mass calls for is already
    split but the long fraction stays past ``deepen_threshold``, the
    most divergent split group is nudged one part further.
    """

    def __init__(self, long_threshold: int = 24, every: int = 16,
                 min_split: int = 0, max_split: Optional[int] = None,
                 deepen_threshold: float = 0.5,
                 planner=None, quarantine: Optional[int] = None,
                 mix: bool = True, leases=None):
        self.long_threshold = long_threshold
        self.every = max(every, 1)
        self.min_split = min_split
        self.max_split = max_split
        self.deepen_threshold = deepen_threshold
        # optional repro.fleet.migrate.MigrationPlanner: plans gathered
        # on the rebalance tick, executed by the engine between ticks
        self.planner = planner
        # optional repro.fleet.lease.LeasePlanner: slot leases granted /
        # revoked on the same gate, after steals claimed the free slots
        self.leases = leases
        # group index holding the reserved (C-1, 1) quarantine slice
        self.quarantine = quarantine
        # False = skip split-mix nudging (migration/quarantine only)
        self.mix = mix
        self.rebalances = 0
        self._plans: list = []

    # -- quarantine reservation ------------------------------------------------

    def reserved_parts(self, groups: Sequence) -> set:
        """Live ``(group, part)`` reservations — steal-ineligible."""
        out = set()
        q = self.quarantine
        if q is not None and 0 <= q < len(groups):
            topo = groups[q].controller.state.topology
            if len(topo) >= 2 and topo[-1] == 1:
                out.add((q, len(topo) - 1))
        return out

    def _maintain_quarantine(self, groups: Sequence) -> int:
        """Re-assert the exact-composition reservation when it drifted."""
        g = groups[self.quarantine]
        topo = g.controller.state.topology
        cap = g.controller.space.capacity
        want = (cap - 1, 1)
        if cap < 2 or (len(topo) >= 2 and topo[-1] == 1):
            return 0
        if not g.controller.space.legal(want):
            return 0
        g.controller.request_topology(want)
        return 1

    def take_plans(self) -> list:
        """Hand the engine this tick's migration plans (drains them)."""
        plans, self._plans = self._plans, []
        return plans

    def desired_split_groups(self, long_frac: float, n_groups: int) -> int:
        # round up: any long-tail mass deserves at least one split group
        want = int(math.ceil(long_frac * n_groups - 1e-9)) \
            if long_frac > 0 else 0
        hi = self.max_split if self.max_split is not None else n_groups
        return max(self.min_split, min(want, hi))

    @staticmethod
    def _divergence(g) -> float:
        rem = np.asarray([r.remaining for r in g.live_requests()],
                         np.float64)
        return 0.0 if rem.size == 0 or rem.max() <= 0 \
            else 1.0 - rem.mean() / rem.max()

    def rebalance(self, tick: int, groups: Sequence) -> int:
        """One chip-level control tick; returns hints issued this call.

        Re-asserts the quarantine reservation, nudges the split mix
        (unless ``mix`` is off), and — when a migration planner is
        wired — gathers this tick's steal/migration plans for the
        engine to pick up via :meth:`take_plans`.  ``groups`` are
        serving groups exposing ``controller`` (a
        :class:`GroupController`), ``live_requests()``, ``queue`` and
        ``load()`` — the :class:`repro.serve.engine.ReconfigurableGroup`
        surface.
        """
        if tick % self.every != 0:
            return 0
        issued = 0
        if self.quarantine is not None \
                and 0 <= self.quarantine < len(groups):
            issued += self._maintain_quarantine(groups)
        issued += self._rebalance_mix(groups) if self.mix else 0
        if self.planner is not None:
            self._plans = self.planner.plan(
                tick, groups, reserved=self.reserved_parts(groups))
        if self.leases is not None:
            self.leases.step(tick, groups,
                             reserved=self.reserved_parts(groups))
        self.rebalances += issued > 0
        return issued

    def _rebalance_mix(self, groups: Sequence) -> int:
        total, long_n = 0, 0
        for g in groups:
            for r in g.live_requests():
                total += 1
                long_n += r.remaining >= self.long_threshold
            for r in g.queue:
                total += 1
                long_n += r.max_new_tokens >= self.long_threshold
        if total == 0:
            return 0
        long_frac = long_n / total
        # the quarantine group's composition is reserved — mix nudges
        # must not fight the standing exact-composition hint
        pool = [g for i, g in enumerate(groups) if i != self.quarantine]
        if not pool:
            return 0
        want = self.desired_split_groups(long_frac, len(pool))
        split = [g for g in pool if g.controller.state.split]
        fused = [g for g in pool if not g.controller.state.split]
        issued = 0
        if len(split) < want:
            # split the most divergent fused groups first
            for g in sorted(fused, key=self._divergence,
                            reverse=True)[:want - len(split)]:
                g.controller.request_topology(2)
                issued += 1
        elif len(split) > want:
            # fuse the least-loaded split groups back
            for g in sorted(split, key=lambda g: g.load())[:len(split) - want]:
                g.controller.request_topology(1)
                issued += 1
        elif split and long_frac > self.deepen_threshold:
            # the split mix is right-sized but the tail mass persists:
            # push the most divergent split group one part deeper
            # (ladder spaces only admit power-of-two counts, so fall
            # back to the next rung when +1 is not legal)
            g = max(split, key=self._divergence)
            ways = g.controller.state.ways
            for deeper in (ways + 1, ways * 2):
                if g.controller.space.legal(deeper):
                    g.controller.request_topology(deeper)
                    issued += 1
                    break
        return issued
