"""Group- and chip-level reconfiguration controllers.

:class:`GroupController` is the single split/fuse state machine in the
codebase: it owns a topology (``ways``), enforces the dwell that
amortizes reconfiguration cost, asks its
:class:`~repro.control.policies.ReconfigPolicy` for a proposal each
decision tick, and applies the
:class:`~repro.control.space.ConfigSpace` amortization check before any
transition.  Every consumer — the ``AmoebaController`` façade, the
serving ``ReconfigurableGroup``, the trainer's straggler monitor — drives
this one object.

:class:`FleetController` is the paper's chip-wide view: 24 SM pairs each
reconfigure independently, but the *mix* of fused and split pairs is a
chip property.  It watches the fleet's long-request fraction and nudges
individual group controllers (through the same dwell-checked transition
path) so the number of split groups tracks the tail mass of the load.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.control.features import FeatureVector, ReplayBuffer
from repro.control.policies import Decision, ReconfigPolicy, ThresholdPolicy
from repro.control.space import ConfigSpace


@dataclass
class ControlState:
    """The one copy of a group's reconfiguration state."""
    ways: int = 1
    steps_in_state: int = 0
    step: int = 0
    # (step, ways, divergence) per observe call — Fig 19's timeline
    history: List[Tuple[int, int, float]] = field(default_factory=list)
    # (step, from_ways, to_ways, gain, reason) per applied transition
    transitions: List[Tuple[int, int, int, float, str]] = \
        field(default_factory=list)

    @property
    def split(self) -> bool:
        return self.ways > 1


class GroupController:
    """Dwell + policy + amortization check for one reconfigurable group."""

    def __init__(self, policy: Optional[ReconfigPolicy] = None,
                 space: Optional[ConfigSpace] = None,
                 dwell: int = 8,
                 replay: Optional[ReplayBuffer] = None,
                 label_margin: float = 0.02,
                 regroup_policy: str = "warp_regroup"):
        self.policy = policy or ThresholdPolicy()
        self.space = space or ConfigSpace(capacity=2, max_ways=2)
        self.dwell = dwell
        self.replay = replay
        self.label_margin = label_margin
        self.regroup_policy = regroup_policy
        self.state = ControlState()
        self._hint: Optional[int] = None

    # -- fleet-level override ------------------------------------------------

    def request_topology(self, ways: int) -> None:
        """Chip-level hint: move toward ``ways`` when dwell next allows.

        The hint flows through the same transition path as policy
        proposals (one rung per decision tick, amortization-checked), so
        a fleet rebalance can never bypass the group's own safeguards.
        """
        self._hint = ways if self.space.legal(ways) else None

    # -- the decision tick ----------------------------------------------------

    def _log_label(self, fv: FeatureVector) -> None:
        if self.replay is None or fv.remaining is None \
                or fv.remaining.size < 2:
            return
        _, gain = self.space.best_ways(fv.remaining, self.regroup_policy)
        self.replay.add(fv.to_array(), 1.0 if gain > self.label_margin
                        else 0.0)

    def observe(self, fv: FeatureVector, max_ways_now: Optional[int] = None
                ) -> int:
        """Feed one decision tick's telemetry; returns the target topology.

        ``max_ways_now`` caps how far the group may split *right now*
        (e.g. a single-request batch cannot be partitioned) without
        touching the configured space.
        """
        st = self.state
        st.step += 1
        st.steps_in_state += 1
        self._log_label(fv)
        if st.steps_in_state < self.dwell:
            st.history.append((st.step, st.ways, fv.divergence))
            return st.ways

        d = self._proposal(fv)
        target = d.ways
        if max_ways_now is not None and target > st.ways:
            target = min(target, max(max_ways_now, st.ways))
        if target != st.ways and \
                self.space.transition_ok(st.ways, target, d.gain):
            st.transitions.append((st.step, st.ways, target, d.gain,
                                   d.reason))
            st.ways = target
            st.steps_in_state = 0
        # a fleet hint survives rejected attempts (capped by a momentary
        # max_ways_now or an under-floor gain) and retires only once the
        # group actually reaches the requested topology
        if self._hint is not None and st.ways == self._hint:
            self._hint = None
        st.history.append((st.step, st.ways, fv.divergence))
        return st.ways

    def _proposal(self, fv: FeatureVector) -> Decision:
        if self._hint is not None and self._hint != self.state.ways:
            step = self.state.ways * 2 if self._hint > self.state.ways \
                else self.state.ways // 2
            gain = self.space.gain(fv.remaining, step,
                                   self.regroup_policy) \
                if fv.remaining is not None else fv.divergence
            return Decision(step, gain=gain, reason="fleet rebalance")
        return self.policy.decide(fv, self.state.ways)

    def reset(self) -> None:
        self.state = ControlState()
        self._hint = None


class FleetController:
    """Chip-wide heterogeneity management across N group controllers.

    The target number of split groups tracks the fraction of outstanding
    *long* work (live + queued requests past ``long_threshold`` tokens),
    re-evaluated every ``every`` wall ticks.  Groups are nudged — never
    forced — via :meth:`GroupController.request_topology`; the per-group
    dwell and amortization check still gate the actual move.
    """

    def __init__(self, long_threshold: int = 24, every: int = 16,
                 min_split: int = 0, max_split: Optional[int] = None):
        self.long_threshold = long_threshold
        self.every = max(every, 1)
        self.min_split = min_split
        self.max_split = max_split
        self.rebalances = 0

    def desired_split_groups(self, long_frac: float, n_groups: int) -> int:
        # round up: any long-tail mass deserves at least one split group
        want = int(math.ceil(long_frac * n_groups - 1e-9)) \
            if long_frac > 0 else 0
        hi = self.max_split if self.max_split is not None else n_groups
        return max(self.min_split, min(want, hi))

    def rebalance(self, tick: int, groups: Sequence) -> int:
        """Nudge the fleet's split mix; returns hints issued this call.

        ``groups`` are serving groups exposing ``controller``
        (a :class:`GroupController`), ``live_requests()``, ``queue`` and
        ``load()`` — the :class:`repro.serve.engine.ReconfigurableGroup`
        surface.
        """
        if tick % self.every != 0:
            return 0
        total, long_n = 0, 0
        for g in groups:
            for r in g.live_requests():
                total += 1
                long_n += r.remaining >= self.long_threshold
            for r in g.queue:
                total += 1
                long_n += r.max_new_tokens >= self.long_threshold
        if total == 0:
            return 0
        want = self.desired_split_groups(long_n / total, len(groups))
        split = [g for g in groups if g.controller.state.split]
        fused = [g for g in groups if not g.controller.state.split]
        issued = 0
        if len(split) < want:
            # split the most divergent fused groups first
            def div(g):
                rem = np.asarray([r.remaining for r in g.live_requests()],
                                 np.float64)
                return 0.0 if rem.size == 0 or rem.max() <= 0 \
                    else 1.0 - rem.mean() / rem.max()
            for g in sorted(fused, key=div, reverse=True)[:want - len(split)]:
                g.controller.request_topology(2)
                issued += 1
        elif len(split) > want:
            # fuse the least-loaded split groups back
            for g in sorted(split, key=lambda g: g.load())[:len(split) - want]:
                g.controller.request_topology(1)
                issued += 1
        self.rebalances += issued > 0
        return issued
