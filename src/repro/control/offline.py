"""Offline corpus for the serve-level scalability predictor.

The paper trains its logistic model on "a large amount of offline
experimental data" from the simulator (``repro.core.gpusim.corpus`` keeps
that path).  The serving analogue generates decision scenarios — batches
with bimodal / lognormal / near-lockstep remaining-length profiles under
varying queue pressure — and labels each with the realized win: does the
best k-way partition of this batch save more slot-steps than the
reconfiguration margin?  The features are exactly the live-telemetry
:class:`~repro.control.features.FeatureVector`, so a model trained here
drops straight into :class:`~repro.control.policies.PredictorPolicy`.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.control.features import SERVE_FEATURES, FeatureVector
from repro.control.space import ConfigSpace
from repro.core import predictor as P


def _sample_remaining(rng: np.random.Generator, n: int) -> np.ndarray:
    kind = rng.choice(("bimodal", "lognormal", "uniform", "draining"))
    if kind == "bimodal":
        r = np.where(rng.random(n) < rng.uniform(0.1, 0.5),
                     rng.integers(24, 200, n), rng.integers(1, 8, n))
    elif kind == "lognormal":
        r = np.ceil(rng.lognormal(np.log(12), rng.uniform(0.3, 1.2), n))
    elif kind == "uniform":
        c = rng.integers(4, 64)
        r = rng.integers(max(c - 2, 1), c + 3, n)
    else:  # draining: a fused batch where some rows already finished
        r = rng.integers(1, 96, n).astype(np.float64)
        r[rng.random(n) < rng.uniform(0.2, 0.7)] = 0.0
    return np.asarray(r, np.float64)


def build_serve_corpus(n_samples: int = 2048, capacity: int = 8,
                       max_ways: int = 2, label_margin: float = 0.02,
                       regroup_policy: str = "warp_regroup",
                       seed: int = 0, hetero: bool = True
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X (N, F), y (N,)) with y=1 iff reconfiguring wins.

    The label is the realized win of the best topology in the group's
    composition lattice (``hetero=False`` restricts it to the balanced
    ladder — the pre-composition labels).
    """
    rng = np.random.default_rng(seed)
    space = ConfigSpace(capacity=capacity, max_ways=max_ways, hetero=hetero)
    X = np.zeros((n_samples, len(SERVE_FEATURES)))
    y = np.zeros(n_samples)
    for i in range(n_samples):
        b = int(rng.integers(2, capacity + 1))
        remaining = _sample_remaining(rng, b)
        fv = FeatureVector.from_group(
            remaining, queue_depth=int(rng.integers(0, 3 * capacity)),
            arrival_rate=float(rng.uniform(0.0, 2.0)), capacity=capacity)
        _, gain = space.best_topology(remaining, regroup_policy)
        X[i] = fv.to_array()
        y[i] = 1.0 if gain > label_margin else 0.0
    return X, y


def train_serve_predictor(n_samples: int = 2048, capacity: int = 8,
                          max_ways: int = 2, label_margin: float = 0.02,
                          regroup_policy: str = "warp_regroup",
                          seed: int = 0, steps: int = 1500,
                          hetero: bool = True):
    """Train the serve-level logistic model; returns (model, info)."""
    X, y = build_serve_corpus(n_samples, capacity, max_ways, label_margin,
                              regroup_policy, seed, hetero=hetero)
    return P.train_logistic(X, y, feature_names=SERVE_FEATURES, steps=steps)


def serve_feature_ablation(model: P.LogisticModel, X: np.ndarray,
                           y: np.ndarray, steps: int = 400
                           ) -> Dict[str, Dict[str, float]]:
    """Paper Fig 20 at the serve level: what actually carries the decision.

    For each feature reports the mean absolute per-sample impact
    (standardized value x coefficient — the paper's impact metric) and
    the drop-one refit accuracy: retrain without the feature and see how
    much the corpus accuracy falls.  A feature whose removal costs
    nothing is dead weight in the online refit loop.
    """
    names = model.feature_names or tuple(
        f"f{i}" for i in range(X.shape[1]))
    impacts = np.abs(np.asarray(P.feature_impacts(
        model, np.asarray(X, np.float64))))
    mean_abs = impacts.mean(axis=0)
    # the drop-one baseline is a full-feature model retrained on the SAME
    # (X, y, steps) budget, so accuracy_cost isolates the feature instead
    # of conflating it with the passed-in model's larger training run
    full, _ = P.train_logistic(X, y, feature_names=names, steps=steps)
    full_acc = float(np.mean(
        (np.asarray(P.predict_proba(full, X)) > 0.5) == (y > 0.5)))
    out: Dict[str, Dict[str, float]] = {}
    for i, name in enumerate(names):
        keep = [j for j in range(X.shape[1]) if j != i]
        sub, _ = P.train_logistic(
            X[:, keep], y,
            feature_names=tuple(names[j] for j in keep), steps=steps)
        sub_acc = float(np.mean(
            (np.asarray(P.predict_proba(sub, X[:, keep])) > 0.5)
            == (y > 0.5)))
        out[name] = {
            "mean_abs_impact": round(float(mean_abs[i]), 4),
            "drop_one_accuracy": round(sub_acc, 4),
            "accuracy_cost": round(full_acc - sub_acc, 4),
        }
    return out
