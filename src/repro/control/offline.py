"""Offline corpus for the serve-level scalability predictor.

The paper trains its logistic model on "a large amount of offline
experimental data" from the simulator (``repro.core.gpusim.corpus`` keeps
that path).  The serving analogue generates decision scenarios — batches
with bimodal / lognormal / near-lockstep remaining-length profiles under
varying queue pressure — and labels each with the realized win: does the
best k-way partition of this batch save more slot-steps than the
reconfiguration margin?  The features are exactly the live-telemetry
:class:`~repro.control.features.FeatureVector`, so a model trained here
drops straight into :class:`~repro.control.policies.PredictorPolicy`.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.control.features import SERVE_FEATURES, FeatureVector
from repro.control.space import ConfigSpace
from repro.core import predictor as P


def _sample_remaining(rng: np.random.Generator, n: int) -> np.ndarray:
    kind = rng.choice(("bimodal", "lognormal", "uniform", "draining"))
    if kind == "bimodal":
        r = np.where(rng.random(n) < rng.uniform(0.1, 0.5),
                     rng.integers(24, 200, n), rng.integers(1, 8, n))
    elif kind == "lognormal":
        r = np.ceil(rng.lognormal(np.log(12), rng.uniform(0.3, 1.2), n))
    elif kind == "uniform":
        c = rng.integers(4, 64)
        r = rng.integers(max(c - 2, 1), c + 3, n)
    else:  # draining: a fused batch where some rows already finished
        r = rng.integers(1, 96, n).astype(np.float64)
        r[rng.random(n) < rng.uniform(0.2, 0.7)] = 0.0
    return np.asarray(r, np.float64)


def build_serve_corpus(n_samples: int = 2048, capacity: int = 8,
                       max_ways: int = 2, label_margin: float = 0.02,
                       regroup_policy: str = "warp_regroup",
                       seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X (N, F), y (N,)) with y=1 iff splitting realizes a win."""
    rng = np.random.default_rng(seed)
    space = ConfigSpace(capacity=capacity, max_ways=max_ways)
    X = np.zeros((n_samples, len(SERVE_FEATURES)))
    y = np.zeros(n_samples)
    for i in range(n_samples):
        b = int(rng.integers(2, capacity + 1))
        remaining = _sample_remaining(rng, b)
        fv = FeatureVector.from_group(
            remaining, queue_depth=int(rng.integers(0, 3 * capacity)),
            arrival_rate=float(rng.uniform(0.0, 2.0)), capacity=capacity)
        _, gain = space.best_ways(remaining, regroup_policy)
        X[i] = fv.to_array()
        y[i] = 1.0 if gain > label_margin else 0.0
    return X, y


def train_serve_predictor(n_samples: int = 2048, capacity: int = 8,
                          max_ways: int = 2, label_margin: float = 0.02,
                          regroup_policy: str = "warp_regroup",
                          seed: int = 0, steps: int = 1500):
    """Train the serve-level logistic model; returns (model, info)."""
    X, y = build_serve_corpus(n_samples, capacity, max_ways, label_margin,
                              regroup_policy, seed)
    return P.train_logistic(X, y, feature_names=SERVE_FEATURES, steps=steps)
