import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_runtest_teardown(item, nextitem):
    # Drop compiled XLA executables between test modules: a full-suite
    # process otherwise accumulates thousands of jitted shapes, and the
    # CPU JIT eventually hits the kernel mmap budget (LLVM "Cannot
    # allocate memory" -> segfault deep into the run).  Shapes recompile
    # per module; correctness is unaffected.
    if nextitem is None:
        return
    mod = item.nodeid.split("::", 1)[0]
    nxt = nextitem.nodeid.split("::", 1)[0]
    if mod != nxt:
        try:
            import jax
            jax.clear_caches()
        except Exception:
            pass
