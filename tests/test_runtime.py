"""Trainer (fault tolerance, compression), checkpointing, data, serving."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, latest_step
from repro.configs import get_config
from repro.configs.base import AmoebaConfig, ShapeConfig, TrainConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.serve import Request, ServeEngine
from repro.train import Trainer
from repro.train.stragglers import StragglerMonitor

SHAPE = ShapeConfig("tiny", 64, 4, "train")


def _trainer(arch="qwen3-14b", **tkw):
    cfg = get_config(arch, reduced=True)
    tcfg = TrainConfig(total_steps=10, warmup_steps=2, learning_rate=1e-3,
                       checkpoint_every=4, **tkw)
    return Trainer(cfg, SHAPE, tcfg)


def test_loss_decreases():
    out = _trainer().train(10)
    hist = out["history"]
    first3 = np.mean([m.loss for m in hist[:3]])
    last3 = np.mean([m.loss for m in hist[-3:]])
    assert last3 < first3


def test_failure_resume_is_exact(tmp_path):
    base = _trainer().train(10)
    losses = [m.loss for m in base["history"]]

    ck = CheckpointManager(str(tmp_path), keep=2)
    fails = {5, 8}

    def inject(k):
        if k in fails:
            fails.discard(k)
            return True
        return False

    out = _trainer().train(10, ckpt=ck, failure_injector=inject)
    assert out["resumes"] == 2
    got = {m.step: m.loss for m in out["history"]}
    for s, l in got.items():
        assert abs(l - losses[s]) < 1e-6, (s, l, losses[s])


def test_grad_compression_trains():
    out = _trainer(grad_compression=True).train(8)
    hist = out["history"]
    assert hist[-1].loss < hist[0].loss + 0.1
    assert out["state"].residuals is not None


def test_micro_steps_match_full_batch():
    """Gradient accumulation over microbatches == one big batch (fp32)."""
    cfg = get_config("qwen3-14b", reduced=True).replace(dtype="float32")
    t1 = Trainer(cfg, SHAPE, TrainConfig(total_steps=3, warmup_steps=1,
                                         learning_rate=1e-3, micro_steps=1))
    t2 = Trainer(cfg, SHAPE, TrainConfig(total_steps=3, warmup_steps=1,
                                         learning_rate=1e-3, micro_steps=2))
    h1 = t1.train(3)["history"]
    h2 = t2.train(3)["history"]
    for a, b in zip(h1, h2):
        assert abs(a.loss - b.loss) < 5e-4, (a.step, a.loss, b.loss)


def test_moe_divergence_telemetry():
    from repro.core.controller import AmoebaController
    cfg = get_config("deepseek-moe-16b", reduced=True)
    ctl = AmoebaController(AmoebaConfig(min_phase_steps=1))
    tr = Trainer(cfg, SHAPE, TrainConfig(total_steps=4, warmup_steps=1),
                 controller=ctl)
    out = tr.train(4)
    assert all(m.divergence > 0 for m in out["history"])
    assert len(ctl.split_state.history) == 4


def test_straggler_monitor():
    import time
    mon = StragglerMonitor(threshold=3.0, warmup=1)
    for i in range(6):
        mon.start()
        time.sleep(0.03 if i != 4 else 0.2)
        mon.stop(i)
    assert len(mon.events) == 1 and mon.events[0]["step"] == 4


# -- checkpoint manager -------------------------------------------------------

def test_ckpt_roundtrip_and_retention(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": [jnp.ones(()), jnp.zeros((4,), jnp.int32)]}
    for s in (1, 2, 3):
        ck.save(s, tree, extra={"tag": s}, blocking=True)
    assert latest_step(str(tmp_path)) == 3
    # retention: only the newest `keep` survive
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [2, 3]
    step, got, extra = ck.restore(like=tree)
    assert step == 3 and extra == {"tag": 3}
    assert got["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))


def test_ckpt_atomicity(tmp_path):
    """A lingering .tmp dir is never picked up as a checkpoint."""
    ck = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(tmp_path, "step_9.tmp"))
    ck.save(1, {"x": jnp.ones((2,))}, blocking=True)
    assert latest_step(str(tmp_path)) == 1


# -- data pipeline -------------------------------------------------------------

def test_data_determinism_and_seek():
    cfg = get_config("qwen3-14b", reduced=True)
    d1 = SyntheticLM(cfg, SHAPE, DataConfig(seed=7))
    d2 = SyntheticLM(cfg, SHAPE, DataConfig(seed=7))
    np.testing.assert_array_equal(d1.batch_at(5)["tokens"],
                                  d2.batch_at(5)["tokens"])
    it = iter(d1)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], d1.batch_at(0)["tokens"])


def test_data_host_sharding_disjoint():
    cfg = get_config("qwen3-14b", reduced=True)
    shape = ShapeConfig("t", 32, 8, "train")
    h0 = SyntheticLM(cfg, shape, DataConfig(seed=1), host_index=0,
                     host_count=2)
    h1 = SyntheticLM(cfg, shape, DataConfig(seed=1), host_index=1,
                     host_count=2)
    assert h0.local_batch == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_data_has_learnable_structure():
    """Markov stream: successor entropy far below uniform."""
    cfg = get_config("qwen3-14b", reduced=True)
    d = SyntheticLM(cfg, ShapeConfig("t", 256, 4, "train"), DataConfig(seed=0))
    toks = d.batch_at(0)["tokens"]
    # each token has only `branching` successors out of vocab
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    avg_succ = np.mean([len(v) for v in succ.values()])
    assert avg_succ <= d.cfg.branching + 1


# -- serving -------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_setup():
    cfg = get_config("qwen3-14b", reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n=12, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.choice([8, 16]))
        mx = int(rng.choice([3, 6, 24]))
        out.append(Request(i, list(map(int, rng.integers(
            0, cfg.vocab_size, plen))), mx))
    return out


def test_serve_all_policies_complete_and_agree(serve_setup):
    """Generated tokens must be identical under every grouping policy —
    batch composition cannot change per-request results."""
    cfg, params = serve_setup
    texts = {}
    stats = {}
    for name, dyn, pol in [("fused", False, "warp_regroup"),
                           ("direct", True, "direct_split"),
                           ("regroup", True, "warp_regroup")]:
        eng = ServeEngine(cfg, params, amoeba=AmoebaConfig(
            regroup_policy=pol, split_threshold=0.3, fuse_threshold=0.05,
            min_phase_steps=2), capacity=4)
        reqs = _requests(cfg)
        eng.submit(reqs)
        st = eng.run(dynamic=dyn)
        assert st.completed == len(reqs)
        texts[name] = {r.rid: tuple(r.generated) for r in reqs}
        stats[name] = st
    assert texts["fused"] == texts["regroup"] == texts["direct"]
    assert stats["regroup"].efficiency >= stats["fused"].efficiency - 1e-9


def test_serve_regroup_beats_fused_on_divergent_load(serve_setup):
    cfg, params = serve_setup
    # long-tail decode lengths: most requests short, a few dominate the
    # batch critical path — the regime where quarantining the tail pays
    rng = np.random.default_rng(3)
    mk = lambda: [Request(i, list(map(int, rng.integers(0, cfg.vocab_size,
                                                        8))),
                          int(rng.choice([2, 40], p=[0.75, 0.25])))
                  for i in range(16)]
    effs = {}
    for name, dyn in [("fused", False), ("regroup", True)]:
        rng = np.random.default_rng(3)
        eng = ServeEngine(cfg, params, amoeba=AmoebaConfig(
            regroup_policy="warp_regroup", split_threshold=0.3,
            fuse_threshold=0.05, min_phase_steps=2), capacity=8)
        eng.submit(mk())
        effs[name] = eng.run(dynamic=dyn).efficiency
    assert effs["regroup"] > effs["fused"] * 1.1
