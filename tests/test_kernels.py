"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,S,Skv,H,KV,hd", [
    (1, 64, 64, 4, 4, 32),      # MHA square
    (2, 96, 96, 8, 2, 64),      # GQA, non-block-multiple seq
    (1, 33, 128, 4, 1, 64),     # MQA, cross shapes
    (2, 200, 200, 8, 4, 128),   # 128-lane head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 48)])
def test_flash_attention_sweep(B, S, Skv, H, KV, hd, dtype, causal, window):
    if Skv != S and causal:
        pytest.skip("causal cross-shape undefined")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=64, bk=64)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("B,S,W,bs,bw", [
    (1, 64, 64, 32, 32),
    (2, 100, 96, 32, 64),      # padded seq
    (1, 257, 33, 64, 16),      # padded width
])
def test_rglru_scan_sweep(B, S, W, bs, bw):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    a = jax.random.uniform(ks[0], (B, S, W), jnp.float32, 0.4, 0.999)
    b = jax.random.normal(ks[1], (B, S, W), jnp.float32)
    got = ops.rglru_scan(a, b, bs=bs, bw=bw)
    want = ref.rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,S,D,N,bs,bd", [
    (1, 64, 64, 8, 32, 32),
    (2, 77, 96, 16, 32, 64),
    (1, 130, 48, 4, 64, 48),
])
def test_ssm_scan_sweep(B, S, D, N, bs, bd):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = jax.random.uniform(ks[0], (B, S, D, N), jnp.float32, 0.4, 0.999)
    b = jax.random.normal(ks[1], (B, S, D, N), jnp.float32) * 0.1
    c = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    y, h = ops.ssm_scan(a, b, c, bs=bs, bd=bd)
    yr, hr = ref.ssm_scan(a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("T,D", [(16, 128), (37, 256), (100, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(T, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (T, D), dtype)
    sc = jax.random.normal(ks[1], (D,), jnp.float32)
    got = ops.rmsnorm(x, sc, bt=16)
    want = ref.rmsnorm(x, sc)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("T,D", [(8, 64), (33, 257), (128, 1024)])
def test_quantize_sweep(T, D):
    x = jax.random.normal(jax.random.PRNGKey(4), (T, D), jnp.float32) * 3.0
    qg, sg = ops.quantize_int8(x, bt=16)
    qr, sr = ref.quantize_int8(x)
    assert int(jnp.max(jnp.abs(qg.astype(jnp.int32)
                               - qr.astype(jnp.int32)))) <= 1
    np.testing.assert_allclose(np.asarray(sg), np.asarray(sr), rtol=1e-6)
    # reconstruction error bounded by half a quantization step per row
    deq = ops.dequantize_int8(qg, sg)
    err = jnp.max(jnp.abs(deq - x), axis=1)
    bound = jnp.max(jnp.abs(x), axis=1) / 127.0
    assert bool(jnp.all(err <= bound * 1.01))
