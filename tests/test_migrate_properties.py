"""Hypothesis properties for the migration planner/executor.

Follows the repo's importorskip pattern (cf. test_control_properties.py):
this module skips where hypothesis is unavailable, and the same
contracts are pinned with concrete cases in test_migrate.py, which
always runs.  The invariants fuzzed here are the ISSUE's conservation
contract: across any plan execution no request is lost or duplicated,
per-part slot budgets are never exceeded, and a zero-bandwidth
KVTransferCost makes every live-migration plan amortization-fail while
queue steals keep flowing.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from fake_fleet import FakeGroup, all_requests
from repro.cluster import ClusterMesh, ClusterPlanner, TieredTransferCost
from repro.configs import get_config
from repro.configs.base import ClusterConfig, MigrationConfig
from repro.fleet.migrate import STEAL, MigrationPlanner
from repro.serve.engine import Request

MODEL_CFG = get_config("qwen3-14b", reduced=True)


def _planner(**kw):
    kw.setdefault("enabled", True)
    kw.setdefault("steal_threshold", 1)
    kw.setdefault("min_gain", 0.0)
    return MigrationPlanner(MigrationConfig(**kw), MODEL_CFG,
                            long_threshold=24, window=64)


def _cluster_planner(n_groups, ccfg=None, **kw):
    kw.setdefault("enabled", True)
    kw.setdefault("steal_threshold", 1)
    kw.setdefault("min_gain", 0.0)
    cfg = MigrationConfig(**kw)
    mesh = ClusterMesh(num_groups=n_groups, groups_per_chip=2)
    ccfg = ccfg or ClusterConfig(groups_per_chip=2)
    cost = TieredTransferCost.from_config(
        mesh, ccfg, dtype_bytes=cfg.kv_dtype_bytes,
        quantized=cfg.quantized_kv)
    return ClusterPlanner(cfg, MODEL_CFG, mesh=mesh, cost=cost, ccfg=ccfg,
                          long_threshold=24, window=64)


def _req(rid: int, tokens: int, started: bool) -> Request:
    r = Request(rid, [1, 2, 3, 4], tokens)
    if started:
        r.generated = [0]          # live: one token in, remaining > 0
    return r


@st.composite
def fleets(draw):
    n_groups = draw(st.integers(2, 4))
    rid = iter(range(100_000))
    groups = []
    for gi in range(n_groups):
        topo = tuple(draw(st.lists(st.integers(1, 4),
                                   min_size=1, max_size=3)))
        parts = []
        for slots in topo:
            k = draw(st.integers(0, slots))
            parts.append([_req(next(rid), draw(st.integers(2, 80)), True)
                          for _ in range(k)])
        queue = [_req(next(rid), draw(st.integers(1, 80)), False)
                 for _ in range(draw(st.integers(0, 6)))]
        groups.append(FakeGroup(gi, topo, queue=queue, parts=parts))
    return groups


@given(fleets(), st.floats(1e3, 1e12), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_no_request_lost_or_duplicated_and_budgets_hold(groups, bw, rounds):
    p = _planner(live=True, link_bandwidth=bw)
    before = sorted(r.rid for r in all_requests(groups))
    assert len(set(before)) == len(before)
    for tick in range(rounds):
        plans = p.plan(tick, groups)
        p.execute(plans, groups, now=tick)
        after = sorted(r.rid for r in all_requests(groups))
        assert after == before, "request lost or duplicated"
        for g in groups:
            for i, slots in enumerate(g.topology):
                assert len(g.part_live(i)) <= slots, \
                    "part slot budget exceeded"


@given(fleets(), st.integers(1, 3))
@settings(max_examples=40, deadline=None)
def test_zero_bandwidth_never_plans_live_migrations(groups, rounds):
    p = _planner(live=True, link_bandwidth=0.0)
    for tick in range(rounds):
        plans = p.plan(tick, groups)
        assert all(m.kind == STEAL for m in plans)
        p.execute(plans, groups, now=tick)
    assert p.live_migrations == 0


@given(fleets(), st.floats(1e3, 1e12), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_cluster_and_fleet_plans_conserve_requests_same_tick(groups, bw,
                                                            rounds):
    """A tiered cluster planner and a flat fleet planner executing in
    the same tick — plus requests in flight on the slow links — must
    still conserve every request and every slot budget."""
    cp = _cluster_planner(len(groups), live=True, link_bandwidth=bw)
    fp = _planner(live=True, link_bandwidth=bw)
    before = sorted(r.rid for r in all_requests(groups))
    for tick in range(rounds):
        cp.deliver_in_flight(tick, groups)
        cp.execute(cp.plan(tick, groups), groups, now=tick)
        fp.execute(fp.plan(tick, groups), groups, now=tick)
        in_air = cp.in_flight_requests()
        after = sorted(r.rid for r in all_requests(groups)
                       + in_air)
        assert after == before, "request lost or duplicated"
        assert len({id(r) for r in in_air}) == len(in_air)
        for g in groups:
            for i, slots in enumerate(g.topology):
                assert len(g.part_live(i)) <= slots, \
                    "part slot budget exceeded"
    # flush the wire: every in-flight steal lands exactly once
    cp.deliver_in_flight(10**9, groups)
    assert cp.in_flight_requests() == []
    assert sorted(r.rid for r in all_requests(groups)) == before


@given(fleets(), st.integers(1, 3))
@settings(max_examples=40, deadline=None)
def test_zero_interchip_bandwidth_confines_moves_to_the_chip(groups,
                                                             rounds):
    """With dead inter-chip links every cross-chip steal and live
    migration is vetoed; whatever still moves, moves over the NoC."""
    ccfg = ClusterConfig(groups_per_chip=2, link_bandwidth=0.0,
                         net_bandwidth=0.0)
    cp = _cluster_planner(len(groups), ccfg=ccfg, live=True)
    mesh = cp.mesh
    for tick in range(rounds):
        plans = cp.plan(tick, groups)
        assert all(mesh.chip_of(m.src[0]) == mesh.chip_of(m.dst[0])
                   for m in plans), "cross-chip move planned on dead link"
        cp.execute(plans, groups, now=tick)
    assert cp.cross_chip_steals == 0 and cp.cross_chip_live == 0
    assert cp.in_flight_requests() == []   # noc moves land instantly


@given(fleets())
@settings(max_examples=40, deadline=None)
def test_reserved_parts_never_receive_work(groups):
    # reserve every part of group 0: nothing may land there
    reserved = {(0, i) for i in range(len(groups[0].topology))}
    p = _planner(live=True, link_bandwidth=1e12)
    plans = p.plan(0, groups, reserved=reserved)
    assert all(m.dst[0] != 0 for m in plans)
    p.execute(plans, groups, now=0)
    for i, slots in enumerate(groups[0].topology):
        assert len(groups[0].part_live(i)) <= slots
