"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import regroup as R
from repro.kernels import ref
from repro.models import scan_utils
from repro.parallel import compression as C

lengths = st.lists(st.floats(min_value=0.0, max_value=1e4,
                             allow_nan=False, allow_infinity=False),
                   min_size=2, max_size=32)


@given(lengths)
@settings(max_examples=100, deadline=None)
def test_divergence_score_in_unit_interval(r):
    d = R.divergence_score(r)
    assert 0.0 <= d < 1.0 + 1e-12


@given(lengths)
@settings(max_examples=100, deadline=None)
def test_split_gains_nonnegative(r):
    """Splitting can never cost slot-steps: each half's max <= global max."""
    for policy in ("warp_regroup", "direct_split"):
        assert R.regroup_gain(r, policy) >= -1e-12


even_lengths = lengths.filter(lambda r: len(r) % 2 == 0)


@given(even_lengths)
@settings(max_examples=100, deadline=None)
def test_warp_regroup_is_optimal_bipartition(r):
    """For equal halves (the paper's two equal SM slices), the sorted split
    minimizes sum of half-costs, so regrouping dominates the direct mid-cut.
    (With odd batches and unequal halves the claim does not hold — the
    engine always splits a fused group into two equal halves.)"""
    assert R.regroup_gain(r, "warp_regroup") >= \
        R.regroup_gain(r, "direct_split") - 1e-12


@given(st.integers(1, 4), st.integers(1, 96), st.integers(1, 8),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_linear_scan_chunking_invariant(B, S, W, seed):
    """Chunked associative scan == sequential recurrence, any chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.random.uniform(ks[0], (B, S, W), jnp.float32, 0.0, 1.0)
    b = jax.random.normal(ks[1], (B, S, W), jnp.float32)
    want = ref.rglru_scan(a, b)
    for chunk in (1, 3, 17, 256):
        got, last = scan_utils.linear_scan(a, b, jnp.zeros((B, W)),
                                           chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(last), np.asarray(want[:, -1]),
                                   atol=1e-4, rtol=1e-4)


@given(st.integers(1, 64), st.integers(1, 300), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_int8_roundtrip_error_bound(T, D, seed):
    """|x - dequant(quant(x))| <= rowwise amax/127/2 * (1+eps)."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (T, D),
                                     jnp.float32)) * 10
    q, s, shape = C.compress_leaf(jnp.asarray(x))
    deq = np.asarray(C.decompress_leaf(q, s, shape))
    # rows of the padded (R, 1024) layout each have their own scale
    flat = x.reshape(-1)
    err = np.abs(deq.reshape(-1) - flat)
    # global bound: half step of the largest row scale
    bound = np.abs(flat).max() / 127.0 * 0.5 + 1e-6
    assert err.max() <= bound * 1.05


@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_error_feedback_converges(k, seed):
    """Repeated compression with error feedback transmits the signal:
    cumulative dequantized sum -> cumulative true sum."""
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (8, 64),
                                     jnp.float32))
    res = np.zeros_like(g)
    sent_total = np.zeros_like(g)
    for _ in range(k):
        q, s, shape = C.compress_leaf(jnp.asarray(g + res))
        deq = np.asarray(C.decompress_leaf(q, s, shape))
        res = (g + res) - deq
        sent_total += deq
    # after k steps, total sent = k*g - residual, residual bounded by 1 step
    err = np.abs(sent_total - k * g).max()
    step = np.abs(g).max() / 127.0
    assert err <= step * 1.5


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 12))
@settings(max_examples=20, deadline=None)
def test_ring_cache_validity_mask(seed, W):
    """Ring slots report valid iff they hold a live absolute position."""
    from repro.models.attention import _ring_valid
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.integers(0, 40, size=(3,)), jnp.int32)
    slots = jnp.arange(W)
    valid = np.asarray(_ring_valid(pos, W, slots))
    for b in range(3):
        p = int(pos[b])
        for i in range(W):
            live = p - ((p - i) % W)
            assert valid[b, i] == (live >= 0)
