"""Hierarchical cluster layer (repro.cluster): mesh, tiers, control.

Geometry and tier pricing are pure-function tests; planner and
controller behavior runs against the protocol fakes from
``fake_fleet.py`` (no model); the end-to-end section drives a real
:class:`~repro.cluster.ClusterEngine` to pin books-balance with
in-flight cross-chip transfers and the telemetry cluster block.  The
same conservation invariants are fuzzed in
``test_migrate_properties.py``.
"""
import math

import jax
import numpy as np
import pytest

from fake_fleet import FakeGroup, all_requests
from repro.cluster import (ClusterController, ClusterEngine, ClusterMesh,
                           ClusterPlanner, RegionManager, TieredTransferCost)
from repro.configs import get_config
from repro.configs.base import (AmoebaConfig, ClusterConfig, FleetConfig,
                                MigrationConfig)
from repro.control import (ConfigSpace, GroupController, ThresholdPolicy)
from repro.fleet import multichip_imbalanced_trace
from repro.fleet.migrate import LIVE, STEAL
from repro.models import transformer as T
from repro.serve import Request

AMOEBA = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                      min_phase_steps=2)


def model_cfg():
    return get_config("qwen3-14b", reduced=True)


@pytest.fixture(scope="module")
def setup():
    cfg = model_cfg()
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def req(rid, tokens, generated=0, plen=4):
    r = Request(rid, [1] * plen, tokens)
    r.generated = [0] * generated
    return r


# a 2x2-chip playground: 4 groups, 2 per chip, all on one node
MESH4 = ClusterMesh(num_groups=4, groups_per_chip=2)


def cplanner(ccfg=None, mesh=MESH4, **kw):
    kw.setdefault("enabled", True)
    ccfg = ccfg or ClusterConfig(groups_per_chip=mesh.groups_per_chip)
    cfg = MigrationConfig(**kw)
    cost = TieredTransferCost.from_config(
        mesh, ccfg, dtype_bytes=cfg.kv_dtype_bytes,
        quantized=cfg.quantized_kv)
    return ClusterPlanner(cfg, model_cfg(), mesh=mesh, cost=cost,
                          ccfg=ccfg, long_threshold=24, window=256)


# -- mesh geometry -------------------------------------------------------------

def test_mesh_partition_and_counts():
    m = ClusterMesh(num_groups=8, groups_per_chip=4, chips_per_node=1)
    assert m.num_chips == 2 and m.num_nodes == 2
    assert m.chip_of(0) == 0 and m.chip_of(4) == 1
    assert m.chip_groups(1) == [4, 5, 6, 7]
    # ragged tail: the last chip holds the remainder
    r = ClusterMesh(num_groups=5, groups_per_chip=4)
    assert r.num_chips == 2 and r.chip_groups(1) == [4]


def test_mesh_coords_are_unique_and_hops_metric():
    m = ClusterMesh(num_groups=8, groups_per_chip=4)
    coords = [m.coord(g) for g in range(8)]
    assert len(set(coords)) == 8
    for a in range(8):
        assert m.hops(a, a) == 0
        for b in range(8):
            assert m.hops(a, b) == m.hops(b, a) >= (a != b)
    with pytest.raises(IndexError):
        m.coord(8)


def test_mesh_tiers_and_adjacency():
    m = ClusterMesh(num_groups=8, groups_per_chip=4, chips_per_node=1)
    assert m.tier(0, 0) == "self"
    assert m.tier(0, 1) == "noc"          # same chip
    assert m.tier(0, 4) == "net"          # one chip per node: crossings net
    one_node = ClusterMesh(num_groups=8, groups_per_chip=4)
    assert one_node.tier(0, 4) == "link"  # same node: board-level link
    # adjacency: same-chip nearest neighbors only (the region criterion)
    assert m.adjacent(0, 1) and m.adjacent(0, 2)
    assert not m.adjacent(0, 3)           # diagonal: two hops
    assert not m.adjacent(3, 4)           # chip boundary, whatever the hops
    assert not m.adjacent(2, 2)
    assert "chip 0" in m.describe() and "chip 1" in m.describe()


def test_mesh_validation():
    with pytest.raises(ValueError):
        ClusterMesh(num_groups=0, groups_per_chip=2)
    with pytest.raises(ValueError):
        ClusterMesh(num_groups=4, groups_per_chip=2, chips_per_node=0)


# -- tiered transfer cost ------------------------------------------------------

def test_tier_pricing_orders_by_distance():
    m = ClusterMesh(num_groups=8, groups_per_chip=4, chips_per_node=1)
    c = TieredTransferCost(mesh=m, noc_bandwidth=1e9, noc_latency=0.0,
                           link_bandwidth=100.0, link_latency=2.0,
                           net_bandwidth=50.0, net_latency=4.0)
    nbytes = 1000
    noc = c.transfer_ticks(nbytes, 0, 1)
    net = c.transfer_ticks(nbytes, 0, 4)
    assert noc == 0                       # sub-tick NoC hop: free
    assert net >= 4 + nbytes / 50.0 - 1   # hop latency + slow wire
    assert c.transfer_ticks(nbytes, 3, 3) == 0.0
    # farther pairs on the same tier pay more hops
    assert c.transfer_ticks(nbytes, 0, 7) > c.transfer_ticks(nbytes, 3, 4)


def test_zero_tier_bandwidth_is_infinite_and_flat_fallback_holds():
    cfg = model_cfg()
    m = ClusterMesh(num_groups=4, groups_per_chip=2)
    c = TieredTransferCost(mesh=m, noc_bandwidth=4e9,
                           link_bandwidth=0.0, net_bandwidth=0.0)
    assert math.isinf(c.transfer_ticks(100, 0, 2))
    assert c.transfer_ticks(100, 0, 1) == 0          # noc unaffected
    assert math.isinf(c.stall_ticks(16, cfg, src=0, dst=2))
    # without src/dst the parent's flat link pricing applies
    flat = TieredTransferCost(mesh=m, link_bandwidth=100.0)
    assert flat.transfer_ticks(1000, None, None) == 10
    assert math.isinf(c.transfer_ticks(1000, None, None))


def test_integer_latency_does_not_round_up_on_float_dust():
    m = ClusterMesh(num_groups=4, groups_per_chip=2)
    c = TieredTransferCost(mesh=m, link_bandwidth=2e8, link_latency=1.0)
    # 0 -> 2 is two hops: 2 latency ticks + a vanishing bandwidth term
    assert m.hops(0, 2) == 2
    assert c.transfer_ticks(16, 0, 2) == 2


def test_steal_ticks_price_the_prompt_not_the_kv():
    c = TieredTransferCost(mesh=MESH4, link_bandwidth=8.0, link_latency=0.0)
    # 0 -> 2: two hops, free latency; 4 tokens * 4B / 8 Bpt = 2 ticks
    assert c.steal_ticks(4, 0, 2) == 2
    assert c.steal_ticks(4, 0, 1) == 0    # noc absorbs it sub-tick


# -- planner: chip-first stealing ----------------------------------------------

def test_steals_resolve_on_chip_first_then_amortized_residual_crosses():
    donor = FakeGroup(0, (4,), queue=[req(i, 4) for i in range(6)])
    mate = FakeGroup(1, (4,))
    far = FakeGroup(2, (4,))
    far2 = FakeGroup(3, (4,))
    groups = [donor, mate, far, far2]
    before = sorted(r.rid for r in all_requests(groups))
    p = cplanner(steal_threshold=1, max_steals=2)
    plans = p.plan(0, groups)
    # the chip phase fills the chipmate, the residual crosses
    intra = [m for m in plans if m.dst[0] == 1]
    cross = [m for m in plans if m.dst[0] in (2, 3)]
    assert len(intra) == 2 and len(cross) == 2
    assert {m.request.rid for m in intra}.isdisjoint(
        {m.request.rid for m in cross})
    assert all(m.gain > 0 and m.stall > 0 for m in cross)
    assert p.execute(plans, groups, now=0) == 4
    # intra-chip lands instantly; cross-chip is in the air
    assert p.intra_chip_steals == 2 and p.cross_chip_steals == 2
    assert mate.stats.steals_in == 2
    assert far.stats.steals_in == 0 and len(p.in_flight_requests()) == 2
    assert p.tier_bytes["noc"] > 0 and p.tier_bytes["link"] > 0
    # conservation must count the requests in flight
    now = sorted(r.rid for r in all_requests(groups)
                 + p.in_flight_requests())
    assert now == before
    # delivery: nothing before the arrival tick, everything at it
    t = p.next_arrival()
    assert t is not None and t > 0
    assert p.deliver_in_flight(t - 1, groups) == 0
    assert p.deliver_in_flight(t, groups) == 2
    assert far.stats.steals_in + far2.stats.steals_in == 2
    assert p.next_arrival() is None
    assert sorted(r.rid for r in all_requests(groups)) == before


def test_zero_interchip_bandwidth_vetoes_crossings_but_noc_flows():
    ccfg = ClusterConfig(groups_per_chip=2, link_bandwidth=0.0,
                         net_bandwidth=0.0)
    donor = FakeGroup(0, (4,), queue=[req(i, 4) for i in range(6)])
    groups = [donor, FakeGroup(1, (4,)), FakeGroup(2, (4,)),
              FakeGroup(3, (4,))]
    p = cplanner(ccfg=ccfg, steal_threshold=1, max_steals=2)
    plans = p.plan(0, groups)
    assert plans and all(m.dst[0] == 1 for m in plans)
    assert p.vetoed_cross_chip > 0
    assert p.execute(plans, groups, now=0) == len(plans)
    assert p.intra_chip_steals == 2 and p.cross_chip_steals == 0
    assert p.in_flight_requests() == []


def test_cross_steal_budget_caps_crossings():
    ccfg = ClusterConfig(groups_per_chip=2, max_cross_steals=1)
    donor = FakeGroup(0, (4,), queue=[req(i, 4) for i in range(8)])
    groups = [donor, FakeGroup(1, (4,)), FakeGroup(2, (4,)),
              FakeGroup(3, (4,))]
    p = cplanner(ccfg=ccfg, steal_threshold=1, max_steals=2)
    plans = p.plan(0, groups)
    assert sum(m.dst[0] in (2, 3) for m in plans) == 1


def test_live_migration_prefers_the_noc_destination():
    lives = [req(0, 60, generated=1), req(1, 3, generated=1),
             req(2, 3, generated=1), req(3, 3, generated=1)]
    donor = FakeGroup(0, (4,), parts=[lives])
    mate = FakeGroup(1, (2, 2))
    far = FakeGroup(3, (2, 2))
    groups = [donor, mate, FakeGroup(2, (1,), parts=[[req(9, 5)]]), far]
    p = cplanner(live=True, min_gain=0.02)
    plans = [m for m in p.plan(0, groups) if m.kind == LIVE]
    assert len(plans) == 1
    m = plans[0]
    # identical free capacity either side of the chip boundary: the
    # same-chip hop stalls less, so it wins the amortized gain
    assert m.dst[0] == 1 and m.stall == 0
    assert p.execute(plans, groups, now=0) == 1
    assert p.intra_chip_live == 1 and p.cross_chip_live == 0


def test_distance_blind_planning_pays_tiered_prices_at_execution():
    # the A/B baseline: one flat pool at plan time, physics at runtime
    ccfg = ClusterConfig(groups_per_chip=2, distance_blind=True)
    donor = FakeGroup(0, (4,), queue=[req(i, 4) for i in range(6)])
    groups = [donor, FakeGroup(1, (1,), parts=[[req(8, 9)]]),
              FakeGroup(2, (4,)), FakeGroup(3, (4,))]
    p = cplanner(ccfg=ccfg, steal_threshold=1, max_steals=2)
    plans = p.plan(0, groups)
    # the blind plan happily targets the far chip (sole free recipient)
    assert plans and all(m.dst[0] in (2, 3) for m in plans)
    assert all(m.stall == 0 for m in plans)          # ...priced flat
    assert p.execute(plans, groups, now=0) == len(plans)
    # ...but the steal still flies the slow link, not a free teleport
    assert p.cross_chip_steals == len(plans)
    assert len(p.in_flight_requests()) == len(plans)
    assert p.next_arrival() > 0


def test_blind_plan_across_dead_link_is_dropped_not_teleported():
    ccfg = ClusterConfig(groups_per_chip=2, distance_blind=True,
                         link_bandwidth=0.0, net_bandwidth=0.0)
    donor = FakeGroup(0, (4,), queue=[req(i, 4) for i in range(6)])
    groups = [donor, FakeGroup(1, (1,), parts=[[req(8, 9)]]),
              FakeGroup(2, (4,)), FakeGroup(3, (4,))]
    before = sorted(r.rid for r in all_requests(groups))
    p = cplanner(ccfg=ccfg, steal_threshold=1, max_steals=2)
    plans = p.plan(0, groups)
    assert plans and all(m.dst[0] in (2, 3) for m in plans)
    assert p.execute(plans, groups, now=0) == 0
    assert p.dropped_unreachable == len(plans)
    # the victims never left the donor's queue
    assert sorted(r.rid for r in all_requests(groups)) == before
    assert len(donor.queue) == 6


def test_region_groups_are_boosted_steal_recipients():
    donor = FakeGroup(0, (4,), queue=[req(i, 40) for i in range(4)])
    a, b = FakeGroup(1, (4,)), FakeGroup(2, (2, 2))
    p = cplanner(mesh=ClusterMesh(num_groups=3, groups_per_chip=3),
                 ccfg=ClusterConfig(groups_per_chip=3),
                 steal_threshold=1, max_steals=2)
    base = p.plan(0, [donor, a, b])
    assert all(m.dst[0] == 1 for m in base)          # most free slots wins
    p.set_regions([2])
    boosted = p.plan(1, [donor, a, b])
    assert all(m.dst[0] == 2 for m in boosted)       # region outranks free


# -- region gather -------------------------------------------------------------

class _RegionGroup(FakeGroup):
    """FakeGroup plus the GroupController surface regions drive."""

    def __init__(self, gid, topology, queue=(), parts=None,
                 capacity=4, max_ways=2):
        super().__init__(gid, topology, queue=queue, parts=parts)
        self.controller = GroupController(
            ThresholdPolicy(0.95, 0.0),
            ConfigSpace(capacity, max_ways=max_ways), dwell=1)


def _region_fleet(long_tokens=60):
    hot = [_RegionGroup(0, (4,), parts=[[req(0, long_tokens, generated=1)]]),
           _RegionGroup(1, (4,), parts=[[req(1, long_tokens, generated=1)]])]
    cold = [_RegionGroup(2, (4,)), _RegionGroup(3, (4,))]
    return hot + cold


def test_region_gathers_deepens_and_releases():
    ccfg = ClusterConfig(groups_per_chip=2, region_dwell=4,
                         region_long_frac=0.5, region_release_frac=0.2)
    rm = RegionManager(MESH4, ccfg, long_threshold=24)
    groups = _region_fleet()
    deep = RegionManager.deep_topology(groups[0].controller.space)
    assert deep == (2, 2)
    assert rm.step(0, groups, {0: 0.9, 1: 0.0}) > 0
    assert rm.region_groups() == {0, 1}
    assert groups[0].controller._hint == deep
    assert groups[1].controller._hint == deep
    assert groups[2].controller._hint is None        # cold chip untouched
    assert rm.gathered == 1 and rm.summary()["active"] == [[0, 1]]
    # drained early: the dwell clock holds the region open
    assert rm.step(2, groups, {0: 0.0}) >= 0
    assert rm.region_groups() == {0, 1}
    # drained past the dwell: members hinted back to fused and freed
    rm.step(6, groups, {0: 0.0})
    assert rm.region_groups() == frozenset()
    assert rm.released == 1
    assert groups[0].controller._hint == (4,)


def test_region_reasserts_deep_hint_against_mix_drift():
    ccfg = ClusterConfig(groups_per_chip=2, region_dwell=4)
    rm = RegionManager(MESH4, ccfg, long_threshold=24)
    groups = _region_fleet()
    rm.step(0, groups, {0: 0.9})
    # a later mix nudge overwrote the hint; the region wins it back
    groups[0].controller._hint = None
    assert rm.step(1, groups, {0: 0.9}) > 0
    assert groups[0].controller._hint == (2, 2)


def test_region_excludes_the_quarantine_group():
    ccfg = ClusterConfig(groups_per_chip=2, region_max_groups=2)
    rm = RegionManager(MESH4, ccfg, long_threshold=24)
    groups = _region_fleet()
    rm.step(0, groups, {0: 0.9}, quarantine=0)
    assert rm.region_groups() == {1}


def test_region_needs_long_mass_not_just_a_hot_frac():
    rm = RegionManager(MESH4, ClusterConfig(groups_per_chip=2),
                       long_threshold=24)
    groups = [_RegionGroup(i, (4,)) for i in range(4)]   # nothing long
    assert rm.step(0, groups, {0: 0.9, 1: 0.9}) == 0
    assert rm.region_groups() == frozenset()


# -- cluster controller --------------------------------------------------------

def _controller(num_groups=4, groups_per_chip=2, quarantine=None,
                rebalance_every=4, region_gather=False):
    mesh = ClusterMesh(num_groups=num_groups,
                       groups_per_chip=groups_per_chip)
    ccfg = ClusterConfig(groups_per_chip=groups_per_chip,
                         region_gather=region_gather)
    fleet = FleetConfig(num_groups=num_groups, capacity=4, mode="dynamic",
                        rebalance_every=rebalance_every,
                        quarantine_group=quarantine,
                        migrate=MigrationConfig(enabled=True),
                        amoeba=AMOEBA)
    return ClusterController(mesh, ccfg, fleet, model_cfg())


def test_controller_gates_on_rebalance_cadence():
    cc = _controller(rebalance_every=4)
    groups = [_RegionGroup(i, (4,)) for i in range(4)]
    cc.rebalance(1, groups)
    assert cc.planner.plan_ticks == 0 and cc.chip_pressure == {}
    cc.rebalance(4, groups)
    assert cc.planner.plan_ticks == 1
    assert sorted(cc.chip_pressure) == [0, 1]


def test_controller_tracks_per_chip_pressure():
    cc = _controller()
    hot = [_RegionGroup(0, (4,), queue=[req(i, 40) for i in range(6)],
                        parts=[[req(10, 60, generated=1)] * 1]),
           _RegionGroup(1, (4,), parts=[[req(11, 60, generated=1)]])]
    cold = [_RegionGroup(2, (4,)), _RegionGroup(3, (4,))]
    cc.rebalance(0, hot + cold)
    p0, p1 = cc.chip_pressure[0], cc.chip_pressure[1]
    assert p0.fv.queue_frac > p1.fv.queue_frac
    assert p0.long_frac > p1.long_frac == 0.0
    d = p0.as_dict()
    assert {"divergence", "queue_frac", "drain_rate", "long_frac"} \
        <= set(d)


def test_controller_quarantine_maps_to_the_owning_chip():
    cc = _controller(quarantine=2)
    assert cc.chip_controllers[0].quarantine is None
    assert cc.chip_controllers[1].quarantine == 0    # local index on chip 1
    groups = [_RegionGroup(i, (4,)) for i in range(4)]
    groups[2].controller.state.topology = (3, 1)
    assert cc.reserved_parts(groups) == {(2, 1)}


def test_cluster_summary_shape():
    cc = _controller(region_gather=True)
    groups = [_RegionGroup(i, (4,)) for i in range(4)]
    cc.rebalance(0, groups)
    s = cc.cluster_summary(groups)
    assert s["chips"] == 2 and s["groups_per_chip"] == 2
    assert s["nodes"] == 1 and s["distance_blind"] is False
    assert set(s["tier_bytes"]) == {"noc", "link", "net"}
    assert "regions" in s and sorted(s["chip_pressure"]) == ["0", "1"]


# -- end to end ----------------------------------------------------------------

def _check_books(requests, eng):
    assert eng.completed == len(requests)
    assert all(r.done for r in requests)
    assert eng.useful_tokens == sum(len(r.generated) for r in requests)
    assert all(len(r.generated) == r.max_new_tokens for r in requests)


def test_cluster_engine_books_and_telemetry(setup):
    cfg, params = setup
    trace = multichip_imbalanced_trace(horizon=40, vocab_size=cfg.vocab_size,
                                       seed=0, chips=2, groups_per_chip=2)
    eng = ClusterEngine(cfg, params, fleet=FleetConfig(
        num_groups=4, capacity=4, router="sticky", mode="dynamic",
        rebalance_every=4, migrate=MigrationConfig(enabled=True),
        amoeba=AMOEBA, cluster=ClusterConfig(groups_per_chip=2)))
    eng.submit(trace)
    s = eng.run(max_ticks=3000)
    _check_books(trace, eng)
    # no request may end the run still in the air
    assert eng.planner.in_flight_requests() == []
    cl = s["cluster"]
    assert cl["chips"] == 2 and set(cl["tier_bytes"]) == {"noc", "link",
                                                          "net"}
    mig = s["migration"]
    assert mig["plan_ticks"] > 0
    assert mig["steals"] == mig["intra_chip_steals"] \
        + mig["cross_chip_steals"]
    assert s["wall_ticks"] >= max(r.finish for r in trace)


def test_cluster_engine_requires_dynamic_migrating_fleet(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="dynamic"):
        ClusterEngine(cfg, params, fleet=FleetConfig(
            num_groups=4, capacity=4, mode="fused", amoeba=AMOEBA))
