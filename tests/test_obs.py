"""repro.obs: event log, metrics, decision audit, exporters, reports.

Unit tests for the observability pipeline plus integration tests that
run real (vec) fleets with ``FleetConfig(obs=...)`` and assert the
acceptance properties: off-mode summaries carry no obs block, full-mode
traces round-trip through JSONL exactly, and the attribution table
answers "which decision preceded each topology change".
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import (AmoebaConfig, ClusterConfig, FleetConfig,
                                MigrationConfig)
from repro.control.features import ReplayBuffer
from repro.fleet.scheduler import FleetEngine
from repro.fleet.telemetry import FleetTelemetry, RollingWindow
from repro.fleet.traffic import TenantProfile, imbalanced_trace, make_trace
from repro.obs import (EVENT_KINDS, EventLog, MetricsRegistry, NULL_LOG,
                       attribution_rows, chrome_trace, decision_rows,
                       jsonable, misprediction_rate, read_jsonl,
                       render_attribution, render_mispredictions,
                       render_report, render_timeline, top_mispredictions,
                       verify_replay, write_chrome_trace, write_jsonl)
from repro.obs.metrics import Histogram


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-14b", reduced=True)
    return cfg


AMOEBA = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                      min_phase_steps=2)


def _fleet_cfg(obs, **kw):
    base = dict(num_groups=2, capacity=4, window=64, mode="dynamic",
                router="sticky", engine="vec",
                migrate=MigrationConfig(enabled=True), amoeba=AMOEBA,
                obs=obs)
    base.update(kw)
    return FleetConfig(**base)


def _run(cfg, fc, seed=5, horizon=40):
    eng = FleetEngine(cfg, None, fleet=fc)
    eng.submit(imbalanced_trace(horizon, cfg.vocab_size, seed=seed,
                                shards=fc.num_groups))
    return eng, eng.run()


# -- EventLog ------------------------------------------------------------------

def test_eventlog_off_is_inert():
    log = EventLog(mode="off")
    assert not log.enabled and not log.full
    log.emit("steal", gid=1, rid=7)
    assert log.total == 0 and len(log) == 0
    assert log.counts["steal"] == 0
    assert log is not NULL_LOG and not NULL_LOG.enabled


def test_eventlog_summary_counts_without_retention():
    log = EventLog(mode="summary")
    for _ in range(3):
        log.emit("reconfig", gid=0, to=(2, 2))
    log.emit("steal", gid=1)
    assert log.total == 4
    assert len(log) == 0                      # no ring in summary mode
    assert log.summary() == {
        "mode": "summary", "total_events": 4,
        "by_kind": {"reconfig": 3, "steal": 1}}


def test_eventlog_full_ring_and_payload_normalization():
    log = EventLog(mode="full")
    log.set_tick(9)
    log.emit("reconfig", gid=0, part=1,
             **{"from": (4,), "to": (np.int64(2), np.int64(2)),
                "gain": np.float32(0.25)})
    (e,) = log.events()
    assert (e.seq, e.tick, e.kind, e.gid, e.part) == (1, 9, "reconfig", 0, 1)
    # raw at emit time (hot path stores the dict as-is) ...
    assert e.payload["from"] == (4,)
    # ... tuples -> lists, numpy -> native on first view (JSONL fixed point)
    p = e.as_dict()["payload"]
    assert p["from"] == [4]
    assert p["to"] == [2, 2]
    assert isinstance(p["to"][0], int)
    assert isinstance(p["gain"], float)
    assert e.as_dict() == json.loads(json.dumps(e.as_dict()))
    log.emit("steal", gid=1, tick=11)          # explicit tick wins
    assert log.events("steal")[0].tick == 11
    assert log.count("steal") == 1 and log.total == 2


def test_eventlog_ring_bounded_counters_exact():
    log = EventLog(mode="full", capacity=4)
    for i in range(10):
        log.emit("stall", gid=0, tick=i, remaining=1)
    assert len(log) == 4 and log.total == 10 and log.dropped == 6
    assert [e.tick for e in log.events()] == [6, 7, 8, 9]
    s = log.summary()
    assert s["retained"] == 4 and s["dropped"] == 6
    log.clear()
    assert log.total == 0 and len(log) == 0 and log.dropped == 0


def test_eventlog_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown obs mode"):
        EventLog(mode="verbose")


def test_fleet_config_obs_validated(setup):
    cfg = setup
    with pytest.raises(ValueError, match="unknown obs mode"):
        FleetEngine(cfg, None, fleet=_fleet_cfg("loud"))


def test_jsonable_fixed_point():
    v = {"a": (1, np.int32(2)), "b": np.array([1.5, 2.5]),
         "c": [np.float64(0.5), {"d": (np.int64(3),)}]}
    j = jsonable(v)
    assert j == json.loads(json.dumps(j))
    assert j == {"a": [1, 2], "b": [1.5, 2.5], "c": [0.5, {"d": [3]}]}


# -- MetricsRegistry -----------------------------------------------------------

def test_histogram_log2_buckets():
    h = Histogram()
    for v in [0, 1, 2, 3, 4, 9]:
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 6 and s["min"] == 0 and s["max"] == 9
    # bucket b holds [2^(b-1), 2^b): 0->0, 1->1, {2,3}->2, 4->3, 9->4
    assert s["log2_buckets"] == {"0": 1, "1": 1, "2": 2, "3": 1, "4": 1}
    assert Histogram().snapshot() == {"count": 0}


def test_metrics_registry_sample_fleet():
    class _G:
        def __init__(self, q, live):
            self.queue = [None] * q
            self._live = live

        def live_count(self):
            return self._live

    class _Planner:
        tier_bytes = {"intra": 128, "inter": 64}

    m = MetricsRegistry()
    m.count("x")
    m.count("x", 2)
    m.sample_fleet(7, [_G(3, 2), _G(1, 1)], planner=_Planner())
    snap = m.snapshot()
    assert snap["counters"] == {"x": 3}
    assert snap["gauges"]["fleet.queue_depth"] == 4
    assert snap["gauges"]["fleet.live"] == 3
    assert snap["gauges"]["fleet.tick"] == 7
    assert snap["gauges"]["tier.inter.bytes"] == 64
    assert snap["histograms"]["fleet.live"]["count"] == 1
    assert snap == json.loads(json.dumps(snap))


# -- decision audit ------------------------------------------------------------

def _decision(tick, gid, proba, label, applied=True, seq=1):
    return {"seq": seq, "tick": tick, "kind": "policy_decision", "gid": gid,
            "part": None,
            "payload": {"from": [4], "target": [2, 2], "applied": applied,
                        "proba": proba, "gain": 0.1, "reason": "r",
                        "features": [0.5, 0.5], "replay_idx": seq - 1,
                        "label": label, "label_gain": 0.0}}


def test_decision_rows_and_mispredictions():
    events = [
        _decision(1, 0, proba=0.9, label=0.0, seq=1),   # confident, wrong
        _decision(2, 0, proba=0.6, label=1.0, seq=2),   # right
        _decision(3, 1, proba=0.3, label=1.0, seq=3),   # wrong, less sure
        {"seq": 4, "tick": 3, "kind": "steal", "gid": 1, "part": None,
         "payload": {}},                                 # ignored
    ]
    rows = decision_rows(events)
    assert len(rows) == 3
    assert [r["mispredicted"] for r in rows] == [True, False, True]
    assert misprediction_rate(rows) == pytest.approx(2 / 3)
    worst = top_mispredictions(rows, k=5)
    assert [r["tick"] for r in worst] == [1, 3]          # by confidence desc
    assert worst[0]["confidence"] == pytest.approx(0.4)


def test_decision_rows_unlabeled_kept_but_unscored():
    e = _decision(1, 0, proba=0.9, label=None)
    e["payload"].pop("label")
    e["payload"].pop("replay_idx")
    (row,) = decision_rows([e])
    assert row["mispredicted"] is None and row["confidence"] is None
    assert misprediction_rate([row]) is None


def test_verify_replay_checks_and_skips_evicted():
    replay = ReplayBuffer(maxlen=2)
    idxs = [replay.add(np.zeros(4), float(y)) for y in (1.0, 0.0, 1.0)]
    assert idxs == [0, 1, 2] and replay.total_added == 3
    rows = [{"replay_idx": i, "label": lab}
            for i, lab in zip(idxs, (1.0, 0.0, 1.0))]
    # idx 0 was evicted by the bounded buffer -> skipped, 2 checked
    assert verify_replay(rows, replay) == 2
    rows[2]["label"] = 0.0
    with pytest.raises(AssertionError, match="audit/replay mismatch"):
        verify_replay(rows, replay)


# -- an observed run: summary plumbing + exporters -----------------------------

def test_off_mode_summary_has_no_obs_block(setup):
    _, s = _run(setup, _fleet_cfg("off"))
    assert "obs" not in s
    assert s["completed"] == s["submitted"]


def test_summary_mode_counts_only(setup):
    _, s = _run(setup, _fleet_cfg("summary"))
    obs = s["obs"]
    assert obs["mode"] == "summary" and obs["total_events"] > 0
    assert "retained" not in obs and "metrics" not in obs
    assert obs["by_kind"].keys() <= set(EVENT_KINDS)


def test_off_and_observed_summaries_agree(setup):
    """Turning observability on must not perturb the run itself."""
    _, s_off = _run(setup, _fleet_cfg("off"))
    _, s_full = _run(setup, _fleet_cfg("full"))
    s_full = dict(s_full)
    s_full.pop("obs")
    for s in (s_off, s_full):
        s.pop("wall_s")
        s.pop("ticks_per_sec")
    assert s_off == s_full


def test_full_mode_trace_and_metrics(setup):
    eng, s = _run(setup, _fleet_cfg("full"))
    obs = s["obs"]
    assert obs["mode"] == "full"
    assert obs["retained"] == len(eng.obs.events())
    assert sum(obs["by_kind"].values()) == obs["total_events"]
    m = obs["metrics"]
    assert m["gauges"]["fleet.tick"] == s["wall_ticks"] - 1
    assert m["histograms"]["fleet.queue_depth"]["count"] > 0
    # every event is tick-stamped within the run and well-formed
    for e in eng.obs.events():
        assert e.kind in EVENT_KINDS
        assert 0 <= e.tick < s["wall_ticks"]


def test_jsonl_roundtrip_exact(setup, tmp_path):
    eng, _ = _run(setup, _fleet_cfg("full"))
    path = str(tmp_path / "trace.jsonl")
    n = write_jsonl(path, eng.obs.events(), meta=eng.obs.meta)
    meta, events = read_jsonl(path)
    assert n == len(events) == len(eng.obs.events())
    assert meta == eng.obs.meta
    assert events == [e.as_dict() for e in eng.obs.events()]
    # and the file is the fixed point of parse -> re-serialize
    rebuilt = [json.dumps({"kind": "_meta", **meta}, sort_keys=True)]
    rebuilt += [json.dumps(jsonable(e), sort_keys=True) for e in events]
    with open(path) as f:
        original = [ln.strip() for ln in f if ln.strip()]
    assert original == rebuilt


def test_chrome_trace_structure(setup, tmp_path):
    eng, s = _run(setup, _fleet_cfg("full"))
    trace = chrome_trace(eng.obs.events(), meta=eng.obs.meta)
    evs = trace["traceEvents"]
    by_ph = {}
    for e in evs:
        by_ph.setdefault(e["ph"], []).append(e)
    # thread metadata for every group that emitted
    names = {e["args"]["name"] for e in by_ph["M"]}
    assert {"group 0", "group 1"} <= names
    # topology spans tile [0, wall) per group, in order, no overlap
    for g in (0, 1):
        spans = sorted((e for e in by_ph["X"] if e["tid"] == g),
                       key=lambda e: e["ts"])
        assert spans and spans[0]["ts"] == 0
        for a, b in zip(spans, spans[1:]):
            assert a["ts"] + a["dur"] == b["ts"]
        assert "+" in spans[0]["name"] or spans[0]["name"].isdigit()
    # steal/migrate flows come in s/f pairs sharing an id
    starts = {e["id"] for e in by_ph.get("s", [])}
    ends = {e["id"] for e in by_ph.get("f", [])}
    assert starts and starts == ends
    out = str(tmp_path / "chrome.json")
    assert write_chrome_trace(out, eng.obs.events(), eng.obs.meta) == len(evs)
    with open(out) as f:
        assert json.load(f)["traceEvents"] == evs


def test_attribution_answers_which_decision_preceded_each_reconfig(setup):
    """Acceptance: every applied topology change joins back to the
    policy_decision that caused it, with features/prediction attached."""
    fc = _fleet_cfg("full", amoeba=AMOEBA.replace(policy="online"))
    eng, s = _run(setup, fc, horizon=60)
    rows = attribution_rows(eng.obs.events())
    assert rows, "run produced no reconfigs"
    for r in rows:
        assert r["decision_tick"] is not None
        assert r["decision_tick"] <= r["tick"]
        assert r["proba"] is not None
        assert isinstance(r["features"], list) and r["features"]
        assert r["from"] != r["to"]
    # the decision the reconfig joins to proposed exactly that target
    decisions = {(e.gid, e.tick): e for e in eng.obs.events("policy_decision")}
    for r in rows:
        d = decisions[(r["gid"], r["decision_tick"])]
        assert d.payload["applied"]
        assert d.payload["target"] == r["to"]
    # audit labels cross-check against the live replay buffer
    checked = verify_replay(decision_rows(
        e.as_dict() for e in eng.obs.events()), eng.policy.replay)
    assert checked > 0


def test_text_reports_render(setup):
    eng, _ = _run(setup, _fleet_cfg(
        "full", amoeba=AMOEBA.replace(policy="online")), horizon=60)
    events = eng.obs.events()
    tl = render_timeline(events, limit=10)
    assert len(tl.splitlines()) == 11 and "more events" in tl.splitlines()[-1]
    attr = render_attribution(events)
    assert attr.splitlines()[0].startswith("tick") and "->" in attr
    assert "misprediction rate" in render_mispredictions(events, k=3)
    report = render_report(events, meta=eng.obs.meta, timeline_limit=5)
    for section in ("== meta ==", "== timeline ==",
                    "== decisions preceding each topology change ==",
                    "== top-10 mispredictions =="):
        assert section in report
    assert render_attribution([]) == "(no reconfigs in trace)"
    assert "no labeled decisions" in render_mispredictions([])


def test_cluster_trace_carries_mesh_and_region_events(setup):
    from repro.cluster import ClusterEngine
    from repro.fleet.traffic import multichip_imbalanced_trace
    cfg = setup
    fc = _fleet_cfg("full", num_groups=4, rebalance_every=4,
                    cluster=ClusterConfig(groups_per_chip=2))
    eng = ClusterEngine(cfg, None, fleet=fc)
    eng.submit(multichip_imbalanced_trace(
        40, cfg.vocab_size, seed=5, chips=2, groups_per_chip=2))
    eng.run()
    mesh = eng.obs.meta["mesh"]
    assert mesh["num_groups"] == 4
    assert set(mesh["chip_of"]) == {"0", "1", "2", "3"}   # string keys
    # chips become Perfetto processes
    trace = chrome_trace(eng.obs.events(), meta=eng.obs.meta)
    procs = {e["args"]["name"] for e in trace["traceEvents"]
             if e.get("name") == "process_name"}
    assert procs == {"chip 0", "chip 1"}


# -- telemetry satellites ------------------------------------------------------

def test_rolling_window_push_gap_carries_boundary():
    """Regression: idle gaps must push a flat boundary sample so the
    post-gap rate is computed over the true span, not a stale window."""
    w = RollingWindow(window=10)
    w.push(0, 0.0)
    w.push(4, 40.0)
    w.push_gap(100)                   # idle ticks 5..104: counter is flat
    assert w._samples[-1] == (104, 40.0)
    assert w.rate() == 0.0            # pre-gap samples expired -> flat
    w.push(105, 45.0)
    assert w.rate() == pytest.approx(5.0)
    # no-ops: zero-length gap, and a gap before any sample
    w2 = RollingWindow(window=10)
    w2.push_gap(8)
    assert not w2._samples
    w2.push(0, 1.0)
    w2.push_gap(0)
    assert len(w2._samples) == 1


def test_telemetry_idle_gap_updates_rate_windows():
    class _Stats:
        useful_tokens = 30
        completed = 3

    class _G:
        stats = _Stats()
        queue = ()

    t = FleetTelemetry(window=16)
    t.on_tick(0, [_G()], ticked=1)
    t.on_idle_gap(50, 1)
    assert t.tokens_window._samples[-1] == (50, 30.0)
    assert t.done_window._samples[-1] == (50, 3.0)
    assert t.tokens_window.rate() == 0.0


def _summary_fixture(requests):
    class _Stats:
        ticks = slot_steps = useful_tokens = completed = 0
        splits = fuses = resizes = stall_ticks = 0
        steals_in = steals_out = migrations_in = migrations_out = 0
        leases_out = leases_in = 0
        efficiency = 0.0

    class _G:
        gid, mode, is_split = 0, "fused", False
        queue = ()
        stats = _Stats()

        def live_requests(self):
            return []

    t = FleetTelemetry()
    t.on_tick(0, [_G()], ticked=1)
    return t, [_G()]


def test_summary_single_tenant_has_no_per_tenant_block():
    from repro.serve.engine import Request
    reqs = [Request(rid=i, prompt=[1], max_new_tokens=2, tenant="only")
            for i in range(3)]
    t, groups = _summary_fixture(reqs)
    s = t.summary(groups, reqs)
    assert "per_tenant" not in s
    reqs2 = reqs + [Request(rid=9, prompt=[1], max_new_tokens=2, tenant="b")]
    s2 = t.summary(groups, reqs2)
    assert set(s2["per_tenant"]) == {"only", "b"}


def test_summary_empty_latency_run_is_zero_not_nan():
    t, groups = _summary_fixture([])
    s = t.summary(groups, [])
    assert s["latency"] == {"mean": 0.0, "p50": 0.0, "p95": 0.0,
                            "p99": 0.0, "max": 0.0}
    assert s["completed"] == 0 and s["submitted"] == 0


def test_summary_router_state_spills_plumb_through():
    t, groups = _summary_fixture([])
    s = t.summary(groups, [], router_state={"planner": object(), "spills": 4})
    assert s["control"]["admission_spills"] == 4
    s2 = t.summary(groups, [], router_state={"spills": 4})   # no planner
    assert "admission_spills" not in s2["control"]
