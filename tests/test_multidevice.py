"""Real multi-device correctness (8 host CPU devices in a subprocess).

The dry-run proves lowering; this proves NUMERICS: the sharded production
paths (MoE shard_map, seq-sharded decode attention, pjit train step) must
produce the same values as the single-device oracle.
"""
import os
import subprocess
import sys

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.models import transformer as T
from repro.parallel import shardctx, resolve
from repro.train import Trainer

assert len(jax.devices()) == 8
mesh = jax.make_mesh((2, 4), ("data", "model"))

# --- MoE: sharded path on a real mesh == dense oracle --------------------
cfg = get_config("deepseek-moe-16b", reduced=True).replace(dtype="float32")
import dataclasses
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                      cfg.vocab_size)}
l_oracle, _ = T.loss_fn(params, batch, cfg,
                        T.Runtime(production=False, remat=False))
with shardctx.use_mesh(mesh):
    l_prod, _ = jax.jit(lambda p, b: T.loss_fn(
        p, b, cfg, T.Runtime(production=True, remat=False)))(params, batch)
err = abs(float(l_oracle) - float(l_prod))
assert err < 2e-3, ("moe sharded-vs-dense", err)
print("moe ok", err)

# --- decode: seq-sharded KV attention == unsharded ------------------------
cfg2 = get_config("qwen3-14b", reduced=True).replace(dtype="float32")
params2, _ = T.init_model(jax.random.PRNGKey(0), cfg2)
rt = T.Runtime(production=False, remat=False)
toks = jax.random.randint(jax.random.PRNGKey(2), (4, 24), 0, cfg2.vocab_size)
lg, st = T.prefill(params2, {"tokens": toks}, cfg2, rt, window=32)
lg1, st1 = T.decode_step(params2, st, toks[:, :1], cfg2, rt)
with shardctx.use_mesh(mesh):
    rtp = T.Runtime(production=True, remat=False)
    lg_m, st_m = T.prefill(params2, {"tokens": toks}, cfg2, rtp, window=32)
    lg1_m, _ = T.decode_step(params2, st_m, toks[:, :1], cfg2, rtp)
err = float(jnp.max(jnp.abs(lg1 - lg1_m)))
assert err < 2e-3, ("decode sharded-vs-dense", err)
print("decode ok", err)

# --- trainer step under pjit mesh == single device -------------------------
shape = ShapeConfig("t", 32, 4, "train")
tcfg = TrainConfig(total_steps=3, warmup_steps=1, learning_rate=1e-3)
t_single = Trainer(cfg2, shape, tcfg,
                   rt=T.Runtime(production=False, remat=True))
h1 = t_single.train(3)["history"]
t_mesh = Trainer(cfg2, shape, tcfg, mesh=mesh,
                 rt=T.Runtime(production=True, remat=True))
h2 = t_mesh.train(3)["history"]
for a, b in zip(h1, h2):
    assert abs(a.loss - b.loss) < 2e-3, (a.step, a.loss, b.loss)
print("trainer ok", [round(m.loss, 4) for m in h2])

# --- compressed all-reduce on a real data axis ------------------------------
from repro.parallel import compression as C
from functools import partial
g = jax.random.normal(jax.random.PRNGKey(3), (8, 16, 64), jnp.float32)
def body(gl):
    mean, res = C.compressed_psum_mean({"g": gl}, "data")
    return mean["g"], res["g"]
mean, res = jax.jit(shardctx.shard_map(
    body, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
    check_vma=False))(g)
# compare against the true mean over the data axis shards
gs = g.reshape(2, 4, 16, 64)
true = jnp.mean(gs, axis=0, keepdims=True)
true = jnp.broadcast_to(true, gs.shape).reshape(8, 16, 64)
err = float(jnp.max(jnp.abs(mean - true)))
bound = float(jnp.max(jnp.abs(g))) / 127.0 * 1.5
assert err <= bound, (err, bound)
print("compression ok", err)
print("ALL-MULTIDEVICE-OK")
"""


def test_multidevice_numerics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", CHILD], env=env,
                       capture_output=True, text=True, timeout=1200)
    assert "ALL-MULTIDEVICE-OK" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
