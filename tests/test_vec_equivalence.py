"""Object-engine vs vec-engine equivalence: bit-identical summaries.

The vectorized core (``repro.fleet.vec``) shares the object engine's
entire control plane — admission scan, controller/policy stack, routers,
migration planning, telemetry — and replaces only the data plane
(per-token decode loops) with masked array updates.  Scheduling is
independent of generated token *values* (one token per live request per
tick), so the two engines must produce *identical* summaries, not merely
similar ones: completed counts, latency percentiles, utilization,
steal/migration counters, per-group stats, everything except wall-clock
timing.  These tests assert exactly that, over deterministic seeded
traces here and over randomized traces in the hypothesis suite below.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import (AmoebaConfig, ClusterConfig, FleetConfig,
                                LeaseConfig, MigrationConfig)
from repro.fleet.scheduler import FleetEngine
from repro.fleet.traffic import (TenantProfile, imbalanced_trace,
                                 make_trace, skewed_longtail_trace,
                                 transient_burst_trace)
from repro.fleet.vec import TrackedQueue
from repro.models import transformer as T
from repro.serve.engine import Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-14b", reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


AMOEBA = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                      min_phase_steps=2)

PROFILES = [
    TenantProfile("short", rate=1.2, length_dist="uniform", mean_tokens=6,
                  min_tokens=2, max_tokens=10, prompt_lengths=(8,)),
    TenantProfile("long", rate=0.4, length_dist="uniform", mean_tokens=32,
                  min_tokens=24, max_tokens=40, prompt_lengths=(16,)),
]


def scrub(summary):
    """Drop the only legitimately engine-dependent keys (wall timing)."""
    s = dict(summary)
    s.pop("wall_s")
    s.pop("ticks_per_sec")
    return s


def deep_diff(a, b, path=""):
    out = []
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            if k not in a or k not in b:
                out.append(f"{path}.{k}: present in only one summary")
            else:
                out += deep_diff(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        if len(a) != len(b):
            out.append(f"{path}: len {len(a)} vs {len(b)}")
        else:
            for i, (x, y) in enumerate(zip(a, b)):
                out += deep_diff(x, y, f"{path}[{i}]")
    elif a != b:
        out.append(f"{path}: {a!r} vs {b!r}")
    return out


def run_pair(cfg, params, fleet_cfg, trace_fn, max_ticks=1_000_000):
    """Run the same trace through both engines; return (obj, vec) summaries."""
    eng_o = FleetEngine(cfg, params, fleet=fleet_cfg)
    eng_v = FleetEngine(cfg, None, fleet=fleet_cfg.replace(engine="vec"))
    eng_o.submit(trace_fn())
    eng_v.submit(trace_fn())
    s_o = eng_o.run(max_ticks=max_ticks)
    s_v = eng_v.run(max_ticks=max_ticks)
    eng_v._vec.check(eng_v.groups)     # SoA invariants hold at the end
    return s_o, s_v


def assert_identical(s_o, s_v):
    diffs = deep_diff(scrub(s_o), scrub(s_v))
    assert not diffs, "summaries diverge:\n" + "\n".join(diffs[:20])


# -- deterministic seeded equivalence ------------------------------------------

CASES = {
    "static_fused": FleetConfig(num_groups=2, capacity=4, window=64,
                                mode="fused", amoeba=AMOEBA),
    "static_split_rr": FleetConfig(num_groups=2, capacity=4, window=64,
                                   mode="split", router="round_robin",
                                   amoeba=AMOEBA),
    "dynamic_least_loaded": FleetConfig(num_groups=2, capacity=4, window=64,
                                        mode="dynamic", amoeba=AMOEBA),
    "dynamic_length_aware_mix": FleetConfig(
        num_groups=3, capacity=4, window=64, mode="dynamic",
        router="length_aware", rebalance_every=8, amoeba=AMOEBA),
    "dynamic_hetero": FleetConfig(
        num_groups=2, capacity=6, window=64, mode="dynamic",
        router="length_aware",
        amoeba=AMOEBA.replace(hetero=True, max_ways=3)),
    "migration_sticky": FleetConfig(
        num_groups=2, capacity=4, window=64, mode="dynamic",
        router="sticky", migrate=MigrationConfig(enabled=True),
        amoeba=AMOEBA),
    "quarantine": FleetConfig(
        num_groups=2, capacity=4, window=64, mode="dynamic",
        router="length_aware", quarantine_group=0, amoeba=AMOEBA),
    # slack leases move admission capacity between parts; grants, early
    # revokes and reconfig force-revokes all live in shared control-plane
    # code, so summaries (incl. the lease block) stay bit-identical
    "lease_sticky": FleetConfig(
        num_groups=2, capacity=4, window=64, mode="dynamic",
        router="sticky", migrate=MigrationConfig(enabled=True),
        lease=LeaseConfig(enabled=True), amoeba=AMOEBA),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_summary_identical(setup, case):
    cfg, params = setup
    fc = CASES[case]
    if case == "migration_sticky":
        def trace():
            return imbalanced_trace(40, cfg.vocab_size, seed=5,
                                    shards=fc.num_groups)
    elif case == "lease_sticky":
        def trace():
            return transient_burst_trace(48, cfg.vocab_size, seed=5,
                                         shards=fc.num_groups,
                                         burst_len=16)
    else:
        def trace():
            return make_trace(PROFILES, horizon=30,
                              vocab_size=cfg.vocab_size, seed=3)
    s_o, s_v = run_pair(cfg, params, fc, trace)
    assert_identical(s_o, s_v)
    assert s_o["completed"] == s_o["submitted"]


def test_summary_identical_under_tick_cutoff(setup):
    """Truncated runs (trace not drained) agree too — partial state is
    finalized identically by both engines."""
    cfg, params = setup
    fc = CASES["dynamic_least_loaded"]
    def trace():
        return skewed_longtail_trace(30, cfg.vocab_size, seed=7)
    s_o, s_v = run_pair(cfg, params, fc, trace, max_ticks=25)
    assert_identical(s_o, s_v)
    assert s_o["completed"] < s_o["submitted"]


def test_cluster_engine_identical(setup):
    """The hierarchical cluster engine inherits vec support."""
    from repro.cluster.engine import ClusterEngine
    from repro.fleet.traffic import multichip_imbalanced_trace
    cfg, params = setup
    fc = FleetConfig(num_groups=4, capacity=4, window=64, mode="dynamic",
                     router="sticky", migrate=MigrationConfig(enabled=True),
                     amoeba=AMOEBA,
                     cluster=ClusterConfig(groups_per_chip=2))
    def trace():
        return multichip_imbalanced_trace(30, cfg.vocab_size, seed=11,
                                          chips=2, groups_per_chip=2)
    eng_o = ClusterEngine(cfg, params, fleet=fc)
    eng_v = ClusterEngine(cfg, None, fleet=fc.replace(engine="vec"))
    eng_o.submit(trace())
    eng_v.submit(trace())
    s_o, s_v = eng_o.run(), eng_v.run()
    eng_v._vec.check(eng_v.groups)
    assert_identical(s_o, s_v)


def test_event_streams_identical(setup):
    """obs='full': both engines must emit the *same events in the same
    order* — every emission site lives in shared control-plane code, so
    the streams are bit-identical, not merely equal in aggregate."""
    cfg, params = setup
    fc = CASES["migration_sticky"].replace(obs="full")
    def trace():
        return imbalanced_trace(40, cfg.vocab_size, seed=5,
                                shards=fc.num_groups)
    eng_o = FleetEngine(cfg, params, fleet=fc)
    eng_v = FleetEngine(cfg, None, fleet=fc.replace(engine="vec"))
    eng_o.submit(trace())
    eng_v.submit(trace())
    s_o, s_v = eng_o.run(), eng_v.run()
    ev_o = [e.as_dict() for e in eng_o.obs.events()]
    ev_v = [e.as_dict() for e in eng_v.obs.events()]
    assert len(ev_o) == len(ev_v)
    diffs = deep_diff(ev_o, ev_v)
    assert not diffs, "event streams diverge:\n" + "\n".join(diffs[:20])
    assert len(ev_o) > 0 and {e["kind"] for e in ev_o} >= {
        "admission", "reconfig", "policy_decision"}
    # the obs summary block rides along and agrees too
    assert_identical(s_o, s_v)
    assert s_o["obs"]["by_kind"] == s_v["obs"]["by_kind"]


# -- vec internals --------------------------------------------------------------

def test_vec_accepts_none_params(setup):
    """The vec engine never touches model params — params=None works."""
    cfg, _ = setup
    eng = FleetEngine(cfg, None, fleet=FleetConfig(
        num_groups=2, capacity=4, engine="vec", amoeba=AMOEBA))
    eng.submit([Request(rid=i, prompt=[1] * 8, max_new_tokens=5)
                for i in range(10)])
    s = eng.run()
    assert s["completed"] == 10
    assert s["wall_s"] >= 0 and s["ticks_per_sec"] > 0


def test_engine_knob_validated(setup):
    cfg, _ = setup
    with pytest.raises(ValueError, match="unknown engine"):
        FleetEngine(cfg, None, fleet=FleetConfig(engine="simd"))


def test_tracked_queue_budget():
    reqs = [Request(rid=i, prompt=[1], max_new_tokens=n)
            for i, n in enumerate([3, 7, 11, 2])]
    q = TrackedQueue(reqs)
    assert q.budget == 23
    q.popleft()
    assert q.budget == 20
    del q[1]                       # the planner's steal path
    assert q.budget == 9
    q.appendleft(reqs[0])
    assert q.budget == 12
    q.remove(reqs[0])
    assert q.budget == 9
    q.pop()
    assert q.budget == 7
    q.clear()
    assert q.budget == 0


def test_submit_normalizes_arrival_without_delivery_mutation(setup):
    """Satellite fix: negative arrivals are clamped at submit time, and
    _deliver no longer rewrites request fields — a trace object seen by
    the router is exactly the one the caller submitted."""
    cfg, _ = setup
    eng = FleetEngine(cfg, None, fleet=FleetConfig(
        num_groups=1, capacity=4, engine="vec", amoeba=AMOEBA))
    r = Request(rid=0, prompt=[1] * 4, max_new_tokens=3, arrival=-5)
    eng.submit([r])
    assert r.arrival == 0          # normalized at the submission boundary
    s = eng.run()
    assert s["completed"] == 1
    assert r.finish is not None and r.latency == r.finish + 1


# -- hypothesis property suite ---------------------------------------------------
# hypothesis is a [test]-extra dependency; the deterministic suite above
# must run even where it is absent, so only this block is conditional.

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:               # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def small_traces(draw):
        n = draw(st.integers(min_value=1, max_value=14))
        reqs = []
        for i in range(n):
            reqs.append(Request(
                rid=i,
                prompt=[1] * draw(st.sampled_from([4, 8])),
                max_new_tokens=draw(st.integers(min_value=1, max_value=30)),
                arrival=draw(st.integers(min_value=0, max_value=20)),
                shard=draw(st.one_of(st.none(),
                                     st.integers(min_value=0, max_value=3))),
            ))
        return reqs

    @st.composite
    def fleet_configs(draw):
        groups = draw(st.integers(min_value=1, max_value=3))
        mode = draw(st.sampled_from(["fused", "split", "dynamic"]))
        kw = dict(
            num_groups=groups, capacity=4, window=64, mode=mode,
            router=draw(st.sampled_from(
                ["round_robin", "least_loaded", "length_aware", "sticky"])),
            amoeba=AMOEBA.replace(hetero=draw(st.booleans())),
        )
        if mode == "dynamic":
            if draw(st.booleans()):
                kw["migrate"] = MigrationConfig(enabled=True)
            if groups > 1 and draw(st.booleans()):
                kw["quarantine_group"] = draw(
                    st.integers(min_value=0, max_value=groups - 1))
        return FleetConfig(**kw)

    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(trace=small_traces(), fc=fleet_configs())
    def test_property_identical(setup, trace, fc):
        import copy
        cfg, params = setup
        eng_o = FleetEngine(cfg, params, fleet=fc)
        eng_v = FleetEngine(cfg, None, fleet=fc.replace(engine="vec"))
        eng_o.submit(copy.deepcopy(trace))
        eng_v.submit(copy.deepcopy(trace))
        s_o = eng_o.run(max_ticks=500)
        s_v = eng_v.run(max_ticks=500)
        eng_v._vec.check(eng_v.groups)
        assert_identical(s_o, s_v)
