"""Config registry + analytic parameter accounting."""
import jax
import pytest

from repro.configs import (ARCH_IDS, LM_SHAPES, all_cells, get_config,
                           shape_applicable)
from repro.models import transformer as T


def test_registry_has_all_ten():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.name == a


def test_forty_cells():
    cells = all_cells()
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok in cells if not ok]
    # long_500k skips exactly the pure-full-attention archs
    assert all(s == "long_500k" for _, s in skipped)
    runs_long = {a for a, s, ok in cells if s == "long_500k" and ok}
    assert runs_long == {"recurrentgemma-9b", "falcon-mamba-7b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_implementation(arch):
    cfg = get_config(arch, reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    assert T.count_params(params) == cfg.param_count()


def test_full_scale_param_counts_sane():
    # headline sizes within 25% of the nameplate (names are nominal)
    expect = {"nemotron-4-340b": 341e9, "arctic-480b": 482e9,
              "falcon-mamba-7b": 7.3e9, "qwen3-14b": 14.8e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.25, (arch, got)


def test_moe_active_params_smaller():
    for arch in ("deepseek-moe-16b", "arctic-480b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_shape_applicability():
    cfg = get_config("qwen3-14b")
    long = [s for s in LM_SHAPES if s.name == "long_500k"][0]
    assert not shape_applicable(cfg, long)
    assert shape_applicable(get_config("falcon-mamba-7b"), long)
