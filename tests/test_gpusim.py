"""Faithful-reproduction targets: the paper's §5 headline numbers."""
import numpy as np
import pytest

from repro.core.gpusim import (SCHEMES, WORKLOADS, profile_features,
                               run_all, run_benchmark)
from repro.core.gpusim.sim import FUSED, QSPLIT


@pytest.fixture(scope="module")
def results():
    return {s: run_all(s) for s in SCHEMES}


def _speedups(results, scheme):
    base = results["baseline"]
    return {n: results[scheme][n].ipc / base[n].ipc for n in WORKLOADS}


def test_sm_speedup_headline(results):
    """Paper: SM reaches 4.25x (cache-capacity bound)."""
    sp = _speedups(results, "warp_regroup")["SM"]
    assert 3.8 <= sp <= 4.8, sp


def test_mum_speedup_headline(results):
    """Paper: MUM 2.11x."""
    sp = _speedups(results, "warp_regroup")["MUM"]
    assert 1.8 <= sp <= 2.5, sp


def test_geomean_near_47_percent(results):
    """Paper: ~47% average IPC gain for AMOEBA."""
    sp = list(_speedups(results, "warp_regroup").values())
    geo = float(np.exp(np.mean(np.log(sp))))
    assert 1.30 <= geo <= 1.60, geo


def test_scheme_ordering(results):
    """warp_regroup >= direct_split >= static-ish >= baseline on geomean."""
    geo = {}
    for s in ("static_fuse", "direct_split", "warp_regroup", "dws"):
        sp = list(_speedups(results, s).values())
        geo[s] = float(np.exp(np.mean(np.log(sp))))
    assert geo["warp_regroup"] >= geo["direct_split"] >= geo["static_fuse"] \
        - 1e-9
    assert geo["warp_regroup"] > geo["dws"]          # paper Fig 21


def test_amoeba_beats_dws(results):
    """Paper: +27% over DWS on average; SM ~3.97x over DWS."""
    wr = _speedups(results, "warp_regroup")
    dws = _speedups(results, "dws")
    ratio = float(np.exp(np.mean(np.log([wr[n] / dws[n] for n in WORKLOADS]))))
    assert ratio > 1.2, ratio
    assert wr["SM"] / dws["SM"] > 3.5


def test_scale_out_benchmarks_not_fused(results):
    """CP/3MM prefer scale-out; static prediction avoids the fuse loss."""
    su = _speedups(results, "scale_up")
    st = _speedups(results, "static_fuse")
    for name in ("CP", "3MM"):
        assert su[name] < 1.0
        assert st[name] >= su[name]


def test_insensitive_benchmarks(results):
    for name in ("FWT", "KM"):
        assert abs(_speedups(results, "warp_regroup")[name] - 1.0) < 0.1


def test_fuse_split_dynamics_fig19(results):
    """RAY toggles between fused and split states, per-pair independently."""
    tr = results["warp_regroup"]["RAY"].trace
    assert (tr == FUSED).any() and (tr == QSPLIT).any()
    # heterogeneity: some epochs have BOTH states simultaneously
    both = ((tr == FUSED).any(axis=1) & (tr == QSPLIT).any(axis=1))
    assert both.mean() > 0.2


def test_l1_miss_reduced_by_fusion(results):
    """Paper Fig 15: SM's L1D miss rate drops >50% under AMOEBA."""
    base = results["baseline"]["SM"].l1d_miss
    fused = results["warp_regroup"]["SM"].l1d_miss
    assert fused < 0.5 * base


def test_actual_memory_access_rate_reduced(results):
    """Paper Fig 16: coalescing across the fused pair cuts actual accesses."""
    for name in ("SM", "MUM"):
        assert results["warp_regroup"][name].actual_mem_rate < \
            results["baseline"][name].actual_mem_rate


def test_profile_features_shape():
    f = profile_features(WORKLOADS["SM"])
    assert f.shape == (11,)
    assert np.all(np.isfinite(f))
