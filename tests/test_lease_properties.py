"""Hypothesis properties for the slack-lease planner.

Follows the repo's importorskip pattern (cf. test_migrate_properties.py);
the same contracts are pinned with concrete cases in test_lease.py,
which always runs.  The fuzzed invariant is the ISSUE's conservation
contract: leases conserve slot budgets — at every step, each part's
``lent + resident`` equals its partition budget (fleet-wide effective
capacity never changes), the planner's book agrees exactly with every
group's counters, no lease outlives its term, and a force-revoke (the
reconfiguration boundary) leaves zero slots leaked.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from fake_fleet import FakeGroup
from repro.configs.base import LeaseConfig
from repro.fleet.lease import LeasePlanner
from repro.serve.engine import Request


def _req(rid, tokens, started=False):
    r = Request(rid, [1, 2, 3], tokens)
    if started:
        r.generated = [0]
    return r


@st.composite
def lease_fleets(draw):
    n_groups = draw(st.integers(2, 4))
    rid = iter(range(100_000))
    groups = []
    for gi in range(n_groups):
        topo = tuple(draw(st.lists(st.integers(2, 5),
                                   min_size=1, max_size=3)))
        parts = []
        for slots in topo:
            k = draw(st.integers(0, slots))
            parts.append([_req(next(rid), draw(st.integers(2, 40)), True)
                          for _ in range(k)])
        queue = [_req(next(rid), draw(st.integers(1, 40)))
                 for _ in range(draw(st.integers(0, 8)))]
        groups.append(FakeGroup(gi, topo, queue=queue, parts=parts))
    return groups


def _assert_conserved(p, groups):
    total_budget = total_eff = 0
    for gi, g in enumerate(groups):
        for i, slots in enumerate(g.topology):
            # the planner's book is the single source of truth and the
            # group counters must mirror it exactly
            assert g._lent[i] == p.lent_at((gi, i)) >= 0
            assert g._borrowed[i] == p.borrowed_at((gi, i)) >= 0
            # lent + resident = partition budget, with >= 1 resident
            resident = slots - g._lent[i]
            assert resident + g._lent[i] == slots
            assert resident + g._borrowed[i] >= 1
            total_budget += slots
            total_eff += g.effective_slots(i)
    # fleet-wide effective capacity is conserved by every grant/return
    assert total_eff == total_budget


@given(lease_fleets(),
       st.lists(st.tuples(st.integers(0, 3),      # queue churn target
                          st.integers(0, 8),      # new queue length
                          st.integers(0, 30),     # completions added
                          st.booleans()),         # force-revoke it too?
               min_size=1, max_size=12),
       st.integers(1, 16), st.floats(0.1, 1.0))
@settings(max_examples=60, deadline=None)
def test_leases_conserve_slot_budgets(groups, churn, max_term, max_frac):
    p = LeasePlanner(LeaseConfig(enabled=True, max_term=max_term,
                                 max_frac=max_frac, max_grants=4))
    p.bind(groups)
    rid = iter(range(200_000, 300_000))
    tick = 0
    for target, qlen, done, revoke in churn:
        gi = target % len(groups)
        g = groups[gi]
        g.queue.clear()
        g.queue.extend(_req(next(rid), 8) for _ in range(qlen))
        g.stats.completed += done
        if revoke:
            p.force_revoke(gi)
            assert not any(l.lender[0] == gi or l.borrower[0] == gi
                           for l in p.active)
            assert all(x == 0 for x in g._lent)
            assert all(x == 0 for x in g._borrowed)
        p.step(tick, groups)
        _assert_conserved(p, groups)
        # no lease outlives its term
        assert all(l.expires > tick for l in p.active)
        assert all(l.slots > 0 for l in p.active)
        tick += 3
    # drain: once every queue is empty, every lease comes home (idle
    # borrowers are revoked, stragglers expire) — no slot leaks
    for g in groups:
        g.queue.clear()
    for _ in range(2):
        p.step(tick, groups)
        tick += max_term + 1
    assert p.active == []
    for g in groups:
        assert all(x == 0 for x in g._lent), (g.gid, g._lent)
        assert all(x == 0 for x in g._borrowed), (g.gid, g._borrowed)
    # the grant ledger balances: everything granted was returned
    assert p.grants == p.revokes + p.expires
    # and the zero-stall contract held throughout
    assert p.stall_ticks_charged == 0
