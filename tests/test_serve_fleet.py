"""Accounting invariants for ServeEngine and the fleet scheduler.

Whatever the grouping policy does, the books must balance: every
submitted request completes exactly once, every generated token is
counted exactly once, and completion stamps are consistent with the wall
clock.  These invariants pin the ReconfigurableGroup refactor and the
FleetEngine on top of it.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import AmoebaConfig, FleetConfig
from repro.fleet import (FleetEngine, ROUTERS, RollingWindow, TenantProfile,
                         bursty_longtail_trace, make_trace)
from repro.fleet.scheduler import route_length_aware
from repro.fleet.telemetry import FleetTelemetry
from repro.models import transformer as T
from repro.serve import ReconfigurableGroup, Request, ServeEngine

AMOEBA = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                      min_phase_steps=2)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-14b", reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n=10, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, list(map(int, rng.integers(
        0, cfg.vocab_size, int(rng.choice([8, 16]))))),
        int(rng.choice([2, 5, 20]))) for i in range(n)]


def _check_books(requests, useful_tokens, completed, prefill_tokens=None):
    assert completed == len(requests)
    assert all(r.done for r in requests)
    assert useful_tokens == sum(len(r.generated) for r in requests)
    assert all(len(r.generated) == r.max_new_tokens for r in requests)
    if prefill_tokens is not None:
        assert prefill_tokens == sum(len(r.prompt) for r in requests)
    for r in requests:
        assert r.finish is not None and r.finish >= r.arrival


@pytest.mark.parametrize("dynamic", [False, True])
@pytest.mark.parametrize("policy", ["direct_split", "warp_regroup"])
def test_serve_engine_accounting(setup, dynamic, policy):
    cfg, params = setup
    eng = ServeEngine(cfg, params, capacity=4, amoeba=AmoebaConfig(
        regroup_policy=policy, split_threshold=0.3, fuse_threshold=0.05,
        min_phase_steps=2))
    reqs = _requests(cfg)
    eng.submit(reqs)
    st = eng.run(dynamic=dynamic)
    _check_books(reqs, st.useful_tokens, st.completed, st.prefill_tokens)
    if not dynamic:
        assert st.splits == 0 and st.fuses == 0


@pytest.mark.parametrize("router", sorted(ROUTERS))
def test_fleet_engine_accounting(setup, router):
    cfg, params = setup
    trace = bursty_longtail_trace(horizon=30, vocab_size=cfg.vocab_size,
                                  seed=1)
    eng = FleetEngine(cfg, params, fleet=FleetConfig(
        num_groups=2, capacity=4, router=router, amoeba=AMOEBA))
    eng.submit(trace)
    s = eng.run()
    _check_books(trace, eng.useful_tokens, eng.completed)
    assert s["completed"] == len(trace) == s["submitted"]
    assert s["wall_ticks"] >= max(r.finish for r in trace)


def test_fleet_modes_generate_identical_tokens(setup):
    """Fleet topology must never change per-request results — only cost."""
    cfg, params = setup
    texts = {}
    for mode in ("fused", "split", "dynamic"):
        trace = bursty_longtail_trace(horizon=25, vocab_size=cfg.vocab_size,
                                      seed=2)
        eng = FleetEngine(cfg, params, fleet=FleetConfig(
            num_groups=2, capacity=4, mode=mode, amoeba=AMOEBA))
        eng.submit(trace)
        eng.run()
        texts[mode] = {r.rid: tuple(r.generated) for r in trace}
    assert texts["fused"] == texts["split"] == texts["dynamic"]


# -- control-plane integration -------------------------------------------------

def test_submit_heap_is_fifo_stable_for_equal_arrivals(setup):
    """heapq submit must deliver same-tick requests in submission order."""
    cfg, params = setup
    eng = FleetEngine(cfg, params, fleet=FleetConfig(
        num_groups=1, capacity=4, router="round_robin", amoeba=AMOEBA))
    reqs = [Request(i, [1, 2, 3], 4, arrival=0) for i in range(6)]
    eng.submit(reqs[:3])
    eng.submit(reqs[3:])
    eng._deliver()
    assert [r.rid for r in eng.groups[0].queue] == list(range(6))


def test_submit_heap_orders_interleaved_arrivals(setup):
    cfg, params = setup
    eng = FleetEngine(cfg, params, fleet=FleetConfig(
        num_groups=1, capacity=4, router="round_robin", amoeba=AMOEBA))
    eng.submit([Request(0, [1], 2, arrival=5), Request(1, [1], 2, arrival=0),
                Request(2, [1], 2, arrival=5)])
    eng.wall = 9
    eng._deliver()
    assert [r.rid for r in eng.groups[0].queue] == [1, 0, 2]


def test_late_submission_of_past_arrival_delivers(setup):
    """A request submitted after its arrival tick passed must be delivered
    on the next delivery pass, not trip the FIFO micro-assert."""
    cfg, params = setup
    eng = FleetEngine(cfg, params, fleet=FleetConfig(
        num_groups=1, capacity=4, router="round_robin", amoeba=AMOEBA))
    eng.submit([Request(0, [1], 2, arrival=5)])
    eng.wall = 5
    eng._deliver()
    eng.submit([Request(1, [1], 2, arrival=0)])
    eng._deliver()
    assert [r.rid for r in eng.groups[0].queue] == [0, 1]


def test_static_modes_ignore_policy_config(setup):
    """Static fused/split fleets never consult the controller, so a
    predictor policy config without a model must not raise."""
    cfg, params = setup
    for mode in ("fused", "split"):
        eng = FleetEngine(cfg, params, fleet=FleetConfig(
            num_groups=1, capacity=4, mode=mode,
            amoeba=AMOEBA.replace(policy="predictor")))
        assert eng.policy is None


@pytest.mark.parametrize("policy", ["oracle", "online", "predictor"])
def test_fleet_policy_stacks_accounting(setup, policy):
    """Every repro.control decision stack must keep the books balanced."""
    from repro.control import train_serve_predictor
    cfg, params = setup
    model = None
    if policy == "predictor":
        model, _ = train_serve_predictor(n_samples=256, steps=150, seed=0)
    trace = bursty_longtail_trace(horizon=25, vocab_size=cfg.vocab_size,
                                  seed=3)
    eng = FleetEngine(cfg, params, model=model, fleet=FleetConfig(
        num_groups=2, capacity=4, router="length_aware",
        amoeba=AMOEBA.replace(policy=policy)))
    eng.submit(trace)
    s = eng.run()
    _check_books(trace, eng.useful_tokens, eng.completed)
    assert s["control"]["policy"] == policy


def test_kway_group_reaches_four_ways(setup):
    """A capacity-8 group under heavy long-tail divergence climbs the
    topology ladder past the paper's binary pair — and the books still
    balance."""
    from repro.serve.engine import RECONF
    cfg, params = setup
    rng = np.random.default_rng(5)
    reqs = [Request(i, [1, 2, 3, 4, 5, 6, 7, 8],
                    int(rng.choice([2, 12, 40, 90])))
            for i in range(16)]
    from repro.serve import ReconfigurableGroup
    g = ReconfigurableGroup(cfg, params, capacity=8, mode="dynamic",
                            amoeba=AMOEBA.replace(policy="oracle",
                                                  max_ways=4,
                                                  min_phase_steps=1))
    g.submit(reqs)
    max_ways_seen, ticks = 1, 0
    while ticks < 2000:
        status = g.step(dynamic=True, now=ticks)
        if status == "idle":
            break
        max_ways_seen = max(max_ways_seen, g.ways)
        ticks += 1
    g.finalize()
    assert max_ways_seen == 4
    assert g.stats.completed == len(reqs)
    assert all(r.done for r in reqs)
    assert g.stats.useful_tokens == sum(len(r.generated) for r in reqs)


def test_fleet_rebalancer_drains_and_reports(setup):
    cfg, params = setup
    trace = bursty_longtail_trace(horizon=25, vocab_size=cfg.vocab_size,
                                  seed=4)
    eng = FleetEngine(cfg, params, fleet=FleetConfig(
        num_groups=2, capacity=4, router="length_aware",
        rebalance_every=4, amoeba=AMOEBA))
    eng.submit(trace)
    s = eng.run()
    _check_books(trace, eng.useful_tokens, eng.completed)
    assert "fleet_rebalances" in s["control"]


# -- pure components (no model) ------------------------------------------------

def test_traffic_trace_shape():
    trace = bursty_longtail_trace(horizon=60, vocab_size=1000, seed=0)
    assert trace, "bursty trace must be non-empty"
    arrivals = [r.arrival for r in trace]
    assert arrivals == sorted(arrivals)
    assert len({r.rid for r in trace}) == len(trace)
    assert {r.tenant for r in trace} == {"chat", "batch"}
    assert all(1 <= r.max_new_tokens <= 256 for r in trace)
    assert all(len(r.prompt) in (8, 16) for r in trace)


def test_traffic_burst_modulation():
    prof = TenantProfile(name="b", rate=1.0, burst_factor=4.0,
                         burst_period=10, burst_duty=0.3)
    on = [prof.intensity(t) for t in range(10)]
    assert max(on) == 4.0 and min(on) == 1.0


def test_make_trace_deterministic():
    a = make_trace([TenantProfile(name="x", rate=0.5)], 40, 100, seed=7)
    b = make_trace([TenantProfile(name="x", rate=0.5)], 40, 100, seed=7)
    assert [(r.arrival, r.prompt, r.max_new_tokens) for r in a] \
        == [(r.arrival, r.prompt, r.max_new_tokens) for r in b]


def test_resumed_run_does_not_double_count(setup):
    """finalize() must be idempotent: a max_ticks cutoff + resume must not
    credit the same completions twice."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, capacity=4, amoeba=AMOEBA)
    reqs = _requests(cfg, n=6, seed=4)
    eng.submit(reqs)
    eng.run(dynamic=True, max_ticks=3)     # cut off mid-drain, finalizes
    st = eng.run(dynamic=True)             # resume to completion
    _check_books(reqs, st.useful_tokens, st.completed, st.prefill_tokens)


def test_split_mode_rejects_capacity_below_two(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="capacity"):
        ReconfigurableGroup(cfg, params, capacity=1, mode="split")


def test_telemetry_idle_gap_consistency():
    """Fast-forwarded idle ticks must show up in utilization/idle stats."""
    class _Stats:
        useful_tokens = 0
        completed = 0

    class _G:
        stats = _Stats()
        queue = ()

    t = FleetTelemetry()
    groups = [_G(), _G()]
    t.on_tick(0, groups, ticked=2)
    t.on_idle_gap(8, len(groups))
    t.on_tick(9, groups, ticked=2)
    assert t.wall_ticks == 10
    assert t.idle_ticks == 8
    assert t.group_tick_slots == 2 * 2 + 8 * 2
    assert len(t.queue_depths) == 10


def test_rolling_window_rate():
    w = RollingWindow(window=10)
    for t in range(20):
        w.push(t, 3.0 * t)
    assert abs(w.rate() - 3.0) < 1e-9


class _FakeRoutee:
    def __init__(self, split, load, topology=None):
        self.is_split, self._load = split, load
        if topology is not None:
            self.topology = topology

    def load(self):
        return self._load


def test_length_aware_router_prefers_split_groups():
    groups = [_FakeRoutee(False, 0), _FakeRoutee(True, 100),
              _FakeRoutee(True, 50)]
    state = {"long_threshold": 24}
    long_req = Request(0, [1], 48)
    short_req = Request(1, [1], 3)
    # routers address (group, part); no topology attr -> no part choice
    assert route_length_aware(long_req, groups, state) == (2, None)
    assert route_length_aware(short_req, groups, state) == (0, None)


def test_length_aware_router_addresses_parts():
    """Long requests target the narrowest part (the quarantine slice),
    short requests the widest — the same addressing migration steals use."""
    groups = [_FakeRoutee(False, 0, topology=(8,)),
              _FakeRoutee(True, 50, topology=(5, 3))]
    state = {"long_threshold": 24}
    assert route_length_aware(Request(0, [1], 48), groups, state) == (1, 1)
    assert route_length_aware(Request(1, [1], 3), groups, state) == (0, None)


def test_router_tie_break_is_least_recently_assigned():
    """Equal-load ties must rotate across groups, not pile onto group 0."""
    from repro.fleet.scheduler import route_least_loaded

    groups = [_FakeRoutee(False, 7) for _ in range(4)]
    state = {}
    picks = [route_least_loaded(Request(i, [1], 4), groups, state)[0]
             for i in range(100)]
    counts = [picks.count(g) for g in range(len(groups))]
    assert min(counts) >= 20, counts       # near-uniform, not index-biased
    # and the length-aware router inherits the same rotation on ties
    groups = [_FakeRoutee(True, 7, topology=(2, 2)) for _ in range(4)]
    state = {"long_threshold": 24}
    picks = [route_length_aware(Request(i, [1], 48), groups, state)[0]
             for i in range(100)]
    counts = [picks.count(g) for g in range(len(groups))]
    assert min(counts) >= 20, counts


class _StubPlanner:
    """Pressure-view stub for router-level spill tests."""

    def __init__(self, pressure):
        self._p = dict(pressure)

    def pressure(self):
        return self._p


def test_spill_stay_stamps_lru_so_spills_rotate_cold_groups():
    """A pinned admission that *stays* is still an assignment.

    Regression: ``_spill`` only stamped the LRU clock when it actually
    spilled, so a cold group that had just absorbed pinned admissions
    still ranked as least-recently-assigned and the next hot-shard spill
    double-booked it instead of rotating to its equally-cold sibling.
    """
    from repro.fleet.scheduler import route_sticky

    groups = [_FakeRoutee(False, 0) for _ in range(4)]
    state = {"planner": _StubPlanner({0: 9.0, 1: 9.0, 2: 0.0, 3: 0.0}),
             "spill_threshold": 1.0}

    def admit(shard):
        return route_sticky(Request(0, [1], 4, shard=shard),
                            groups, state)[0]

    dests = [admit(2),   # pinned cold: stays on 2 (and must stamp it)
             admit(0),   # hot spill: 2 was just assigned -> 3
             admit(3),   # pinned cold: stays on 3
             admit(1)]   # hot spill: 3 is now fresher -> back to 2
    assert dests == [2, 3, 3, 2], dests
    # alternating hot shards keep rotating, never twice in a row onto
    # the same cold group
    follow = [admit(0), admit(1), admit(0), admit(1)]
    assert follow == [3, 2, 3, 2], follow


def test_sticky_stay_without_planner_still_stamps_lru():
    """The no-planner / zero-threshold stay path stamps too, so pinned
    and unsharded admissions share one honest recency clock."""
    from repro.fleet.scheduler import route_sticky

    groups = [_FakeRoutee(False, 0) for _ in range(3)]
    state = {}
    assert route_sticky(Request(0, [1], 4, shard=0), groups, state)[0] == 0
    # the unsharded fallback (least-loaded) must see group 0 as recently
    # assigned and rotate away from it on the all-tied load
    assert route_sticky(Request(1, [1], 4), groups, state)[0] == 1
