"""Import-and-execute smoke tests for the demo scripts.

Marked ``examples`` and excluded from the default tier-1 run (see
``addopts`` in pyproject.toml); run them explicitly with

    PYTHONPATH=src python -m pytest -q -m examples
"""
import os
import runpy
import sys

import pytest

EXAMPLES = os.path.join(os.path.dirname(__file__), "..", "examples")

pytestmark = pytest.mark.examples


def _run(script: str, argv):
    old = sys.argv
    sys.argv = [script] + argv
    try:
        runpy.run_path(os.path.join(EXAMPLES, script), run_name="__main__")
    finally:
        sys.argv = old


def test_control_plane_example_runs():
    _run("control_plane.py",
         ["--groups", "2", "--capacity", "4", "--horizon", "20",
          "--variants", "1"])


def test_serve_fleet_example_runs():
    _run("serve_fleet.py", ["--groups", "2", "--capacity", "4",
                            "--horizon", "20"])


def test_hetero_topology_example_runs():
    _run("hetero_topology.py", ["--groups", "2", "--capacity", "4",
                                "--horizon", "20"])


def test_work_stealing_example_runs():
    _run("work_stealing.py", ["--groups", "2", "--capacity", "4",
                              "--horizon", "20"])


def test_cluster_mesh_example_runs():
    _run("cluster_mesh.py", ["--chips", "2", "--groups-per-chip", "2",
                             "--capacity", "4", "--horizon", "20"])


def test_trace_timeline_example_runs(tmp_path):
    _run("trace_timeline.py",
         ["--chips", "2", "--groups-per-chip", "2", "--capacity", "4",
          "--horizon", "20", "--out-dir", str(tmp_path)])
    assert (tmp_path / "trace_timeline.jsonl").exists()
    assert (tmp_path / "trace_timeline_chrome.json").exists()
