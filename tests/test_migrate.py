"""Cross-group work stealing and KV-costed migration (repro.fleet.migrate).

Planner- and executor-level invariants run against the lightweight
protocol fakes in ``fake_fleet.py`` (no model); the end-to-end section
drives real ``ReconfigurableGroup``s and the full ``FleetEngine`` to pin
the books-balance and token-identity contracts under migration.  The
same conservation invariants are fuzzed under hypothesis in
``test_migrate_properties.py``.
"""
import jax
import numpy as np
import pytest

from fake_fleet import FakeGroup, all_requests
from repro.configs import get_config
from repro.configs.base import AmoebaConfig, FleetConfig, MigrationConfig
from repro.control import (ConfigSpace, FeatureVector, FleetController,
                           GroupController, ThresholdPolicy)
from repro.fleet import FleetEngine, imbalanced_trace
from repro.fleet.migrate import (KVTransferCost, LIVE, STEAL,
                                 MigrationPlanner)
from repro.models import transformer as T
from repro.serve import ReconfigurableGroup, Request

AMOEBA = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                      min_phase_steps=2)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-14b", reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def model_cfg():
    return get_config("qwen3-14b", reduced=True)


def planner(mcfg=None, **kw):
    kw.setdefault("enabled", True)
    return MigrationPlanner(MigrationConfig(**kw), mcfg or model_cfg(),
                            long_threshold=24, window=256)


def req(rid, tokens, generated=0, plen=4):
    r = Request(rid, [1] * plen, tokens)
    r.generated = [0] * generated
    return r


# -- KVTransferCost ------------------------------------------------------------

def test_kv_bytes_grow_with_seq_len_and_window_caps():
    cfg = model_cfg()
    c = KVTransferCost(link_bandwidth=1e6)
    assert c.kv_bytes(64, cfg) > c.kv_bytes(8, cfg) > 0
    assert c.kv_bytes(512, cfg, window=64) == c.kv_bytes(64, cfg, window=64)


def test_zero_bandwidth_prices_transfer_at_infinity():
    cfg = model_cfg()
    assert np.isinf(KVTransferCost(link_bandwidth=0.0)
                    .stall_ticks(16, cfg))
    assert KVTransferCost(link_bandwidth=1e12).stall_ticks(16, cfg) >= 1


def test_quantized_kv_ships_fewer_bytes():
    """int8 wire layout: one code per entry + one fp32 scale per row."""
    cfg = model_cfg()
    bf16 = KVTransferCost(link_bandwidth=1e6, quantized=False)
    int8 = KVTransferCost(link_bandwidth=1e6, quantized=True)
    for seq in (8, 64, 512):
        assert 0 < int8.kv_bytes(seq, cfg) < bf16.kv_bytes(seq, cfg)
    # the saving is the dtype ratio, minus the per-row scale overhead
    assert int8.kv_bytes(256, cfg) <= 0.85 * bf16.kv_bytes(256, cfg)


def test_quantized_kv_flips_the_live_migration_veto():
    """At one fixed link bandwidth, the bf16 transfer stalls too long to
    amortize while the int8 wire layout clears the same ``min_gain`` bar
    — the point of pricing migrations off the quantized layout."""
    cfg = model_cfg()
    seq_len = 5                     # plen 4 + 1 generated, see req()
    bytes_bf = KVTransferCost(quantized=False).kv_bytes(seq_len, cfg,
                                                        window=256)
    bytes_q = KVTransferCost(quantized=True).kv_bytes(seq_len, cfg,
                                                      window=256)
    assert bytes_q <= 0.85 * bytes_bf
    # donor (4,) with one 60-tail: saved=4*57, fused=4*60, destination
    # (2,2) adds 2*(stall+59) -> the move amortizes iff stall < ~52
    bw = bytes_bf / 60.0            # bf16 stalls 60 ticks: vetoed
    lives = lambda: [req(0, 60, generated=1), req(1, 3, generated=1),
                     req(2, 3, generated=1), req(3, 3, generated=1)]
    p_bf = planner(live=True, min_gain=0.02, link_bandwidth=bw)
    plans = p_bf.plan(0, [FakeGroup(0, (4,), parts=[lives()]),
                          FakeGroup(1, (2, 2))])
    assert not any(m.kind == LIVE for m in plans)
    assert p_bf.rejected_amortization == 1
    p_q = planner(live=True, min_gain=0.02, link_bandwidth=bw,
                  quantized_kv=True)
    plans = p_q.plan(0, [FakeGroup(0, (4,), parts=[lives()]),
                         FakeGroup(1, (2, 2))])
    live = [m for m in plans if m.kind == LIVE]
    assert len(live) == 1 and live[0].gain > 0.02
    assert live[0].stall < 60


# -- planning against protocol fakes -------------------------------------------

def test_planner_steals_overflow_to_starving_parts():
    donor = FakeGroup(0, (4,), queue=[req(i, 40 if i % 2 else 3)
                                      for i in range(6)])
    recip = FakeGroup(1, (5, 3))
    p = planner(steal_threshold=1, max_steals=4)
    plans = p.plan(0, [donor, recip])
    steals = [m for m in plans if m.kind == STEAL]
    assert steals and all(m.dst[0] == 1 for m in steals)
    # long victims target the narrowest part, short the widest
    for m in steals:
        want = 1 if m.request.max_new_tokens >= 24 else 0
        assert m.dst[1] == want, m.as_dict()
    executed = p.execute(plans, [donor, recip], now=0)
    assert executed == len(steals)
    assert p.steals == len(steals)
    assert donor.stats.steals_out == len(steals)
    assert recip.stats.steals_in == len(steals)
    # donor keeps its oldest requests: steals come from the queue tail
    assert [r.rid for r in donor.queue] == \
        list(range(6 - len(steals)))


def test_planner_respects_steal_budget_and_threshold():
    donor = FakeGroup(0, (8,), queue=[req(i, 4) for i in range(20)])
    recip = FakeGroup(1, (8,))
    plans = planner(steal_threshold=2, max_steals=3).plan(0, [donor, recip])
    assert len(plans) == 3
    # a donor at/below the threshold is left alone
    calm = FakeGroup(0, (8,), queue=[req(0, 4), req(1, 4)])
    assert planner(steal_threshold=2).plan(0, [calm, FakeGroup(1, (8,))]) \
        == []


def test_no_circular_steals_between_mutually_loaded_groups():
    """Two groups both over the steal threshold must not swap requests:
    a group with a steal-worthy backlog is never a recipient."""
    a = FakeGroup(0, (4,), queue=[req(i, 8) for i in range(3)])
    b = FakeGroup(1, (4,), queue=[req(i + 10, 8) for i in range(3)])
    assert planner(steal_threshold=2).plan(0, [a, b]) == []


def test_reserved_parts_are_steal_ineligible():
    donor = FakeGroup(0, (4,), queue=[req(i, 40) for i in range(5)])
    recip = FakeGroup(1, (3, 1))
    # the only free narrow part is reserved: long steals fall to part 0;
    # reserving everything blocks stealing entirely
    plans = planner(steal_threshold=1).plan(0, [donor, recip],
                                            reserved={(1, 1)})
    assert plans and all(m.dst == (1, 0) for m in plans)
    plans = planner(steal_threshold=1).plan(
        0, [donor, recip], reserved={(1, 0), (1, 1)})
    assert plans == []


def test_live_migration_plans_and_amortization():
    lives = [req(0, 60, generated=1), req(1, 3, generated=1),
             req(2, 3, generated=1), req(3, 3, generated=1)]
    donor = FakeGroup(0, (4,), parts=[lives])
    recip = FakeGroup(1, (2, 2))
    p = planner(live=True, min_gain=0.02, link_bandwidth=1e12)
    plans = p.plan(0, [donor, recip])
    live = [m for m in plans if m.kind == LIVE]
    assert len(live) == 1
    m = live[0]
    assert m.request.rid == 0 and m.src == (0, 0) and m.dst[0] == 1
    assert m.gain > 0.02 and m.stall >= 1
    assert p.execute(plans, [donor, recip], now=0) == 1
    assert recip.part_live(m.dst[1]) == [m.request]
    assert recip.stall[m.dst[1]] == m.stall
    assert donor.part_live(0) == lives[1:]


def test_zero_bandwidth_fails_every_live_amortization_but_steals_flow():
    lives = [req(0, 60, generated=1), req(1, 3, generated=1)]
    donor = FakeGroup(0, (4,), parts=[lives],
                      queue=[req(10, 4), req(11, 4)])
    recip = FakeGroup(1, (2, 2))
    p = planner(live=True, link_bandwidth=0.0, steal_threshold=1)
    plans = p.plan(0, [donor, recip])
    assert all(m.kind == STEAL for m in plans) and plans
    assert p.rejected_amortization > 0


def test_charge_ticks_whole_tick_quantum():
    from repro.fleet.migrate import charge_ticks
    assert charge_ticks(0.4) == 0      # sub-tick: hides behind decode
    assert charge_ticks(1.0) == 1
    assert charge_ticks(2.0) == 2
    assert charge_ticks(2.9) == 3      # int() would have billed 2
    with pytest.raises(ValueError):
        charge_ticks(float("inf"))


def test_fractional_stall_ceil_flips_the_veto_at_the_boundary():
    """A 2.9-tick transfer occupies the destination for 3 whole ticks;
    billing it as 2 (truncation) let moves through that do not amortize.

    The fixture sits exactly between the two billings: with the donor
    part at remaining [59, 2, 2, 2] and a 2-slot destination, the gain
    is (110 - 2c)/236 — 0.449 under truncation (c=2), 0.441 under ceil
    (c=3) — so min_gain = 0.445 vetoes iff the charge is honest.
    """
    from dataclasses import dataclass

    @dataclass(frozen=True)
    class _FracCost(KVTransferCost):
        ticks: float = 2.9

        def stall_ticks(self, seq_len, model_cfg, window=None,
                        src=None, dst=None):
            return self.ticks

    def build():
        lives = [req(0, 60, generated=1)] + \
            [req(i, 3, generated=1) for i in (1, 2, 3)]
        return FakeGroup(0, (4,), parts=[lives]), FakeGroup(1, (2, 2))

    def plan_live(min_gain, ticks):
        donor, recip = build()
        p = MigrationPlanner(
            MigrationConfig(enabled=True, live=True, min_gain=min_gain),
            model_cfg(), long_threshold=24, window=256,
            cost=_FracCost(ticks=ticks))
        return [m for m in p.plan(0, [donor, recip])
                if m.kind == LIVE], p

    live, p = plan_live(0.445, 2.9)
    assert live == [] and p.rejected_amortization == 1
    # below the honest bar the move flows — billed the whole 3 ticks
    live, _ = plan_live(0.43, 2.9)
    assert len(live) == 1 and live[0].stall == 3
    # sub-tick transfers stay free (the NoC-hop-hides-behind-decode rule)
    live, _ = plan_live(0.445, 0.4)
    assert len(live) == 1 and live[0].stall == 0


def test_execute_conserves_requests_and_budgets():
    donor = FakeGroup(0, (4,), parts=[[req(0, 50, generated=1),
                                       req(1, 2, generated=1)]],
                      queue=[req(i + 2, 8) for i in range(6)])
    recip = FakeGroup(1, (2, 2), parts=[[req(20, 5, generated=1)], []])
    groups = [donor, recip]
    before = sorted(r.rid for r in all_requests(groups))
    p = planner(steal_threshold=1, live=True, link_bandwidth=1e12,
                min_gain=0.0)
    for tick in range(4):
        p.execute(p.plan(tick, groups), groups, now=tick)
        after = sorted(r.rid for r in all_requests(groups))
        assert after == before                      # no loss, no duplication
        for g in groups:
            for i, slots in enumerate(g.topology):
                assert len(g.part_live(i)) <= slots


def test_stale_plan_is_dropped_not_applied():
    donor = FakeGroup(0, (4,), queue=[req(0, 8), req(1, 8), req(2, 8)])
    recip = FakeGroup(1, (4,))
    p = planner(steal_threshold=1)
    plans = p.plan(0, [donor, recip])
    assert plans
    victim = plans[0].request
    donor.queue.remove(victim)                      # raced away
    executed = p.execute(plans, [donor, recip], now=0)
    assert executed == len(plans) - 1
    assert victim not in recip.queue


# -- quarantine reservation (exact-composition fleet hints) --------------------

class _CtlGroup:
    """test_control-style fake exposing the FleetController surface."""

    def __init__(self, remaining, capacity=8, max_ways=4):
        self.controller = GroupController(
            ThresholdPolicy(0.95, 0.0),
            ConfigSpace(capacity, max_ways=max_ways), dwell=1)
        self._remaining = list(remaining)
        self.queue = []

    def live_requests(self):
        class R:
            def __init__(self, n):
                self.remaining = n
                self.max_new_tokens = n
        return [R(n) for n in self._remaining]

    def load(self):
        return sum(self._remaining)

    def observe(self):
        rem = np.asarray(self._remaining, np.float64)
        self.controller.observe(FeatureVector.from_group(
            rem, 0, 0.0, self.controller.space.capacity))


def test_quarantine_reservation_survives_rebalance():
    groups = [_CtlGroup([10.0, 12.0, 11.0, 10.0]),
              _CtlGroup([10.0, 12.0, 11.0, 10.0])]
    fc = FleetController(long_threshold=24, every=1, quarantine=0)
    for t in range(8):
        fc.rebalance(t, groups)
        for g in groups:
            g.observe()
    assert groups[0].controller.state.topology == (7, 1)
    assert fc.reserved_parts(groups) == {(0, 1)}
    # the reservation holds across further rebalances (and would be
    # re-asserted if the group's own policy drifted it away)
    for t in range(8, 16):
        fc.rebalance(t, groups)
        for g in groups:
            g.observe()
    assert groups[0].controller.state.topology == (7, 1)
    assert fc.reserved_parts(groups) == {(0, 1)}


def test_mix_nudges_skip_the_quarantine_group():
    """Long-tail pressure must nudge the other groups, never fight the
    quarantine group's standing exact-composition hint."""
    groups = [_CtlGroup([100.0, 90.0, 95.0, 100.0]),
              _CtlGroup([100.0, 90.0, 95.0, 100.0])]
    fc = FleetController(long_threshold=24, every=1, quarantine=0)
    fc.rebalance(0, groups)
    assert groups[0].controller._hint == (7, 1)      # reservation, not a 2
    assert groups[1].controller._hint == 2           # mix nudge went here


def test_exact_composition_hint_applies_and_retires():
    gc = GroupController(ThresholdPolicy(0.95, 0.0),
                         ConfigSpace(8, max_ways=4), dwell=1)
    fv = FeatureVector.from_group(
        np.array([10.0, 12.0, 11.0, 10.0]), 0, 0.0, 8)
    gc.request_topology((7, 1))
    for _ in range(4):
        gc.observe(fv)
    assert gc.state.topology == (7, 1)
    assert gc._hint is None                          # retired exactly


# -- end to end on the real engine ---------------------------------------------

def _check_books(requests, eng):
    assert eng.completed == len(requests)
    assert all(r.done for r in requests)
    assert eng.useful_tokens == sum(len(r.generated) for r in requests)
    assert all(len(r.generated) == r.max_new_tokens for r in requests)


def test_fleet_stealing_balances_books_and_tokens(setup):
    """Stealing must change only placement: every request completes
    exactly once and generates exactly the tokens it would have
    generated without migration."""
    cfg, params = setup
    texts = {}
    for label, mig in (("off", MigrationConfig(enabled=False)),
                       ("on", MigrationConfig(enabled=True))):
        trace = imbalanced_trace(horizon=25, vocab_size=cfg.vocab_size,
                                 seed=6, shards=2)
        eng = FleetEngine(cfg, params, fleet=FleetConfig(
            num_groups=2, capacity=4, router="sticky", mode="dynamic",
            rebalance_every=4, migrate=mig, amoeba=AMOEBA))
        eng.submit(trace)
        s = eng.run()
        _check_books(trace, eng)
        texts[label] = {r.rid: tuple(r.generated) for r in trace}
        if label == "on":
            assert s["migration"]["steals"] > 0
            assert s["migration"]["plan_ticks"] > 0
            for g in s["groups"]:
                assert "steals_in" in g and "stall_ticks" in g
    assert texts["off"] == texts["on"]


def test_live_migration_end_to_end(setup):
    """A real KV row moves between groups: books balance, the stall is
    charged to the destination part, and the migrated request's tokens
    are unchanged."""
    cfg, params = setup
    reqs = [Request(i, [1, 2, 3, 4], n)
            for i, n in enumerate([60, 3, 3, 3])]
    baseline = [Request(i, [1, 2, 3, 4], n)
                for i, n in enumerate([60, 3, 3, 3])]
    g0 = ReconfigurableGroup(cfg, params, capacity=4, mode="fused",
                             amoeba=AMOEBA)
    g1 = ReconfigurableGroup(cfg, params, capacity=4, mode="split",
                             amoeba=AMOEBA)
    g0.submit(reqs)
    g0.step(now=0)                       # admit + first decode tick
    p = planner(live=True, min_gain=0.0, link_bandwidth=1e12)
    plans = p.plan(0, [g0, g1])
    live = [m for m in plans if m.kind == LIVE]
    assert len(live) == 1 and live[0].request is reqs[0]
    assert p.execute(plans, [g0, g1], now=0) == 1
    assert g1.stats.migrations_in == 1 and g0.stats.migrations_out == 1
    tick = 1
    while tick < 500:
        s0 = g0.step(now=tick)
        s1 = g1.step(now=tick)
        if s0 == "idle" and s1 == "idle":
            break
        tick += 1
    g0.finalize()
    g1.finalize()
    assert g0.stats.completed + g1.stats.completed == len(reqs)
    assert all(r.done for r in reqs)
    assert g1.stats.stall_ticks >= live[0].stall
    # token identity vs an undisturbed fused run
    ref = ReconfigurableGroup(cfg, params, capacity=4, mode="fused",
                              amoeba=AMOEBA)
    ref.submit(baseline)
    t = 0
    while ref.step(now=t) != "idle" and t < 500:
        t += 1
    ref.finalize()
    assert [tuple(r.generated) for r in reqs] \
        == [tuple(r.generated) for r in baseline]


def test_admission_spill_reduces_stealing(setup):
    """Closing the router/planner loop: when sticky admissions consult
    the planner's pressure view and spill off hot groups, steals only
    handle the residual — fewer than when every pinned admission lands
    hot and must be re-homed after the fact."""
    cfg, params = setup
    steals, spills = {}, {}
    for label, thresh in (("off", 0.0), ("on", 4.0)):
        trace = imbalanced_trace(horizon=25, vocab_size=cfg.vocab_size,
                                 seed=6, shards=2)
        eng = FleetEngine(cfg, params, fleet=FleetConfig(
            num_groups=2, capacity=4, router="sticky", mode="dynamic",
            rebalance_every=4,
            migrate=MigrationConfig(enabled=True, spill_threshold=thresh),
            amoeba=AMOEBA))
        eng.submit(trace)
        s = eng.run()
        _check_books(trace, eng)
        steals[label] = s["migration"]["steals"]
        spills[label] = s["control"]["admission_spills"]
    assert spills["off"] == 0 and spills["on"] > 0
    assert steals["on"] < steals["off"]


def test_quarantine_fleet_runs_and_reports(setup):
    cfg, params = setup
    trace = imbalanced_trace(horizon=20, vocab_size=cfg.vocab_size,
                             seed=7, shards=2)
    eng = FleetEngine(cfg, params, fleet=FleetConfig(
        num_groups=2, capacity=4, router="sticky", mode="dynamic",
        rebalance_every=2, quarantine_group=1,
        migrate=MigrationConfig(enabled=True), amoeba=AMOEBA))
    eng.submit(trace)
    s = eng.run()
    _check_books(trace, eng)
    assert "reserved_parts" in s["control"]


def test_quarantine_group_out_of_range_rejected(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="quarantine_group"):
        FleetEngine(cfg, params, fleet=FleetConfig(
            num_groups=2, capacity=4, quarantine_group=5, amoeba=AMOEBA))
