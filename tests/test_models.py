"""Per-arch smoke + decode/full-forward agreement + kernel-path parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T

RT = T.Runtime(production=False, remat=True)


def _batch(cfg, B=2, S=48, dtype=jnp.bfloat16, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.encoder_layers:
        b["audio_embeds"] = jax.random.normal(ks[1], (B, 24, cfg.d_model),
                                              dtype)
    if cfg.vision_stub:
        b["vision_embeds"] = jax.random.normal(ks[2], (B, 16, cfg.d_model),
                                               dtype)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one grad step, shapes + no NaNs."""
    cfg = get_config(arch, reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    loss, metrics = jax.jit(
        lambda p, b: T.loss_fn(p, b, cfg, RT))(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))

    grads = jax.grad(lambda p: T.loss_fn(p, batch, cfg, RT)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0

    logits, _ = T.logits_fn(params, batch, cfg, RT)
    assert logits.shape == (2, 48, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    """Teacher-forced decode off the ring cache == full-sequence logits."""
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    rt = T.Runtime(production=False, remat=False)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S, jnp.float32)
    toks = batch["tokens"]
    full, _ = T.logits_fn(params, batch, cfg, rt)
    P0 = S - 3
    pb = dict(batch)
    pb["tokens"] = toks[:, :P0]
    lg, st = T.prefill(params, pb, cfg, rt, window=S)
    errs = [float(jnp.max(jnp.abs(lg - full[:, P0 - 1])))]
    for t in range(P0, S):
        lg, st = T.decode_step(params, st, toks[:, t:t + 1], cfg, rt)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 1e-3, errs


@pytest.mark.parametrize("arch", ["qwen3-14b", "recurrentgemma-9b",
                                  "falcon-mamba-7b"])
def test_pallas_kernel_path_matches_jnp(arch):
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, dtype=jnp.float32)
    l0, _ = T.loss_fn(params, batch, cfg,
                      T.Runtime(production=False, remat=False))
    l1, _ = T.loss_fn(params, batch, cfg,
                      T.Runtime(production=False, remat=False,
                                use_kernels=True, q_block=32, kv_block=32))
    assert abs(float(l0) - float(l1)) < 2e-4


def test_sliding_window_limits_context():
    """With window W, logits at position t ignore tokens < t - W."""
    cfg = get_config("qwen3-14b", reduced=True).replace(
        dtype="float32", attn_window=8, num_layers=2)
    rt = T.Runtime(production=False, remat=False)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                              cfg.vocab_size)
    out1, _ = T.logits_fn(params, {"tokens": toks}, cfg, rt)
    # perturb a token far outside the window of the last position
    toks2 = toks.at[0, 2].set((toks[0, 2] + 7) % cfg.vocab_size)
    out2, _ = T.logits_fn(params, {"tokens": toks2}, cfg, rt)
    # last position: tokens < 24-8 = 16 are invisible (2 < 16)
    assert float(jnp.max(jnp.abs(out1[0, -1] - out2[0, -1]))) < 1e-5
    # but position 3 (inside its window) must change
    assert float(jnp.max(jnp.abs(out1[0, 3] - out2[0, 3]))) > 1e-5


def test_moe_dense_vs_sharded_single_device():
    """The capacity-buffer production path == capacity-free oracle when
    capacity is ample (single device, no mesh)."""
    from repro.models import moe as M
    cfg = get_config("deepseek-moe-16b", reduced=True).replace(dtype="float32")
    cfg = cfg.replace(moe=cfg.moe.__class__(
        num_experts=8, top_k=2, d_ff_expert=64, num_shared=2,
        capacity_factor=8.0))
    params, _ = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y_ref, aux_ref = M.moe_dense(params, x, cfg)
    y_prod, aux_prod = M.moe_sharded(params, x, cfg)
    assert float(jnp.max(jnp.abs(y_ref - y_prod))) < 1e-4
    assert float(aux_prod.dropped) == 0.0
