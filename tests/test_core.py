"""Predictor, controller, fusion plans, regrouping, metrics parsing."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AmoebaConfig
from repro.core import (AmoebaController, MeshPlan, StepProfile,
                        collective_bytes, plan_family, predict_fuse,
                        train_logistic)
from repro.core import predictor as P
from repro.core import regroup as R
from repro.core.fusion import amortized_switch_ok, reshard_cost_s


def test_logistic_learns_separable():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(400, 3))
    y = (X @ np.array([2.0, -1.0, 0.5]) + 0.3 > 0).astype(float)
    model, info = train_logistic(X, y, feature_names=("a", "b", "c"))
    assert info["train_accuracy"] > 0.95
    assert float(model.w[0]) > 0 and float(model.w[1]) < 0


def test_logistic_save_load_roundtrip(tmp_path):
    rng = np.random.default_rng(1)
    X = rng.normal(size=(100, 4))
    y = (X[:, 0] > 0).astype(float)
    model, _ = train_logistic(X, y, feature_names=tuple("abcd"))
    path = os.path.join(tmp_path, "m.json")
    P.save_model(model, path)
    m2 = P.load_model(path)
    x = np.array([0.5, -1, 2, 0.1])
    assert abs(float(P.predict_proba(model, x))
               - float(P.predict_proba(m2, x))) < 1e-6


def test_feature_impacts_sum_to_logit():
    rng = np.random.default_rng(2)
    X = rng.normal(size=(50, 3))
    y = (X[:, 0] > 0).astype(float)
    model, _ = train_logistic(X, y)
    x = X[0]
    impacts = P.feature_impacts(model, x)
    z = float(np.sum(np.asarray(impacts)) + model.b)
    p = float(P.predict_proba(model, x))
    assert abs(1 / (1 + np.exp(-z)) - p) < 1e-5


def test_plan_family_shapes():
    fam = plan_family(MeshPlan("base", data=16, model=16))
    assert fam["fused"].shape == (8, 32)
    assert fam["scale_out"].shape == (32, 8)
    assert all(p.num_devices == 256 for p in fam.values())


def test_amortization_veto():
    # 1 GB/chip resharded over 50 GB/s ICI = 0.04 s; gain must repay it
    assert not amortized_switch_ok(1e-4, 1e9, 10)
    assert amortized_switch_ok(1e-3, 1e9, 100)


def test_collective_bytes_parser():
    hlo = """
      %a = bf16[1024,512] all-reduce(bf16[1024,512] %x)
      %b = f32[2048] all-gather(f32[512] %y), dimensions={0}
      %c = bf16[64,128] reduce-scatter(bf16[512,128] %z)
      %d = s32[10] add(s32[10] %p, s32[10] %q)
    """
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 1024 * 512 * 2
    assert got["all-gather"] == 2048 * 4
    assert got["reduce-scatter"] == 64 * 128 * 2
    assert got["all-to-all"] == 0


def test_roofline_terms():
    p = StepProfile("t", flops=197e12, hbm_bytes=819e9, coll_bytes=50e9,
                    chips=256, model_flops=197e12 * 256)
    r = p.roofline()
    assert abs(r["compute_s"] - 1.0) < 1e-6
    assert abs(r["memory_s"] - 1.0) < 1e-6
    assert abs(r["collective_s"] - 1.0) < 1e-6
    assert r["roofline_frac"] == pytest.approx(1.0)


def test_controller_roofline_choice_and_veto():
    ctl = AmoebaController(AmoebaConfig())
    base = StepProfile("s", flops=1e12, hbm_bytes=1e9, coll_bytes=5e9,
                       chips=256)
    fused = StepProfile("s", flops=1e12, hbm_bytes=1e9, coll_bytes=2e9,
                        chips=256)
    d = ctl.choose_plan({"base": base, "fused": fused},
                        param_bytes_per_chip=1e8, steps_remaining=1e6)
    assert d.plan == "fused"
    d2 = ctl.choose_plan({"base": base, "fused": fused},
                         param_bytes_per_chip=1e12, steps_remaining=1)
    assert d2.plan == "base"
    assert "amortize" in d2.reason


def test_controller_split_fuse_hysteresis():
    ctl = AmoebaController(AmoebaConfig(min_phase_steps=2,
                                        split_threshold=0.3,
                                        fuse_threshold=0.1))
    lens = np.array([100.0, 5.0, 90.0, 3.0])
    states = [ctl.observe(R.divergence_score(lens), lens) for _ in range(4)]
    assert states[-1] is True
    fast, slow = ctl.layout([0, 1, 2, 3], lens)
    assert set(fast) == {1, 3} and set(slow) == {0, 2}
    # low divergence -> re-fuse after dwell
    calm = np.array([5.0, 5.0, 5.0, 5.0])
    states = [ctl.observe(R.divergence_score(calm), calm) for _ in range(4)]
    assert states[-1] is False


def test_regroup_beats_direct_on_interleaved():
    lens = [100.0, 4.0, 90.0, 6.0, 80.0, 5.0]
    assert R.regroup_gain(lens, "warp_regroup") > \
        R.regroup_gain(lens, "direct_split")


def test_moe_divergence_bounds():
    assert R.moe_divergence([0.25] * 4) == pytest.approx(0.0)
    assert 0.7 < R.moe_divergence([0.97, 0.01, 0.01, 0.01]) < 1.0
