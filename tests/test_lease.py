"""Slack-lease invariants (repro.fleet.lease).

Planner-level contracts run against the ``fake_fleet`` protocol fakes
(no model): grants conserve slot budgets, terms are bounded, revocation
fires on lender heat and borrower idleness, pricing respects the
``move_gain`` floor, and mesh wiring confines cross-group leases to
adjacent same-chip pairs with dead links vetoed.  The end-to-end section
drives a real lease-enabled ``FleetEngine`` to pin the zero-stall
contract and the reconfig force-revoke boundary.  The same conservation
invariants are fuzzed under hypothesis in ``test_lease_properties.py``.
"""
import jax
import pytest

from fake_fleet import FakeGroup
from repro.cluster import ClusterMesh, TieredTransferCost
from repro.configs import get_config
from repro.configs.base import (AmoebaConfig, ClusterConfig, FleetConfig,
                                LeaseConfig, MigrationConfig)
from repro.fleet import FleetEngine, LeasePlanner, transient_burst_trace
from repro.models import transformer as T
from repro.serve import Request

AMOEBA = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                      min_phase_steps=2)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-14b", reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def lease_planner(**kw):
    kw.setdefault("enabled", True)
    return LeasePlanner(LeaseConfig(**kw), long_threshold=24)


def req(rid, tokens, generated=0):
    r = Request(rid, [1] * 4, tokens)
    r.generated = [0] * generated
    return r


def hot_borrower(gid=1, slots=4, queue=6):
    """A group with every slot busy and a backlog: the lease customer."""
    return FakeGroup(gid, (slots,),
                     parts=[[req(100 * gid + i, 5, 1)
                             for i in range(slots)]],
                     queue=[req(100 * gid + 50 + i, 4)
                            for i in range(queue)])


def assert_books_clean(p, groups):
    assert p.active == []
    for g in groups:
        assert all(x == 0 for x in g._lent), (g.gid, g._lent)
        assert all(x == 0 for x in g._borrowed), (g.gid, g._borrowed)


# -- granting ------------------------------------------------------------------

def test_grant_widens_borrower_and_shrinks_lender():
    lender = FakeGroup(0, (4,))            # fully idle
    borrower = hot_borrower()
    groups = [lender, borrower]
    p = lease_planner()
    p.bind(groups)
    assert lender._lease_book is p
    p.step(0, groups)
    assert p.grants == 1 and len(p.active) == 1
    n = p.active[0].slots
    # max_frac 0.5 of a 4-slot part: at most 2 slots out
    assert 0 < n <= 2
    assert lender._lent == [n] and borrower._borrowed == [n]
    assert lender.effective_slots(0) == 4 - n
    assert borrower.effective_slots(0) == 4 + n
    assert p.lent_at((0, 0)) == n and p.borrowed_at((1, 0)) == n
    assert lender.stats.leases_out == n
    assert borrower.stats.leases_in == n
    # fleet-wide effective capacity is conserved
    assert sum(g.effective_slots(i) for g in groups
               for i in range(len(g.topology))) == 8


def test_term_is_bounded_and_expiry_returns_the_slots():
    groups = [FakeGroup(0, (4,)), hot_borrower()]
    p = lease_planner(max_term=8)
    p.bind(groups)
    p.step(0, groups)
    (l,) = p.active
    assert l.expires - l.granted <= 8
    groups[1].queue.clear()                # burst over before expiry
    groups[1]._parts[0].clear()
    p.step(l.expires, groups)
    assert p.expires == 1
    assert_books_clean(p, groups)


def test_lender_heat_revokes_early():
    groups = [FakeGroup(0, (4,)), hot_borrower()]
    p = lease_planner()
    p.bind(groups)
    p.step(0, groups)
    assert p.grants == 1
    # the lender's own queue heats past revoke_threshold: slots go home
    # well before the term is up
    groups[0].queue.extend(req(200 + i, 8) for i in range(6))
    p.step(4, groups)
    assert p.revokes == 1 and p.expires == 0
    assert_books_clean(p, groups)


def test_idle_borrower_returns_slots_before_expiry():
    groups = [FakeGroup(0, (4,)), hot_borrower()]
    p = lease_planner()
    p.bind(groups)
    p.step(0, groups)
    assert p.grants == 1
    groups[1].queue.clear()                # burst passed, width unused
    p.step(4, groups)
    assert p.revokes == 1
    assert_books_clean(p, groups)


def test_min_gain_vetoes_and_counts_rejections():
    groups = [FakeGroup(0, (4,)), hot_borrower()]
    # the fixture's best gain is exactly 0.5 (2 slots, full term, fused
    # 4*term): a floor at 0.5 vetoes it
    p = lease_planner(min_gain=0.5)
    p.bind(groups)
    p.step(0, groups)
    assert p.grants == 0 and p.rejected_amortization == 1
    assert_books_clean(p, groups)


def test_lender_always_keeps_one_resident_slot():
    # max_frac=1.0 would allow lending a part entire: the resident-slot
    # floor must still hold one back, or the part could never drain its
    # own admissions again
    groups = [FakeGroup(0, (2,)), hot_borrower()]
    p = lease_planner(max_frac=1.0)
    p.bind(groups)
    p.step(0, groups)
    assert p.grants == 1
    assert groups[0]._lent == [1]
    assert groups[0].effective_slots(0) == 1


def test_intra_group_lease_from_stranded_slots():
    """A split group lends its quarantine slice's stranded idle slots to
    its own wide part — no lender-heat veto (the 'lender queue' is the
    borrower's own backlog) and no backfill loss."""
    g = FakeGroup(0, (5, 3),
                  parts=[[req(i, 5, 1) for i in range(5)],
                         [req(10, 40, 1)]],   # 1 long rider, 2 stranded
                  queue=[req(20 + i, 4) for i in range(6)])
    p = lease_planner()
    p.bind([g])
    p.step(0, [g])
    assert p.grants == 1
    (l,) = p.active
    assert l.lender == (0, 1) and l.borrower == (0, 0)
    assert g.effective_slots(0) == 5 + l.slots
    assert g.effective_slots(1) == 3 - l.slots


def test_reserved_parts_neither_lend_nor_borrow():
    lender = FakeGroup(0, (4,))
    borrower = hot_borrower()
    groups = [lender, borrower]
    p = lease_planner()
    p.bind(groups)
    p.step(0, groups, reserved={(0, 0), (1, 0)})
    assert p.grants == 0 and p.active == []


# -- mesh confinement (the cluster wiring) -------------------------------------

def _mesh_fixture(noc_bandwidth=4e9):
    mesh = ClusterMesh(num_groups=4, groups_per_chip=2)
    ccfg = ClusterConfig(groups_per_chip=2, noc_bandwidth=noc_bandwidth)
    cost = TieredTransferCost.from_config(mesh, ccfg, dtype_bytes=2,
                                          quantized=False)
    return mesh, cost


def test_mesh_confines_leases_to_same_chip_neighbors():
    mesh, cost = _mesh_fixture()
    chipmates = mesh.chip_groups(1)        # the borrower's chip
    gb = chipmates[-1]
    groups = [hot_borrower(gid=g, queue=6) if g == gb
              else FakeGroup(g, (4,)) for g in range(4)]
    p = lease_planner()
    p.mesh, p.cost = mesh, cost
    p.bind(groups)
    p.step(0, groups)
    assert p.grants >= 1
    # every lender is a same-chip neighbor, never a cross-chip group
    for l in p.active:
        assert l.lender[0] in chipmates, (l.lender, chipmates)


def test_dead_noc_link_vetoes_cross_group_leases():
    mesh, cost = _mesh_fixture(noc_bandwidth=0.0)   # NoC down
    chipmates = mesh.chip_groups(1)
    gb = chipmates[-1]
    groups = [hot_borrower(gid=g, queue=6) if g == gb
              else FakeGroup(g, (4,)) for g in range(4)]
    p = lease_planner()
    p.mesh, p.cost = mesh, cost
    p.bind(groups)
    p.step(0, groups)
    assert p.grants == 0 and p.active == []


# -- force-revoke (the reconfiguration boundary) -------------------------------

def test_force_revoke_clears_every_lease_touching_the_group():
    groups = [FakeGroup(0, (4, 4)), hot_borrower(gid=1),
              hot_borrower(gid=2)]
    p = lease_planner(max_grants=4)
    p.bind(groups)
    p.step(0, groups)
    assert p.grants >= 2                   # lender 0 serves both hot groups
    p.force_revoke(0, reason="reconfig")
    assert_books_clean(p, groups)
    assert p.revokes >= 2


# -- end to end (real engine) --------------------------------------------------

def _lease_fleet(enabled, obs="summary", **kw):
    fleet = FleetConfig(num_groups=2, capacity=4, router="sticky",
                        mode="dynamic", engine="object", obs=obs,
                        migrate=MigrationConfig(enabled=True),
                        amoeba=AMOEBA, **kw)
    return fleet.replace(lease=fleet.lease.replace(enabled=enabled))


def test_lease_fleet_end_to_end_zero_stall_and_clean_books(setup):
    """Leases grant under a rotating burst, every one is returned, the
    books are clean after reconfigs, and — the contract — no reconfig
    stall is ever attributable to a lease grant."""
    cfg, params = setup
    eng = FleetEngine(cfg, params, fleet=_lease_fleet(True, obs="full"))
    trace = transient_burst_trace(60, cfg.vocab_size, seed=1, shards=2,
                                  burst_len=20)
    eng.submit(trace)
    s = eng.run(max_ticks=400)
    assert s["completed"] == s["submitted"] == len(trace)
    lease = s["lease"]
    assert lease["grants"] > 0
    assert lease["stall_ticks_charged"] == 0
    assert lease["active"] == 0
    assert lease["grants"] == lease["revokes"] + lease["expires"]
    assert s["obs"]["by_kind"]["lease"] \
        == lease["grants"] + lease["revokes"] + lease["expires"]
    # groups reconfigured during the run, so leases crossed the
    # force-revoke boundary; the books must still balance
    assert s["obs"]["by_kind"].get("reconfig", 0) > 0
    for g in eng.groups:
        assert all(x == 0 for x in g._lent)
        assert all(x == 0 for x in g._borrowed)
    snaps = s["groups"]
    assert sum(x["leases_out"] for x in snaps) \
        == sum(x["leases_in"] for x in snaps) > 0


def test_lease_disabled_summary_is_unchanged(setup):
    """lease.enabled=False must be bit-identical to a build without the
    subsystem: no lease block, same books as the seed path."""
    cfg, params = setup
    results = {}
    for label, enabled in (("off", False), ("on", True)):
        eng = FleetEngine(cfg, params, fleet=_lease_fleet(enabled))
        trace = transient_burst_trace(40, cfg.vocab_size, seed=2,
                                      shards=2, burst_len=16)
        eng.submit(trace)
        results[label] = eng.run(max_ticks=400)
    assert "lease" not in results["off"]
    assert results["off"]["completed"] == results["off"]["submitted"]
    assert "lease" in results["on"]
