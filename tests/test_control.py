"""Unit + property tests for the repro.control plane.

The controller invariants the refactor must pin:
(a) hysteresis + dwell never toggles the split state in consecutive ticks,
(b) every applied ConfigSpace transition passed the amortization check,
(c) a saved/loaded predictor produces byte-identical decisions.
"""
import os

import numpy as np
import pytest

from repro.configs.base import AmoebaConfig
from repro.control import (ConfigSpace, ControlState, FeatureVector,
                           FleetController, GroupController, OnlinePolicy,
                           OraclePolicy, PredictorPolicy, ReplayBuffer,
                           ThresholdPolicy, build_serve_corpus, make_policy,
                           train_serve_predictor)
from repro.core import predictor as P
from repro.core.controller import AmoebaController


def fv_of(remaining, queue=0, rate=0.0, capacity=8):
    return FeatureVector.from_group(np.asarray(remaining, np.float64),
                                    queue, rate, capacity)


# -- ConfigSpace ---------------------------------------------------------------

def test_config_space_topologies_and_names():
    sp = ConfigSpace(capacity=8, max_ways=4)
    assert sp.topologies() == (1, 2, 4)
    assert [sp.name(w) for w in sp.topologies()] == ["1x8", "2x4", "4x2"]
    assert ConfigSpace(capacity=4, max_ways=8).topologies() == (1, 2, 4)
    assert ConfigSpace(capacity=2, max_ways=2).topologies() == (1, 2)


def test_config_space_partition_reduces_to_regroup_pair():
    from repro.core.regroup import POLICIES
    sp = ConfigSpace(capacity=8, max_ways=2)
    rem = [100.0, 5.0, 90.0, 3.0]
    fast, slow = POLICIES["warp_regroup"](list(range(4)), rem)
    assert sp.partition(list(range(4)), rem, 2) == [fast, slow]


def test_config_space_deeper_split_never_costs_more():
    sp = ConfigSpace(capacity=8, max_ways=4)
    rem = [100.0, 5.0, 90.0, 3.0, 80.0, 2.0, 70.0, 1.0]
    assert sp.gain(rem, 4) >= sp.gain(rem, 2) >= 0.0


def test_config_space_transition_legality():
    sp = ConfigSpace(capacity=8, max_ways=4, min_gain=0.05)
    assert sp.transition_ok(1, 2, gain=0.2)
    assert not sp.transition_ok(1, 2, gain=0.01)      # under the floor
    assert not sp.transition_ok(1, 4, gain=0.9)       # skips a rung
    assert sp.transition_ok(4, 2, gain=0.0)           # fusing always amortizes
    assert not sp.transition_ok(2, 2, gain=1.0)


# -- policies ------------------------------------------------------------------

def test_threshold_policy_matches_legacy_semantics():
    pol = ThresholdPolicy(split_threshold=0.3, fuse_threshold=0.1)
    hot = fv_of([100.0, 5.0, 90.0, 3.0])
    assert pol.decide(hot, 1).ways == 2
    calm = fv_of([5.0, 5.0, 5.0, 5.0])
    assert pol.decide(calm, 1).ways == 1
    assert pol.decide(calm, 2).ways == 1              # re-fuse under the band


def test_oracle_policy_climbs_toward_best_topology():
    sp = ConfigSpace(capacity=8, max_ways=4)
    pol = OraclePolicy(space=sp, margin=0.01)
    divergent = fv_of([100.0, 5.0, 90.0, 3.0, 80.0, 2.0, 70.0, 1.0])
    d = pol.decide(divergent, 1)
    assert d.ways == 2                                # one rung per tick
    assert pol.decide(divergent, 2).ways == 4
    lockstep = fv_of([5.0, 5.0, 5.0, 5.0])
    assert pol.decide(lockstep, 2).ways == 1


def test_online_policy_bootstraps_then_refits():
    buf = ReplayBuffer(maxlen=512)
    pol = OnlinePolicy(replay=buf, refit_every=16, min_samples=32,
                       train_steps=120)
    assert not pol.fitted
    X, y = build_serve_corpus(n_samples=64, seed=3)
    for xi, yi in zip(X, y):
        buf.add(xi, yi)
    hot = fv_of([100.0, 5.0, 90.0, 3.0])
    for _ in range(20):
        pol.decide(hot, 1)
    assert pol.fitted and pol.refits >= 1
    assert pol.refit_info[-1]["train_accuracy"] > 0.8
    assert len(pol.refit_info[-1]["loss_history_tail"]) == 5


def test_make_policy_factory():
    sp = ConfigSpace(capacity=8)
    assert make_policy("threshold", space=sp).name == "threshold"
    assert make_policy("oracle", space=sp).name == "oracle"
    assert make_policy("online", space=sp).name == "online"
    with pytest.raises(ValueError, match="predictor"):
        make_policy("predictor", space=sp)
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("nope", space=sp)


def test_train_logistic_returns_loss_history():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(200, 3))
    y = (X[:, 0] > 0).astype(float)
    _, info = P.train_logistic(X, y, steps=50)
    hist = info["loss_history"]
    assert isinstance(hist, list) and len(hist) == 50
    assert hist[-1] < hist[0]
    import json
    json.dumps(hist)          # artifact-safe: plain floats, not ndarray


# -- GroupController ----------------------------------------------------------

def test_group_controller_dwell_blocks_early_moves():
    gc = GroupController(ThresholdPolicy(0.3, 0.1), ConfigSpace(8, 2),
                         dwell=3)
    hot = fv_of([100.0, 5.0, 90.0, 3.0])
    assert [gc.observe(hot) for _ in range(4)] == [1, 1, 2, 2]


def test_group_controller_max_ways_now_caps_splitting():
    gc = GroupController(ThresholdPolicy(0.3, 0.1), ConfigSpace(8, 2),
                         dwell=1)
    hot = fv_of([100.0, 2.0])
    assert gc.observe(hot, max_ways_now=1) == 1       # can't split a loner
    assert gc.observe(hot, max_ways_now=2) == 2


def test_group_controller_hint_respects_dwell_and_space():
    gc = GroupController(ThresholdPolicy(0.9, 0.0), ConfigSpace(8, 2),
                         dwell=2)
    calm = fv_of([50.0, 45.0, 48.0, 47.0])
    gc.request_topology(2)
    assert gc.observe(calm) == 1                      # dwell not yet served
    assert gc.observe(calm) == 2                      # hint applied via space
    assert gc.state.transitions[-1][4] == "fleet rebalance"


def test_hint_survives_rejected_attempt():
    """A fleet nudge capped by max_ways_now must retry, not vanish."""
    gc = GroupController(ThresholdPolicy(0.9, 0.0), ConfigSpace(8, 2),
                         dwell=1)
    calm = fv_of([50.0, 45.0])
    gc.request_topology(2)
    assert gc.observe(calm, max_ways_now=1) == 1   # capped: hint retained
    assert gc.observe(calm, max_ways_now=2) == 2   # applied next tick
    assert gc._hint is None                        # retired once reached


def test_facade_keeps_legacy_api():
    cfg = AmoebaConfig(min_phase_steps=1, split_threshold=0.3,
                       fuse_threshold=0.1)
    ctl = AmoebaController(cfg)
    lens = np.array([100.0, 5.0, 90.0, 3.0])
    assert ctl.observe(0.5, lens) is True
    st = ctl.split_state
    assert st.split and len(st.history) == 1
    assert st.history[0][1] is True
    fast, slow = ctl.layout([0, 1, 2, 3], lens)
    assert set(fast) == {1, 3} and set(slow) == {0, 2}


# -- FleetController -----------------------------------------------------------

def test_fleet_controller_targets_long_fraction():
    fc = FleetController(long_threshold=24)
    assert fc.desired_split_groups(0.0, 4) == 0
    assert fc.desired_split_groups(0.5, 4) == 2
    assert fc.desired_split_groups(1.0, 4) == 4


def test_fleet_controller_nudges_groups():
    class FakeReq:
        def __init__(self, n):
            self.remaining = n
            self.max_new_tokens = n

    class FakeGroup:
        def __init__(self, live):
            self.controller = GroupController(
                ThresholdPolicy(0.99, 0.0), ConfigSpace(8, 2), dwell=1)
            self._live = [FakeReq(n) for n in live]
            self.queue = []

        def live_requests(self):
            return self._live

        def load(self):
            return sum(r.remaining for r in self._live)

    groups = [FakeGroup([100, 2, 90, 3]), FakeGroup([5, 4, 6, 5])]
    fc = FleetController(long_threshold=24, every=1)
    issued = fc.rebalance(0, groups)
    assert issued == 1
    # the divergent group got the split hint, the lockstep one did not
    assert groups[0].controller._hint == 2
    assert groups[1].controller._hint is None


# -- replay / labels -----------------------------------------------------------

def test_group_controller_logs_realized_win_labels():
    buf = ReplayBuffer()
    gc = GroupController(ThresholdPolicy(0.3, 0.1), ConfigSpace(8, 2),
                         dwell=2, replay=buf, label_margin=0.02)
    gc.observe(fv_of([100.0, 5.0, 90.0, 3.0]))       # splitting clearly wins
    gc.observe(fv_of([5.0, 5.0, 5.0, 5.0]))          # lockstep: no win
    X, y = buf.dataset()
    assert X.shape[0] == 2 and list(y) == [1.0, 0.0]


def test_serve_predictor_learns_the_corpus():
    model, info = train_serve_predictor(n_samples=512, steps=400, seed=0)
    assert info["train_accuracy"] > 0.85


# -- fast suggest_* (shared-ordering evaluator) --------------------------------

def _brute_best(sp, cands, r, policy):
    """The pre-optimization argmin: full slot_cost per candidate."""
    return min(cands, key=lambda t: (sp.slot_cost(r, t, policy), len(t), t))


def test_fast_suggests_match_brute_force():
    """suggest_split/improve/fuse must pick exactly the brute-force
    argmin over the public slot_cost — the fast path is an evaluation
    strategy, never a behavior change."""
    rng = np.random.default_rng(0)
    for _ in range(120):
        cap = int(rng.integers(2, 13))
        sp = ConfigSpace(capacity=cap, max_ways=int(rng.integers(2, 7)),
                         hetero=bool(rng.integers(0, 2)))
        comps = sp.compositions()
        cur = comps[rng.integers(0, len(comps))]
        r = rng.integers(1, 40, int(rng.integers(2, cap + 2))
                         ).astype(np.float64)
        policy = ("warp_regroup", "direct_split")[rng.integers(0, 2)]

        cands = [t for t in sp.split_moves(cur) if len(t) <= r.size]
        if cands:
            assert sp.suggest_split(cur, r, policy) == \
                _brute_best(sp, cands, r, policy)
        cands = sp.fuse_moves(cur)
        if cands:
            assert sp.suggest_fuse(cur, r, policy) == \
                _brute_best(sp, cands, r, policy)
        cands = [t for t in sp.split_moves(cur) + sp.resize_moves(cur)
                 if len(t) <= r.size]
        if cands:
            best = _brute_best(sp, cands, r, policy)
            want = best if sp.slot_cost(r, best, policy) \
                < sp.slot_cost(r, cur, policy) - 1e-12 else None
            assert sp.suggest_improve(cur, r, policy) == want


def test_ordered_cost_bit_identical_to_slot_cost():
    rng = np.random.default_rng(1)
    for _ in range(200):
        cap = int(rng.integers(1, 17))
        sp = ConfigSpace(capacity=cap, max_ways=int(rng.integers(1, 9)),
                         hetero=True)
        comps = sp.compositions()
        t = comps[rng.integers(0, len(comps))]
        r = rng.integers(0, 50, int(rng.integers(0, cap + 3))
                         ).astype(np.float64)
        for policy in ("warp_regroup", "direct_split"):
            r_ord = sp._policy_order(r, policy) if r.size else r
            assert sp._ordered_cost(r_ord, t) == sp.slot_cost(r, t, policy)
