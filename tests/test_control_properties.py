"""Hypothesis property tests for repro.control invariants.

Split from test_control.py so the whole-module importorskip (the
repo's established pattern, cf. test_properties.py) only skips the
property suite where hypothesis is unavailable.
"""
import os

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.control import (ConfigSpace, FeatureVector, GroupController,
                           OraclePolicy, PredictorPolicy, ThresholdPolicy,
                           train_serve_predictor)
from repro.core import predictor as P


def fv_of(remaining, queue=0, rate=0.0, capacity=8):
    return FeatureVector.from_group(np.asarray(remaining, np.float64),
                                    queue, rate, capacity)


divergences = st.lists(st.floats(min_value=0.0, max_value=0.95,
                                 allow_nan=False),
                       min_size=4, max_size=64)


@given(divergences, st.integers(2, 6))
@settings(max_examples=60, deadline=None)
def test_hysteresis_dwell_never_toggles_consecutively(divs, dwell):
    """(a) the dwell makes consecutive-tick topology changes impossible."""
    gc = GroupController(ThresholdPolicy(0.3, 0.1), ConfigSpace(8, 2),
                         dwell=dwell)
    prev, prev_changed = 1, False
    for d in divs:
        ways = gc.observe(FeatureVector(divergence=d))
        changed = ways != prev
        assert not (changed and prev_changed), "toggled on consecutive ticks"
        prev, prev_changed = ways, changed


remaining_lists = st.lists(
    st.floats(min_value=0.0, max_value=1e3, allow_nan=False),
    min_size=2, max_size=16)


@given(st.lists(remaining_lists, min_size=4, max_size=24),
       st.sampled_from([2, 4]), st.floats(0.0, 0.2))
@settings(max_examples=40, deadline=None)
def test_transitions_always_pass_amortization(batches, max_ways, min_gain):
    """(b) every applied transition satisfied the ConfigSpace check."""
    space = ConfigSpace(capacity=8, max_ways=max_ways, min_gain=min_gain)
    gc = GroupController(OraclePolicy(space=space, margin=0.01), space,
                         dwell=1)
    for rem in batches:
        gc.observe(fv_of(rem))
    for _step, frm, to, gain, _reason in gc.state.transitions:
        assert to in space.neighbors(frm)
        if to > frm:
            assert gain > space.min_gain


@pytest.fixture(scope="module")
def saved_predictor(tmp_path_factory):
    model, _ = train_serve_predictor(n_samples=256, steps=200, seed=1)
    path = os.path.join(str(tmp_path_factory.mktemp("model")), "m.json")
    P.save_model(model, path)
    return model, P.load_model(path)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_predictor_roundtrip_identical_decisions(saved_predictor, seed):
    """(c) save_model/load_model roundtrip preserves every decision."""
    model, m2 = saved_predictor
    a = PredictorPolicy(model=model, space=ConfigSpace(8, 2))
    b = PredictorPolicy(model=m2, space=ConfigSpace(8, 2))
    rng = np.random.default_rng(seed)
    for _ in range(8):
        rem = rng.integers(0, 120, rng.integers(2, 9)).astype(float)
        fv = fv_of(rem, queue=int(rng.integers(0, 16)),
                   rate=float(rng.uniform(0, 2)))
        for ways in (1, 2):
            da, db = a.decide(fv, ways), b.decide(fv, ways)
            assert da.ways == db.ways
            assert abs(da.proba - db.proba) < 1e-9
