"""Hypothesis property tests for repro.control invariants.

Split from test_control.py so the whole-module importorskip (the
repo's established pattern, cf. test_properties.py) only skips the
property suite where hypothesis is unavailable.  The same contracts are
pinned with concrete cases in test_topology.py, which always runs.
"""
import itertools
import os

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.control import (ConfigSpace, FeatureVector, GroupController,
                           OraclePolicy, PredictorPolicy, ThresholdPolicy,
                           train_serve_predictor)
from repro.core import predictor as P


def fv_of(remaining, queue=0, rate=0.0, capacity=8):
    return FeatureVector.from_group(np.asarray(remaining, np.float64),
                                    queue, rate, capacity)


divergences = st.lists(st.floats(min_value=0.0, max_value=0.95,
                                 allow_nan=False),
                       min_size=4, max_size=64)


@given(divergences, st.integers(2, 6))
@settings(max_examples=60, deadline=None)
def test_hysteresis_dwell_never_toggles_consecutively(divs, dwell):
    """(a) the dwell makes consecutive-tick topology changes impossible
    for a freshly reconfigured part (all parts reset on the first split
    from fused, so the whole group is pinned here)."""
    gc = GroupController(ThresholdPolicy(0.3, 0.1), ConfigSpace(8, 2),
                         dwell=dwell)
    prev, prev_changed = 1, False
    for d in divs:
        ways = gc.observe(FeatureVector(divergence=d))
        changed = ways != prev
        assert not (changed and prev_changed), "toggled on consecutive ticks"
        prev, prev_changed = ways, changed


remaining_lists = st.lists(
    st.floats(min_value=0.0, max_value=1e3, allow_nan=False),
    min_size=2, max_size=16)


@given(st.lists(remaining_lists, min_size=4, max_size=24),
       st.sampled_from([2, 4, 8]), st.floats(0.0, 0.2),
       st.booleans())
@settings(max_examples=40, deadline=None)
def test_transitions_always_pass_amortization(batches, max_ways, min_gain,
                                              hetero):
    """(b) every applied move is a single-step lattice neighbor that
    satisfied the ConfigSpace per-part amortization check."""
    space = ConfigSpace(capacity=8, max_ways=max_ways, min_gain=min_gain,
                        hetero=hetero)
    gc = GroupController(OraclePolicy(space=space, margin=0.01), space,
                         dwell=1)
    for rem in batches:
        gc.observe(fv_of(rem))
    for _step, frm, to, gain, _reason in gc.state.transitions:
        assert to in space.neighbors(frm)
        assert space.legal(to)
        if len(to) >= len(frm):            # split or re-cut must amortize
            assert gain > space.min_gain


# -- composition-lattice invariants (the heterogeneous-topology refactor) ------

def brute_force_compositions(capacity, max_parts):
    out = set()
    for k in range(1, min(max_parts, capacity) + 1):
        for cuts in itertools.combinations(range(1, capacity), k - 1):
            bounds = (0,) + cuts + (capacity,)
            out.add(tuple(bounds[i + 1] - bounds[i]
                          for i in range(len(bounds) - 1)))
    return out


@given(st.integers(2, 10), st.integers(1, 10))
@settings(max_examples=40, deadline=None)
def test_composition_enumeration_exhaustive(capacity, max_ways):
    """compositions() is exactly the set of integer compositions of the
    capacity into at most max_ways parts."""
    sp = ConfigSpace(capacity=capacity, max_ways=max_ways)
    got = set(sp.compositions())
    assert got == brute_force_compositions(capacity, max_ways)


@given(st.integers(2, 9), st.integers(2, 9))
@settings(max_examples=30, deadline=None)
def test_every_topology_reachable_from_fused(capacity, max_ways):
    """Every composition is reachable from fused via single-part moves."""
    sp = ConfigSpace(capacity=capacity, max_ways=max_ways)
    seen = {(capacity,)}
    frontier = [(capacity,)]
    while frontier:
        nxt = []
        for t in frontier:
            for nb in sp.neighbors(t):
                if nb not in seen:
                    seen.add(nb)
                    nxt.append(nb)
        frontier = nxt
    assert seen == set(sp.compositions())


@given(st.lists(st.floats(min_value=0.0, max_value=1e3, allow_nan=False),
                min_size=2, max_size=8),
       st.integers(2, 8),
       st.sampled_from(["warp_regroup", "direct_split"]))
@settings(max_examples=80, deadline=None)
def test_partition_conserves_indices_within_budgets(rem, max_ways, policy):
    """partition() is a permutation split: every index appears exactly
    once, no part exceeds its slot budget, and when the batch is large
    enough no part is left empty (an empty part would price its slots
    at zero)."""
    sp = ConfigSpace(capacity=8, max_ways=max_ways)
    for t in sp.compositions():
        parts = sp.partition(list(range(len(rem))), rem, t, policy)
        flat = sorted(i for p in parts for i in p)
        assert flat == list(range(len(rem)))
        assert len(parts) == len(t)
        for s, p in zip(t, parts):
            assert len(p) <= s
        if len(rem) >= len(t):
            assert all(len(p) >= 1 for p in parts)


@given(st.lists(st.floats(min_value=1.0, max_value=1e3, allow_nan=False),
                min_size=2, max_size=8))
@settings(max_examples=60, deadline=None)
def test_best_topology_never_worse_than_ladder(rem):
    """The composition argmax dominates the balanced-ladder argmax."""
    sp = ConfigSpace(capacity=8, max_ways=8)
    _, ladder_gain = sp.best_ways(rem)
    _, comp_gain = sp.best_topology(rem)
    assert comp_gain >= ladder_gain - 1e-9


@pytest.fixture(scope="module")
def saved_predictor(tmp_path_factory):
    model, _ = train_serve_predictor(n_samples=256, steps=200, seed=1)
    path = os.path.join(str(tmp_path_factory.mktemp("model")), "m.json")
    P.save_model(model, path)
    return model, P.load_model(path)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_predictor_roundtrip_identical_decisions(saved_predictor, seed):
    """(c) save_model/load_model roundtrip preserves every decision."""
    model, m2 = saved_predictor
    a = PredictorPolicy(model=model, space=ConfigSpace(8, 2))
    b = PredictorPolicy(model=m2, space=ConfigSpace(8, 2))
    rng = np.random.default_rng(seed)
    for _ in range(8):
        rem = rng.integers(0, 120, rng.integers(2, 9)).astype(float)
        fv = fv_of(rem, queue=int(rng.integers(0, 16)),
                   rate=float(rng.uniform(0, 2)))
        for ways in (1, 2):
            da, db = a.decide(fv, ways), b.decide(fv, ways)
            assert da.ways == db.ways
            assert da.topology == db.topology
            assert abs(da.proba - db.proba) < 1e-9