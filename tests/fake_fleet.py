"""Lightweight in-memory groups implementing the migration protocol.

``repro.fleet.migrate`` plans and executes against a small group surface
(``queue`` / ``topology`` / ``part_live`` / ``stats`` / ``can_insert`` /
``extract_live`` / ``insert_live`` / ``submit``) so its invariants can be
pinned without spinning up a JAX model.  :class:`FakeGroup` implements
exactly that surface over plain lists; the real
``repro.serve.engine.ReconfigurableGroup`` is exercised by the
end-to-end tests in ``test_migrate.py``.
"""
import collections
from typing import List, Optional

from repro.serve.engine import Request, ServeStats


class FakeGroup:
    """Parts are lists of live Requests; KV rows are opaque tokens."""

    def __init__(self, gid: int, topology, queue=(), parts=None):
        self.gid = gid
        self._topology = tuple(topology)
        self.queue = collections.deque(queue)
        self.stats = ServeStats()
        self._parts: List[List[Request]] = \
            [list(p) for p in parts] if parts is not None \
            else [[] for _ in self._topology]
        assert len(self._parts) == len(self._topology)
        self.stall: List[int] = [0] * len(self._topology)
        # slack-lease books, mirroring ReconfigurableGroup
        self._lent: List[int] = [0] * len(self._topology)
        self._borrowed: List[int] = [0] * len(self._topology)
        self._lease_book = None

    @property
    def topology(self):
        return self._topology

    def part_live(self, i: int) -> List[Request]:
        return [r for r in self._parts[i] if not r.done]

    # -- slack leases (same surface as ReconfigurableGroup) --------------------

    def effective_slots(self, i: int) -> int:
        return self._topology[i] - self._lent[i] + self._borrowed[i]

    def _part_live_n(self, i: int) -> int:
        return len(self.part_live(i))

    def lease_out(self, i: int, n: int) -> None:
        assert 0 < n and self._lent[i] + n < self._topology[i] \
            + self._borrowed[i]
        self._lent[i] += n

    def lease_back(self, i: int, n: int) -> None:
        assert 0 < n <= self._lent[i]
        self._lent[i] -= n

    def lease_in(self, i: int, n: int) -> None:
        assert n > 0
        self._borrowed[i] += n

    def lease_return(self, i: int, n: int) -> None:
        assert 0 < n <= self._borrowed[i]
        self._borrowed[i] -= n

    def live_requests(self) -> List[Request]:
        return [r for p in self._parts for r in p if not r.done]

    def load(self) -> float:
        return (sum(r.remaining for r in self.live_requests())
                + sum(r.max_new_tokens for r in self.queue))

    def submit(self, requests, now: int = 0,
               part: Optional[int] = None) -> None:
        for r in requests:
            if part is not None:
                r.part_affinity = part
            self.queue.append(r)

    def can_insert(self, part: int) -> bool:
        return (0 <= part < len(self._topology)
                and len(self.part_live(part)) < self._topology[part])

    def extract_live(self, req: Request):
        for p in self._parts:
            for j, r in enumerate(p):
                if r is req and not r.done:
                    del p[j]
                    self.stats.migrations_out += 1
                    return ("kv", req.rid), ("last", req.rid)
        return None

    def insert_live(self, req: Request, state, last, part: int,
                    stall: int = 0) -> bool:
        if not self.can_insert(part):
            return False
        self._parts[part].append(req)
        self.stall[part] = max(self.stall[part], int(stall))
        self.stats.migrations_in += 1
        return True


def all_requests(groups) -> List[Request]:
    """Every request anywhere in the fake fleet (queues + parts)."""
    out: List[Request] = []
    for g in groups:
        out.extend(g.queue)
        for p in g._parts:
            out.extend(p)
    return out
