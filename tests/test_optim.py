"""AdamW math (incl. the lax.map stacked-leaf path) and schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         global_norm, global_norm_clip)


def _reference_adamw(p, g, m, v, t, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    return p - lr * (mh / (np.sqrt(vh) + eps) + wd * p), m, v


def test_adamw_matches_reference():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)}
    st = adamw_init(p)
    lr = 1e-2
    new_p, st2 = adamw_update(p, g, st, lr=lr)
    ref_p, ref_m, ref_v = _reference_adamw(
        np.asarray(p["w"]), np.asarray(g["w"]),
        np.zeros((4, 5)), np.zeros((4, 5)), 1, lr)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref_p, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st2.m["w"]), ref_m, rtol=1e-5)
    assert int(st2.step) == 1


def test_adamw_stacked_map_path_matches_direct():
    """ndim>=3 leaves go through lax.map — must equal the direct math."""
    rng = np.random.default_rng(1)
    stacked = jnp.asarray(rng.normal(size=(12, 6, 4)), jnp.float32)
    gs = jnp.asarray(rng.normal(size=(12, 6, 4)), jnp.float32)
    p1 = {"w": stacked}
    st1 = adamw_init(p1)
    out1, _ = adamw_update(p1, {"w": gs}, st1, lr=1e-2)
    # same update applied layer-by-layer through the 2D path
    outs = []
    for i in range(12):
        pi = {"w": stacked[i]}
        sti = adamw_init(pi)
        oi, _ = adamw_update(pi, {"w": gs[i]}, sti, lr=1e-2)
        outs.append(np.asarray(oi["w"]))
    np.testing.assert_allclose(np.asarray(out1["w"]), np.stack(outs),
                               rtol=1e-5, atol=1e-6)


def test_grad_scale_equals_explicit_clip():
    rng = np.random.default_rng(2)
    p = {"w": jnp.asarray(rng.normal(size=(3, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(3, 3)) * 10, jnp.float32)}
    norm = global_norm(g)
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(norm, 1e-9))
    clipped, norm2 = global_norm_clip(g, 1.0)
    assert abs(float(norm) - float(norm2)) < 1e-5
    o1, _ = adamw_update(p, g, adamw_init(p), lr=1e-2, grad_scale=scale)
    o2, _ = adamw_update(p, clipped, adamw_init(p), lr=1e-2)
    np.testing.assert_allclose(np.asarray(o1["w"]), np.asarray(o2["w"]),
                               rtol=1e-5)


def test_cosine_schedule_shape():
    lr = [float(cosine_schedule(jnp.asarray(s), base_lr=1.0, warmup=10,
                                total=100)) for s in range(100)]
    assert lr[0] == 0.0
    assert abs(lr[10] - 1.0) < 0.11
    assert lr[99] < 0.2
    assert all(a >= b - 1e-6 for a, b in zip(lr[10:], lr[11:]))  # decreasing
