"""Unit tests for the composition-topology ConfigSpace (no hypothesis).

The property-style invariants also live in test_control_properties.py
under hypothesis; this file pins the same contracts with concrete cases
so they run in environments without hypothesis installed.
"""
import itertools

import numpy as np
import pytest

from repro.control import (ConfigSpace, GroupController, OraclePolicy,
                           ReplayBuffer, ThresholdPolicy, balanced,
                           topology_name)
from repro.control.features import FeatureVector
from repro.control.policies import OnlinePolicy
from repro.core import predictor as P


def fv_of(remaining, queue=0, rate=0.0, capacity=8):
    return FeatureVector.from_group(np.asarray(remaining, np.float64),
                                    queue, rate, capacity)


def brute_force_compositions(capacity, max_parts):
    out = set()
    for k in range(1, min(max_parts, capacity) + 1):
        for cuts in itertools.combinations(range(1, capacity), k - 1):
            bounds = (0,) + cuts + (capacity,)
            out.add(tuple(bounds[i + 1] - bounds[i]
                          for i in range(len(bounds) - 1)))
    return out


# -- enumeration ---------------------------------------------------------------

@pytest.mark.parametrize("capacity,max_ways", [(4, 4), (6, 3), (8, 4), (8, 8)])
def test_composition_enumeration_exhaustive(capacity, max_ways):
    sp = ConfigSpace(capacity=capacity, max_ways=max_ways)
    got = set(sp.compositions())
    assert got == brute_force_compositions(capacity, max_ways)
    for t in got:
        assert sum(t) == capacity and all(p >= 1 for p in t)
        assert len(t) <= max_ways


def test_ladder_space_is_the_balanced_special_case():
    sp = ConfigSpace(capacity=8, max_ways=4, hetero=False)
    assert sp.compositions() == ((8,), (4, 4), (2, 2, 2, 2))
    assert not sp.legal((5, 3))
    assert ConfigSpace(capacity=8, max_ways=4).legal((5, 3))


def test_balanced_covers_non_power_of_two():
    assert balanced(8, 2) == (4, 4)
    assert balanced(6, 4) == (2, 2, 1, 1)
    assert balanced(5, 2) == (3, 2)
    assert sum(balanced(17, 5)) == 17


# -- the capacity-waste bug (ISSUE satellite) ----------------------------------

def test_non_power_of_two_capacity_prices_every_slot():
    """capacity=6, ways=4 used to price 4x1 slots against a fused cost of
    6 x max — dropping 2 slots and inflating the gain."""
    sp = ConfigSpace(capacity=6, max_ways=4)
    rem = [50.0, 50.0, 50.0, 50.0]
    # a lockstep batch gains nothing from splitting; the old pricing
    # reported (6*50 - 4*1*50) / (6*50) = 1/3 of phantom gain here
    assert sp.gain(rem, 4) == pytest.approx(0.0)
    t = sp.as_topology(4)
    assert sum(t) == 6 and t == (2, 2, 1, 1)
    assert sp.slot_cost(rem, 4) == pytest.approx(6 * 50.0)
    assert topology_name(4, 6) == "2+2+1+1"       # not a lossless-looking 4x1
    assert topology_name(2, 8) == "2x4"


# -- reachability --------------------------------------------------------------

@pytest.mark.parametrize("capacity,max_ways", [(6, 3), (8, 4), (8, 8)])
def test_every_topology_reachable_from_fused_by_single_moves(capacity,
                                                             max_ways):
    sp = ConfigSpace(capacity=capacity, max_ways=max_ways)
    fused = (capacity,)
    seen = {fused}
    frontier = [fused]
    while frontier:
        nxt = []
        for t in frontier:
            for nb in sp.neighbors(t):
                assert sp.legal(nb), nb
                if nb not in seen:
                    seen.add(nb)
                    nxt.append(nb)
        frontier = nxt
    assert seen == set(sp.compositions())


def test_moves_change_part_count_by_a_legal_step():
    sp = ConfigSpace(capacity=8, max_ways=8)
    for t in sp.compositions():
        for nb in sp.split_moves(t):
            assert len(nb) > len(t) and sum(nb) == 8
        for nb in sp.fuse_moves(t):
            assert len(nb) < len(t) and sum(nb) == 8
        for nb in sp.resize_moves(t):
            assert len(nb) == len(t) and sum(nb) == 8 and nb != t


def test_resize_recuts_a_stale_quarantine():
    """A (7, 1) cut whose wide part inherited fresh tail work re-shapes
    to quarantine the new longs — the drifted-mix fix."""
    sp = ConfigSpace(capacity=8, max_ways=2)
    drifted = [1.0, 1.0, 1.0, 1.0, 39.0, 39.0, 39.0, 38.0]
    t = sp.suggest_improve((7, 1), drifted)
    assert t is not None and len(t) == 2
    assert sp.slot_cost(drifted, t) < sp.slot_cost(drifted, (7, 1))
    assert min(t) >= 3                      # the tail needs a wider slice
    assert (5, 3) in sp.resize_moves((7, 1))
    assert sp.resize_moves((8,)) == ()      # nothing to re-cut when fused
    assert ConfigSpace(8, 2, hetero=False).resize_moves((4, 4)) == ()
    # a resize is a single amortization-checked transition
    assert sp.transition_ok((7, 1), (5, 3), gain=0.2)
    assert not sp.transition_ok((7, 1), (5, 3), gain=-0.1)


# -- partition conservation ----------------------------------------------------

@pytest.mark.parametrize("policy", ["warp_regroup", "direct_split"])
def test_partition_conserves_indices_and_respects_budgets(policy):
    rng = np.random.default_rng(0)
    sp = ConfigSpace(capacity=8, max_ways=8)
    for t in sp.compositions():
        for b in (2, 3, 5, 8):
            rem = rng.integers(1, 100, b).astype(float)
            parts = sp.partition(list(range(b)), rem, t, policy)
            flat = [i for p in parts for i in p]
            assert sorted(flat) == list(range(b))          # conservation
            assert len(parts) == len(t)
            for s, p in zip(t, parts):
                assert len(p) <= s                         # slot budget
            if b >= len(t):
                assert all(len(p) >= 1 for p in parts)     # no stranded part


def test_two_way_partition_is_bit_identical_to_regroup_pair():
    from repro.core.regroup import POLICIES
    sp = ConfigSpace(capacity=8, max_ways=8)
    rng = np.random.default_rng(1)
    for b in (2, 3, 4, 7, 8):
        rem = rng.integers(0, 120, b).astype(float)
        for policy in ("warp_regroup", "direct_split"):
            fast, slow = POLICIES[policy](list(range(b)), rem)
            assert sp.partition(list(range(b)), rem, (4, 4), policy) \
                == [fast, slow]


# -- skew-aware sizing ---------------------------------------------------------

def test_skewed_tail_prefers_unequal_cut():
    """The paper's heterogeneous-SM case: 5 short + 3 long requests get
    the (5, 3) cut, which no equal ladder can express."""
    sp = ConfigSpace(capacity=8, max_ways=8)
    rem = [2.0, 2.0, 2.0, 2.0, 2.0, 90.0, 90.0, 90.0]
    best, gain = sp.best_topology(rem)
    assert gain > sp.gain(rem, 2) > 0.0          # beats the balanced pair
    assert len(set(best)) > 1                    # genuinely heterogeneous
    assert sp.slot_cost(rem, best) < sp.slot_cost(rem, (4, 4))
    # and (5, 3) itself prices below every equal split
    for ways in (2, 4, 8):
        assert sp.slot_cost(rem, (5, 3)) <= sp.slot_cost(rem, ways)


def test_no_phantom_gain_from_stranded_slots():
    """A lockstep 2-request batch must not 'gain' by scattering into 8
    one-slot parts whose 6 empty slots get priced at zero."""
    sp = ConfigSpace(capacity=8, max_ways=8)
    best, gain = sp.best_topology([50.0, 50.0])
    assert gain == pytest.approx(0.0)
    assert len(best) <= 2
    assert sp.gain([50.0, 50.0], (1,) * 8) == 0.0
    _, ladder_gain = sp.best_ways([50.0, 50.0])
    assert ladder_gain == pytest.approx(0.0)
    # and the move suggesters never propose more parts than requests
    t = sp.suggest_split((8,), [50.0, 50.0])
    assert t is None or len(t) <= 2


def test_drained_group_never_resizes_onto_empty_parts():
    """A split group that drained below its part count must not 'improve'
    by shuffling slot budget onto parts that would stay empty."""
    sp = ConfigSpace(capacity=8, max_ways=4)
    drained = [90.0, 5.0]                   # 2 live requests, 3 parts
    assert sp.suggest_improve((2, 2, 4), drained) is None
    assert sp.move_gain(drained, (2, 2, 4), (2, 4, 2)) == 0.0
    assert not sp.transition_ok((2, 2, 4), (2, 4, 2),
                                sp.move_gain(drained, (2, 2, 4), (2, 4, 2)))
    # with enough live work the same resize is scored on its merits
    busy = [90.0, 5.0, 80.0, 3.0, 70.0, 2.0, 60.0, 1.0]
    t = sp.suggest_improve((7, 1), busy)
    assert t is not None and len(t) <= len(busy)


def test_oracle_fuses_back_when_split_edge_shrinks_below_margin():
    """The fuse-back hysteresis: a split whose gain over fused drops
    under the margin targets fused again instead of holding forever."""
    sp = ConfigSpace(capacity=8, max_ways=4)
    pol = OraclePolicy(space=sp, margin=0.05)
    nearly_lockstep = fv_of([50.0, 50.0, 50.0, 49.0, 50.0, 50.0, 50.0, 48.0])
    assert 0.0 < sp.best_topology(nearly_lockstep.remaining)[1] < 0.05
    d = pol.decide(nearly_lockstep, (4, 4))
    assert d.ways == 1 and d.topology == (8,)


def test_move_gain_is_relative_to_current_topology():
    sp = ConfigSpace(capacity=8, max_ways=4)
    rem = [100.0, 5.0, 90.0, 3.0]
    g_fused_to_pair = sp.move_gain(rem, (8,), (5, 3))
    assert g_fused_to_pair == pytest.approx(sp.gain(rem, (5, 3)))
    # a second split from the pair saves less than the first did
    assert sp.move_gain(rem, (5, 3), (5, 2, 1)) < g_fused_to_pair


def test_transition_ok_per_part_moves():
    sp = ConfigSpace(capacity=8, max_ways=4, min_gain=0.05)
    assert sp.transition_ok((8,), (5, 3), gain=0.2)
    assert not sp.transition_ok((8,), (5, 3), gain=0.01)   # under the floor
    assert not sp.transition_ok((8,), (4, 2, 2), gain=0.9)  # two moves away
    assert sp.transition_ok((5, 3), (8,), gain=0.0)        # fuse amortizes
    assert sp.transition_ok((4, 2, 2), (4, 4), gain=0.0)   # neighbor merge
    assert not sp.transition_ok((2, 4, 2), (4, 4), gain=0.0)  # no single merge
    assert not sp.transition_ok((5, 3), (5, 3), gain=1.0)


def test_best_topology_greedy_matches_enumeration_on_small_space():
    sp = ConfigSpace(capacity=8, max_ways=4)
    rem = [2.0, 2.0, 2.0, 40.0, 90.0, 90.0, 3.0, 2.0]
    t_enum, g_enum = sp.best_topology(rem)
    # force the greedy path by monkey-ish large threshold: emulate via
    # neighbors-only hill climb from fused
    cur, cur_gain = (8,), 0.0
    for _ in range(8):
        step = None
        for nb in sp.neighbors(cur):
            g = sp.gain(rem, nb)
            if g > cur_gain + 1e-12:
                step, cur_gain = nb, g
        if step is None:
            break
        cur = step
    assert g_enum >= cur_gain - 1e-9
    assert g_enum >= sp.gain(rem, 2)


# -- controller integration ----------------------------------------------------

def test_controller_walks_to_heterogeneous_topology():
    sp = ConfigSpace(capacity=8, max_ways=4)
    gc = GroupController(OraclePolicy(space=sp, margin=0.01), sp, dwell=1)
    skew = fv_of([2.0, 2.0, 2.0, 2.0, 2.0, 90.0, 90.0, 90.0])
    for _ in range(6):
        gc.observe(skew)
    assert gc.state.split
    # at least one applied move landed on an unequal composition
    assert any(len(set(to)) > 1 for _, _, to, _, _ in gc.state.transitions)
    for _step, frm, to, gain, _r in gc.state.transitions:
        assert to in sp.neighbors(frm)
        if len(to) > len(frm):
            assert gain > sp.min_gain


def test_per_part_dwell_clocks_are_independent():
    """A part that just reconfigured blocks its own next move without
    freezing its siblings."""
    sp = ConfigSpace(capacity=8, max_ways=4)
    gc = GroupController(OraclePolicy(space=sp, margin=0.0), sp, dwell=3)
    st = gc.state
    st.topology = (4, 4)
    st.part_ages = [5, 0]               # part 1 just reconfigured
    assert sp.touched_parts((4, 4), (2, 2, 4)) == (0,)
    assert sp.touched_parts((4, 4), (4, 2, 2)) == (1,)
    assert sp.touched_parts((4, 4), (2, 2, 2, 2)) == (0, 1)
    # ages carry across a move that only touches part 0
    ages = gc._rebuild_ages((4, 4), (2, 2, 4), [5, 9])
    assert ages == [0, 0, 9]
    ages = gc._rebuild_ages((4, 2, 2), (4, 4), [7, 1, 2])
    assert ages == [7, 0]


def test_group_controller_accepts_exact_topology_hint():
    sp = ConfigSpace(capacity=8, max_ways=4)
    gc = GroupController(ThresholdPolicy(0.99, 0.0), sp, dwell=1)
    gc.request_topology((5, 3))
    skew = fv_of([2.0, 2.0, 2.0, 2.0, 2.0, 90.0, 90.0, 90.0])
    assert gc.observe(skew) == 2
    assert gc._hint is None             # retired once the count matched


# -- replay recency + drift reset ----------------------------------------------

def test_replay_weighted_dataset_decays_with_age():
    buf = ReplayBuffer(maxlen=64)
    for i in range(32):
        buf.add(np.full(5, float(i)), float(i % 2))
    X, y, w = buf.weighted_dataset(half_life=8)
    assert w[-1] == pytest.approx(1.0)
    assert w[-9] == pytest.approx(0.5)          # one half-life older
    assert np.all(np.diff(w) > 0)               # strictly fresher = heavier
    X2, y2, w2 = buf.weighted_dataset(None)
    assert np.all(w2 == 1.0)


def test_replay_reset_keeps_newest_window():
    buf = ReplayBuffer(maxlen=64)
    for i in range(40):
        buf.add(np.full(5, float(i)), 1.0)
    buf.reset(keep_last=8)
    assert len(buf) == 8
    X, _ = buf.dataset()
    assert X[0, 0] == 32.0 and X[-1, 0] == 39.0
    buf.reset()
    assert len(buf) == 0


def test_online_policy_drift_reset_forgets_stale_regime():
    """After a regime flip the drift check drops the stale buffer and the
    policy falls back to its threshold bootstrap instead of riding a
    wrong model for replay_capacity samples."""
    rng = np.random.default_rng(0)
    buf = ReplayBuffer(maxlen=1024)
    pol = OnlinePolicy(replay=buf, refit_every=16, min_samples=32,
                       train_steps=150, drift_window=24,
                       drift_threshold=0.6)
    # regime A: feature 0 high => split wins
    for _ in range(128):
        x = rng.normal(size=5)
        buf.add(x, 1.0 if x[0] > 0 else 0.0)
    assert pol.maybe_refit() and pol.fitted
    # regime B: the relationship inverts
    for _ in range(48):
        x = rng.normal(size=5)
        buf.add(x, 0.0 if x[0] > 0 else 1.0)
    assert pol.drift_detected()
    pol.maybe_refit()                    # the refit path routes to reset
    assert pol.drift_resets == 1
    assert not pol.fitted                # back to bootstrap
    assert len(buf) == pol.drift_window


def test_train_logistic_sample_weight_steers_fit():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(256, 2))
    y_new = (X[:, 0] > 0).astype(float)
    y_old = 1.0 - y_new
    # first half labeled by the stale rule, second half by the fresh one
    y = np.concatenate([y_old[:128], y_new[128:]])
    w_fresh = np.concatenate([np.full(128, 1e-3), np.ones(128)])
    m_flat, _ = P.train_logistic(X, y, steps=200)
    m_fresh, _ = P.train_logistic(X, y, steps=200, sample_weight=w_fresh)
    acc = lambda m: float(np.mean(
        (np.asarray(P.predict_proba(m, X[128:])) > 0.5) == (y_new[128:] > .5)))
    assert acc(m_fresh) > 0.9 > acc(m_flat) + 0.2


# -- feature ablation ----------------------------------------------------------

def test_serve_feature_ablation_reports_every_feature():
    from repro.control import (SERVE_FEATURES, build_serve_corpus,
                               serve_feature_ablation,
                               train_serve_predictor)
    X, y = build_serve_corpus(n_samples=256, seed=0)
    model, _ = train_serve_predictor(n_samples=256, steps=200, seed=0)
    abl = serve_feature_ablation(model, X, y, steps=120)
    assert set(abl) == set(SERVE_FEATURES)
    for row in abl.values():
        assert {"mean_abs_impact", "drop_one_accuracy",
                "accuracy_cost"} <= set(row)
    # divergence is the paper's dominant signal at the serve level too
    top = max(abl, key=lambda k: abl[k]["mean_abs_impact"])
    assert top in ("divergence", "spread")
