"""Work-stealing demo: cross-group migration on an imbalanced fleet.

The chip-level scheduling story of ``repro.fleet.migrate``, end to end:

1. **KVTransferCost** — what moving a live request actually costs: KV
   bytes as a function of sequence length and the model config, turned
   into destination-part stall ticks by the link bandwidth.

2. **Fleet A/B** — replay one shard-skewed trace (``imbalanced_trace``:
   a hot router shard hammers one group under sticky routing while its
   neighbors starve) through the same fleet with migration disabled and
   enabled, and compare p99 latency plus the steal/migration counters.

    PYTHONPATH=src python examples/work_stealing.py --horizon 60
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--horizon", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="qwen3-14b")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.configs.base import (AmoebaConfig, FleetConfig,
                                    MigrationConfig)
    from repro.fleet import FleetEngine, KVTransferCost, imbalanced_trace
    from repro.models import transformer as T
    from repro.serve.engine import make_decode_fn

    cfg = get_config(args.arch, reduced=True)

    # -- 1: the transfer-cost model -----------------------------------------
    print("== KVTransferCost: what a live migration costs ==")
    cost = KVTransferCost(link_bandwidth=4e9)
    for seq in (16, 64, 256):
        b = cost.kv_bytes(seq, cfg)
        print(f"  seq_len={seq:4d}: {b/1e6:7.3f} MB "
              f"-> stall {cost.stall_ticks(seq, cfg):.0f} tick(s)")
    print(f"  zero-bandwidth link: stall = "
          f"{KVTransferCost(link_bandwidth=0).stall_ticks(64, cfg)} "
          f"(live migration never amortizes; steals still flow)")

    # -- 2: fleet A/B — stealing off vs on ----------------------------------
    print("\n== fleet: sticky routing on a shard-skewed trace ==")
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    rt = T.Runtime(production=False, remat=False)
    decode = make_decode_fn(cfg, rt)
    amoeba = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                          min_phase_steps=2)
    for label, mig in (("no_stealing", MigrationConfig(enabled=False)),
                       ("stealing", MigrationConfig(enabled=True))):
        trace = imbalanced_trace(horizon=args.horizon,
                                 vocab_size=cfg.vocab_size,
                                 seed=args.seed, shards=args.groups)
        eng = FleetEngine(cfg, params, rt=rt, decode_fn=decode,
                          fleet=FleetConfig(
                              num_groups=args.groups,
                              capacity=args.capacity,
                              router="sticky", mode="dynamic",
                              rebalance_every=4, migrate=mig,
                              amoeba=amoeba))
        eng.submit(trace)
        s = eng.run()
        lat = s["latency"]
        line = (f"  {label:12s} ticks={s['wall_ticks']:4d} "
                f"p50={lat['p50']:5.1f} p99={lat['p99']:5.1f} "
                f"util={s['utilization']:.2f}")
        mig_s = s.get("migration")
        if mig_s:
            line += (f"  steals={mig_s['steals']} "
                     f"live={mig_s['live_migrations']} "
                     f"stall={mig_s['stall_ticks']}")
        print(line)


if __name__ == "__main__":
    main()
