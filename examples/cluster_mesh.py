"""Cluster-mesh demo: hierarchical fleet-of-fleets with tiered costs.

The ``repro.cluster`` layer end to end:

1. **ClusterMesh** — groups at 2D coordinates, tiled into chips (and
   chips into nodes); distances are Manhattan hops, and every pair of
   groups sits on a transfer tier: intra-chip NoC, inter-chip link, or
   inter-node network.

2. **TieredTransferCost** — the same KV bytes model the flat planner
   prices, walked across the tiers: a same-chip hop hides behind the
   decode tick while the identical transfer across chips pays per-hop
   latency over a slow wire, and a zero-bandwidth tier prices at
   infinity (the veto).

3. **Cluster A/B** — one multi-chip imbalanced trace (a hot chip bursts
   fat-tailed work while the other chips trickle) replayed through the
   same mesh twice: ``hierarchical`` (chip-first stealing, amortized
   crossings) vs ``flat_blind`` (``ClusterConfig.distance_blind``: one
   flat pool at plan time, physical tier prices at execution).

    PYTHONPATH=src python examples/cluster_mesh.py --horizon 40
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=2)
    ap.add_argument("--groups-per-chip", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--horizon", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="qwen3-14b")
    args = ap.parse_args()

    import jax

    from repro.cluster import ClusterEngine, ClusterMesh, TieredTransferCost
    from repro.configs import get_config
    from repro.configs.base import (AmoebaConfig, ClusterConfig, FleetConfig,
                                    MigrationConfig)
    from repro.fleet import multichip_imbalanced_trace
    from repro.models import transformer as T
    from repro.serve.engine import make_decode_fn

    cfg = get_config(args.arch, reduced=True)
    groups = args.chips * args.groups_per_chip

    # -- 1: the mesh ---------------------------------------------------------
    print("== ClusterMesh: groups tiled into chips on a 2D grid ==")
    mesh = ClusterMesh(num_groups=groups,
                       groups_per_chip=args.groups_per_chip)
    print(mesh.describe())

    # -- 2: tiered pricing ---------------------------------------------------
    print("\n== TieredTransferCost: one transfer, three distances ==")
    ccfg = ClusterConfig(groups_per_chip=args.groups_per_chip,
                         link_bandwidth=256.0, link_latency=12.0,
                         net_bandwidth=64.0, net_latency=24.0)
    cost = TieredTransferCost.from_config(mesh, ccfg, dtype_bytes=2,
                                          quantized=False)
    seq = 32
    nbytes = cost.kv_bytes(seq, cfg, window=256)
    pairs = [(0, 1)]
    if groups > args.groups_per_chip:
        pairs.append((0, args.groups_per_chip))
        pairs.append((0, groups - 1))
    for a, b in pairs:
        tier = mesh.tier(a, b)
        print(f"  g{a} -> g{b} ({tier:4s}, {mesh.hops(a, b)} hops): "
              f"{nbytes / 1e3:6.1f} KB of seq={seq} KV -> "
              f"stall {cost.stall_ticks(seq, cfg, window=256, src=a, dst=b):.0f} "
              f"tick(s)")
    dead = TieredTransferCost.from_config(
        mesh, ccfg.replace(link_bandwidth=0.0, net_bandwidth=0.0),
        dtype_bytes=2, quantized=False)
    print(f"  dead inter-chip tiers: cross-chip stall = "
          f"{dead.stall_ticks(seq, cfg, src=0, dst=groups - 1)} "
          f"(crossings vetoed; the NoC keeps flowing)")

    # -- 3: cluster A/B — hierarchical vs distance-blind ---------------------
    print("\n== cluster: one hot chip, tiered links, two cost models ==")
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    rt = T.Runtime(production=False, remat=False)
    decode = make_decode_fn(cfg, rt)
    amoeba = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                          min_phase_steps=2)
    for label, cluster in (("flat_blind", ccfg.replace(distance_blind=True)),
                           ("hierarchical", ccfg)):
        trace = multichip_imbalanced_trace(
            horizon=args.horizon, vocab_size=cfg.vocab_size,
            seed=args.seed, chips=args.chips,
            groups_per_chip=args.groups_per_chip)
        eng = ClusterEngine(cfg, params, rt=rt, decode_fn=decode,
                            fleet=FleetConfig(
                                num_groups=groups, capacity=args.capacity,
                                router="sticky", mode="dynamic",
                                rebalance_every=4,
                                migrate=MigrationConfig(enabled=True),
                                amoeba=amoeba, cluster=cluster))
        eng.submit(trace)
        s = eng.run()
        lat, m, cl = s["latency"], s["migration"], s["cluster"]
        print(f"  {label:12s} ticks={s['wall_ticks']:4d} "
              f"p50={lat['p50']:5.1f} p99={lat['p99']:5.1f} "
              f"steals noc={m['intra_chip_steals']} "
              f"cross={m['cross_chip_steals']} "
              f"vetoed={m['vetoed_cross_chip']} "
              f"link_stall={cl['tier_stall_ticks']['link']}")


if __name__ == "__main__":
    main()
