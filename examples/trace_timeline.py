"""Trace timeline demo: per-decision observability over a skewed run.

The ``repro.obs`` pipeline end to end, on the vec engine (no model
weights needed) over a cluster mesh:

1. run a shard-skewed trace with ``FleetConfig(obs="full")`` and an
   ``online`` policy so decisions carry realized labels;
2. export the event stream to JSONL and to Chrome trace-event JSON —
   open the latter at https://ui.perfetto.dev to see group topologies
   as spans, steals as flow arrows, reconfigs as instants;
3. print the text timeline, the decisions-preceding-reconfigs table
   ("which decision caused each topology change?"), and the decision
   audit's top-K misprediction table.

    PYTHONPATH=src python examples/trace_timeline.py --horizon 40
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=2)
    ap.add_argument("--groups-per-chip", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--horizon", type=int, default=40)
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--out-dir", default="/tmp")
    args = ap.parse_args()

    from repro.cluster import ClusterEngine
    from repro.configs import get_config
    from repro.configs.base import (AmoebaConfig, ClusterConfig,
                                    FleetConfig, MigrationConfig)
    from repro.fleet import multichip_imbalanced_trace
    from repro.obs import (render_attribution, render_mispredictions,
                           render_timeline, verify_replay, decision_rows,
                           write_chrome_trace, write_jsonl)

    cfg = get_config(args.arch, reduced=True)
    groups = args.chips * args.groups_per_chip

    # -- 1: an observed cluster run -----------------------------------------
    print("== observed run: skewed trace, online policy, obs='full' ==")
    fleet = FleetConfig(
        num_groups=groups, capacity=args.capacity, router="sticky",
        mode="dynamic", engine="vec", rebalance_every=4,
        migrate=MigrationConfig(enabled=True),
        amoeba=AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                            min_phase_steps=2, policy="online"),
        cluster=ClusterConfig(groups_per_chip=args.groups_per_chip),
        obs="full")
    eng = ClusterEngine(cfg, None, fleet=fleet)
    trace = multichip_imbalanced_trace(
        horizon=args.horizon, vocab_size=cfg.vocab_size, seed=args.seed,
        chips=args.chips, groups_per_chip=args.groups_per_chip)
    eng.submit(trace)
    s = eng.run()
    obs = s["obs"]
    print(f"  {s['completed']}/{s['submitted']} requests drained in "
          f"{s['wall_ticks']} ticks; {obs['total_events']} events: "
          + ", ".join(f"{k}={v}" for k, v in obs["by_kind"].items()))

    # -- 2: exporters --------------------------------------------------------
    os.makedirs(args.out_dir, exist_ok=True)
    jsonl = os.path.join(args.out_dir, "trace_timeline.jsonl")
    chrome = os.path.join(args.out_dir, "trace_timeline_chrome.json")
    n = write_jsonl(jsonl, eng.obs.events(), meta=eng.obs.meta)
    m = write_chrome_trace(chrome, eng.obs.events(), meta=eng.obs.meta)
    print(f"\n== exports ==\n  {jsonl}: {n} events (JSONL)\n"
          f"  {chrome}: {m} trace events — open at ui.perfetto.dev")

    # -- 3: the reports ------------------------------------------------------
    print("\n== timeline (first 25 events) ==")
    print(render_timeline(eng.obs.events(), limit=25))
    print("\n== which decision preceded each topology change? ==")
    print(render_attribution(eng.obs.events()))
    print("\n== decision audit: top-5 mispredictions ==")
    print(render_mispredictions(eng.obs.events(), k=5))
    rows = decision_rows(e.as_dict() for e in eng.obs.events())
    checked = verify_replay(rows, eng.policy.replay)
    print(f"\naudit cross-check: {checked} decision labels verified "
          f"against the live replay buffer")


if __name__ == "__main__":
    main()
