"""Control-plane demo: one policy stack from the simulator to the fleet.

Walks the two levels of the unified ``repro.control`` plane:

1. **gpusim level** — build the paper's offline corpus (§4.1.3: run both
   static configurations, label with the winner), train the logistic
   scalability predictor, and drive the simulator's per-kernel fuse
   decision through the shared ``PredictorPolicy`` — reporting its
   accuracy against the run-both ``OraclePolicy``.

2. **fleet level** — serve a bursty long-tail trace under
   ``OnlinePolicy``: the fleet starts on the threshold rule, logs
   (features, realized-win) samples into the telemetry replay buffer,
   refits its logistic model mid-run, and finishes predictor-in-the-loop.

    PYTHONPATH=src python examples/control_plane.py --horizon 80
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--horizon", type=int, default=80)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--variants", type=int, default=4,
                    help="gpusim corpus variants per workload")
    ap.add_argument("--arch", default="qwen3-14b")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.configs.base import AmoebaConfig, FleetConfig
    from repro.control import PredictorPolicy
    from repro.core.gpusim import WORKLOADS, profile_features
    from repro.core.gpusim.corpus import train_sim_predictor
    from repro.core.gpusim.sim import run_benchmark
    from repro.fleet import FleetEngine, bursty_longtail_trace
    from repro.models import transformer as T

    # -- level 1: the paper's offline predictor drives the simulator --------
    print("== gpusim: offline corpus -> logistic predictor ==")
    model, info = train_sim_predictor(variants_per_workload=args.variants,
                                      seed=args.seed, epochs=24)
    print(f"corpus n={info['n']}  train_acc={info['train_accuracy']:.3f}  "
          f"base-profile acc={info['base_profile_accuracy']:.3f}")
    policy = PredictorPolicy(model=model, positive_means_split=False)
    agree = 0
    for name, w in WORKLOADS.items():
        fused = policy.choose_static(profile_features(w))
        a = run_benchmark(w, "baseline", epochs=24)
        b = run_benchmark(w, "scale_up", epochs=24)
        agree += fused == (b.ipc > a.ipc)
        print(f"  {name:4s} predictor says {'fuse ' if fused else 'split'} "
              f"(oracle: {'fuse' if b.ipc > a.ipc else 'split'})")
    print(f"predictor/oracle agreement: {agree}/{len(WORKLOADS)}")

    # -- level 2: the same stack, online, in the serving fleet --------------
    print("\n== fleet: bursty long-tail trace under OnlinePolicy ==")
    cfg = get_config(args.arch, reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    trace = bursty_longtail_trace(horizon=args.horizon,
                                  vocab_size=cfg.vocab_size, seed=args.seed)
    eng = FleetEngine(cfg, params, fleet=FleetConfig(
        num_groups=args.groups, capacity=args.capacity,
        router="length_aware", mode="dynamic",
        amoeba=AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                            min_phase_steps=2, policy="online",
                            refit_every=48)))
    eng.submit(trace)
    s = eng.run()
    lat, ctl = s["latency"], s["control"]
    print(f"completed {s['completed']}/{s['submitted']}  "
          f"eff={s['efficiency']:.3f}  p50={lat['p50']:.1f}  "
          f"p99={lat['p99']:.1f}")
    print(f"replay samples={ctl['replay_samples']}  "
          f"refits={ctl.get('refits', 0)}")
    if ctl.get("last_refit"):
        lr = ctl["last_refit"]
        print(f"last refit: n={lr['n']}  acc={lr['train_accuracy']:.3f}  "
              f"nll tail={lr['loss_history_tail']}")


if __name__ == "__main__":
    main()
