"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps.

The config is a scaled qwen3 family member (12L x 768, ~103M params
including embeddings) on the synthetic Markov stream; loss drops well below
the unigram entropy because the stream has learnable bigram structure.
Checkpoints + fault-tolerant resume are on; pass --steps to shorten.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.ckpt import CheckpointManager
    from repro.data.pipeline import DataConfig
    from repro.train import Trainer

    cfg = get_config("qwen3-14b").replace(
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32_000)
    print(f"params: {cfg.param_count() / 1e6:.1f}M")

    shape = ShapeConfig("train_lm", args.seq, args.batch, "train")
    tcfg = TrainConfig(learning_rate=6e-4, warmup_steps=args.steps // 10,
                       total_steps=args.steps, checkpoint_every=100)
    trainer = Trainer(cfg, shape, tcfg, data_cfg=DataConfig(seed=0))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    out = trainer.train(args.steps, ckpt=ckpt, log_every=20)
    hist = out["history"]
    if hist:
        k = max(len(hist) // 10, 1)
        for m in hist[::k]:
            print(f"step {m.step:4d}  loss {m.loss:.4f}  "
                  f"gnorm {m.grad_norm:.2f}  lr {m.lr:.2e}  {m.dt:.2f}s")
        print(f"final loss {hist[-1].loss:.4f} "
              f"(uniform would be ln(32000)={np.log(32000):.2f}; "
              f"bigram floor = ln(8)={np.log(8):.2f})")
    print(f"straggles={len(out['monitor'].events)} resumes={out['resumes']}")


if __name__ == "__main__":
    main()
