"""Heterogeneous-topology demo: the composition lattice end to end.

Walks the paper's §5 headline capability — "dynamic creation of
heterogeneous SMs through independent fusing or splitting" — at the
three levels of this reproduction:

1. **ConfigSpace lattice** — enumerate the composition topologies of a
   capacity-8 group, show the skew-aware partitioner picking the
   ``(5, 3)`` cut that no equal-ways ladder can express.

2. **GroupController walk** — feed a skewed batch through the oracle
   policy and watch the controller climb the lattice one amortization-
   checked per-part move at a time.

3. **gpusim static chips (Fig 12)** — rank heterogeneous chip
   compositions (n fused pairs + rest split) and see workloads whose
   best static chip is a *mix*, not either homogeneous end.

4. **Fleet A/B** — replay one skewed long-tail trace through an
   equal-ladder fleet and a heterogeneous-composition fleet and compare
   p99 latency / slot efficiency.

    PYTHONPATH=src python examples/hetero_topology.py --horizon 60
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--horizon", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--skip-fleet", action="store_true",
                    help="lattice + gpusim only (no model init)")
    args = ap.parse_args()

    import numpy as np

    from repro.control import (ConfigSpace, FeatureVector, GroupController,
                               OraclePolicy, topology_name)

    # -- 1: the composition lattice -----------------------------------------
    print("== ConfigSpace: composition lattice ==")
    sp = ConfigSpace(capacity=args.capacity, max_ways=args.capacity)
    comps = sp.compositions()
    print(f"capacity={args.capacity}: {len(comps)} topologies "
          f"(ladder had {len(sp.topologies())})")
    skew = np.array([2.0, 2.0, 2.0, 2.0, 2.0, 90.0, 90.0, 90.0]
                    [:args.capacity])
    best, gain = sp.best_topology(skew)
    print(f"skewed batch {skew.astype(int).tolist()}:")
    print(f"  best topology   {topology_name(best, args.capacity):10s} "
          f"gain={gain:.3f}")
    print(f"  balanced pair   {topology_name(2, args.capacity):10s} "
          f"gain={sp.gain(skew, 2):.3f}")
    parts = sp.partition(list(range(skew.size)), skew, best)
    for slots, p in zip(best, parts):
        lens = [int(skew[i]) for i in p]
        print(f"  part x{slots} slots <- remaining {lens}")

    # -- 2: the controller climbs the lattice -------------------------------
    print("\n== GroupController: per-part moves under the oracle ==")
    gc = GroupController(OraclePolicy(space=sp, margin=0.01), sp, dwell=1)
    fv = FeatureVector.from_group(skew, 0, 0.0, args.capacity)
    for _ in range(6):
        gc.observe(fv)
    for step, frm, to, g, reason in gc.state.transitions:
        print(f"  tick {step}: {sp.name(frm)} -> {sp.name(to)} "
              f"(gain {g:.3f}; {reason})")

    # -- 3: gpusim heterogeneous static chips (Fig 12) ----------------------
    print("\n== gpusim: static chip-composition ranking ==")
    from repro.core.gpusim import WORKLOADS, rank_chip_mixes
    for name in ("SM", "RAY", "CP"):
        rows = rank_chip_mixes(WORKLOADS[name], epochs=16)
        tag = " <- heterogeneous wins" \
            if 0 < rows[0]["n_fused"] < 24 else ""
        print(f"  {name:4s} best {rows[0]['mix']:8s} "
              f"ipc={rows[0]['ipc']:.1f}{tag}")

    if args.skip_fleet:
        return

    # -- 4: fleet A/B — ladder vs compositions ------------------------------
    print("\n== fleet: equal ladder vs heterogeneous compositions ==")
    import jax

    from repro.configs import get_config
    from repro.configs.base import AmoebaConfig, FleetConfig
    from repro.fleet import FleetEngine, skewed_longtail_trace
    from repro.models import transformer as T
    from repro.serve.engine import make_decode_fn

    cfg = get_config(args.arch, reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    rt = T.Runtime(production=False, remat=False)
    decode = make_decode_fn(cfg, rt)
    base = AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                        min_phase_steps=2, policy="oracle",
                        max_ways=min(args.capacity, 8))
    for label, hetero in (("equal-ladder", False), ("heterogeneous", True)):
        trace = skewed_longtail_trace(horizon=args.horizon,
                                      vocab_size=cfg.vocab_size,
                                      seed=args.seed)
        eng = FleetEngine(cfg, params, rt=rt, decode_fn=decode,
                          fleet=FleetConfig(
                              num_groups=args.groups,
                              capacity=args.capacity,
                              router="length_aware", mode="dynamic",
                              amoeba=base.replace(hetero=hetero)))
        eng.submit(trace)
        s = eng.run()
        lat = s["latency"]
        topos = s["control"].get("topologies_visited", [])
        print(f"  {label:14s} eff={s['efficiency']:.3f} "
              f"p50={lat['p50']:5.1f} p99={lat['p99']:5.1f} "
              f"topologies={['+'.join(map(str, t)) for t in topos]}")


if __name__ == "__main__":
    main()
