"""Faithful-reproduction demo: the paper's Fig 12 in one command.

Runs all six schemes over the 12 calibrated benchmarks and prints the
speedup table with the paper's headline targets alongside.

    PYTHONPATH=src python examples/gpusim_paper.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main() -> None:
    from repro.core.gpusim import SCHEMES, WORKLOADS, run_all

    res = {s: run_all(s) for s in SCHEMES}
    base = res["baseline"]
    print(f"{'bench':8s}" + "".join(f"{s:>14s}" for s in SCHEMES[1:]))
    for name in WORKLOADS:
        row = [res[s][name].ipc / base[name].ipc for s in SCHEMES[1:]]
        print(f"{name:8s}" + "".join(f"{v:14.3f}" for v in row))
    print("-" * 78)
    for s in SCHEMES[1:]:
        sp = [res[s][n].ipc / base[n].ipc for n in WORKLOADS]
        print(f"geomean {s:14s} {np.exp(np.mean(np.log(sp))):.3f}")
    wr = {n: res["warp_regroup"][n].ipc / base[n].ipc for n in WORKLOADS}
    print(f"\npaper targets: SM 4.25x (got {wr['SM']:.2f}), "
          f"MUM 2.11x (got {wr['MUM']:.2f}), geomean ~1.47 "
          f"(got {np.exp(np.mean(np.log(list(wr.values())))):.3f})")


if __name__ == "__main__":
    main()
