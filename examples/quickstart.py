"""Quickstart: the AMOEBA loop end to end in two minutes on CPU.

1. Run the faithful GPU reproduction on one benchmark (paper Fig 12).
2. Train the scalability predictor and inspect its decision (Fig 20).
3. Train a reduced LM for a few steps with divergence telemetry.
4. Serve a small request trace with dynamic group splitting (Fig 19).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def main() -> None:
    # --- 1. the paper's machine --------------------------------------------
    from repro.core.gpusim import WORKLOADS, run_benchmark
    base = run_benchmark(WORKLOADS["RAY"], "baseline", epochs=64)
    amoeba = run_benchmark(WORKLOADS["RAY"], "warp_regroup", epochs=64)
    print(f"[gpusim] RAY: baseline IPC {base.ipc:.1f} -> AMOEBA "
          f"{amoeba.ipc:.1f} ({amoeba.ipc / base.ipc:.2f}x), "
          f"{amoeba.switches} fuse/split switches")

    # --- 2. the scalability predictor ---------------------------------------
    from repro.core import predictor as P
    from repro.core.gpusim import profile_features
    from repro.core.gpusim.corpus import train_sim_predictor
    model, info = train_sim_predictor(variants_per_workload=4, epochs=24)
    p = float(P.predict_proba(model, profile_features(WORKLOADS["RAY"])))
    print(f"[predictor] acc={info['train_accuracy']:.2f}, "
          f"P(fuse RAY)={p:.2f}")

    # --- 3. train a reduced LM ----------------------------------------------
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.train import Trainer
    cfg = get_config("qwen3-14b", reduced=True)
    tr = Trainer(cfg, ShapeConfig("demo", 64, 4, "train"),
                 TrainConfig(total_steps=8, warmup_steps=2,
                             learning_rate=1e-3))
    hist = tr.train(8)["history"]
    print(f"[train] qwen3-14b (reduced): loss {hist[0].loss:.3f} -> "
          f"{hist[-1].loss:.3f} over {len(hist)} steps")

    # --- 4. serve with dynamic splitting -------------------------------------
    from repro.configs.base import AmoebaConfig
    from repro.models import transformer as T
    from repro.serve import Request, ServeEngine
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(i, list(map(int, rng.integers(0, cfg.vocab_size, 8))),
                    int(rng.choice([3, 24], p=[0.7, 0.3])))
            for i in range(12)]
    eng = ServeEngine(cfg, params, amoeba=AmoebaConfig(
        regroup_policy="warp_regroup", split_threshold=0.3,
        fuse_threshold=0.05, min_phase_steps=2), capacity=4)
    eng.submit(reqs)
    st = eng.run(dynamic=True)
    print(f"[serve] {st.completed} requests, efficiency "
          f"{st.efficiency:.2f} tokens/slot-step, "
          f"{st.splits} splits / {st.fuses} re-fuses")


if __name__ == "__main__":
    main()
