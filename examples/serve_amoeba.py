"""Serving demo: AMOEBA dynamic group splitting vs the fused baseline.

Builds a long-tail request trace on a reduced model and runs the engine
under all three policies; prints per-policy efficiency, the controller's
split/fuse timeline (Fig 19 at the mesh level), and verifies the generated
text is identical across policies.

    PYTHONPATH=src python examples/serve_amoeba.py --requests 24
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--arch", default="qwen3-14b")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import AmoebaConfig
    from repro.models import transformer as T
    from repro.serve import Request, ServeEngine

    cfg = get_config(args.arch, reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)

    def mk():
        rng = np.random.default_rng(1)
        return [Request(i, list(map(int, rng.integers(
            0, cfg.vocab_size, int(rng.choice([8, 16]))))),
            int(rng.choice([4, 8, 48], p=[0.4, 0.35, 0.25])))
            for i in range(args.requests)]

    texts = {}
    for name, dyn, pol in [("fused_baseline", False, "warp_regroup"),
                           ("direct_split", True, "direct_split"),
                           ("warp_regroup", True, "warp_regroup")]:
        eng = ServeEngine(cfg, params, amoeba=AmoebaConfig(
            regroup_policy=pol, split_threshold=0.3, fuse_threshold=0.05,
            min_phase_steps=2), capacity=args.capacity)
        reqs = mk()
        eng.submit(reqs)
        st = eng.run(dynamic=dyn)
        texts[name] = {r.rid: tuple(r.generated) for r in reqs}
        print(f"{name:16s} ticks={st.ticks:4d} slots={st.slot_steps:6d} "
              f"eff={st.efficiency:.3f} splits={st.splits} "
              f"fuses={st.fuses} completed={st.completed}")
        if dyn and pol == "warp_regroup":
            hist = eng.controller.state.history
            timeline = "".join("S" if w > 1 else "." for _, w, _ in hist[:80])
            print(f"  controller timeline: {timeline}")
    same = texts["fused_baseline"] == texts["warp_regroup"] \
        == texts["direct_split"]
    print(f"generated tokens identical across policies: {same}")


if __name__ == "__main__":
    main()
