"""Fleet serving demo: N reconfigurable pairs vs the static chips.

Replays one bursty long-tail multi-tenant trace through three fleet
configurations (all-fused, all-split, AMOEBA-dynamic with length-aware
routing) and prints the fleet-wide telemetry plus a per-group breakdown
for the dynamic run — the chip-level view the single-pair demo
(``serve_amoeba.py``) cannot show.

    PYTHONPATH=src python examples/serve_fleet.py --groups 4 --horizon 120
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="qwen3-14b")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import AmoebaConfig
    from repro.fleet import bursty_longtail_trace, replay_modes
    from repro.models import transformer as T

    cfg = get_config(args.arch, reduced=True)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    rt = T.Runtime(production=False, remat=False)

    summaries = replay_modes(
        cfg, params, rt,
        lambda: bursty_longtail_trace(horizon=args.horizon,
                                      vocab_size=cfg.vocab_size,
                                      seed=args.seed),
        groups=args.groups, capacity=args.capacity,
        amoeba=AmoebaConfig(split_threshold=0.3, fuse_threshold=0.05,
                            min_phase_steps=2))

    dyn = summaries["amoeba_dynamic"]
    print("\namoeba_dynamic per-group:")
    for g in dyn["groups"]:
        print(f"  g{g['gid']} split={str(g['is_split']):5s} "
              f"eff={g['efficiency']:.3f} "
              f"splits={g['splits']} fuses={g['fuses']} "
              f"completed={g['completed']}")
    if "per_tenant" in dyn:
        for t, ts in dyn["per_tenant"].items():
            print(f"  tenant {t:6s} n={ts['n']:3d} "
                  f"p50={ts['p50']:5.1f} p99={ts['p99']:5.1f}")
    fus = summaries["static_fused"]
    print(f"\ndynamic vs static-fused: "
          f"p99 {fus['latency']['p99'] / max(dyn['latency']['p99'], 1e-9):.2f}x, "
          f"efficiency {dyn['efficiency'] / max(fus['efficiency'], 1e-9):.2f}x")


if __name__ == "__main__":
    main()
